"""L2 model: shapes, quantized-block fidelity, loss sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def arch():
    return M.ARCHS[3]  # tl-phi, smallest


@pytest.fixture(scope="module")
def params(arch):
    return M.init_params(arch, seed=0)


@pytest.fixture(scope="module")
def tokens(arch):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(1, arch.vocab, size=(2, arch.seq_len)), jnp.int32)


def test_model_fwd_shape(arch, params, tokens):
    logits = M.model_fwd(params, tokens, arch.n_heads)
    assert logits.shape == (2, arch.seq_len, arch.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_loss_finite_and_better_than_uniform(arch, params, tokens):
    loss = float(M.loss_fn(params, tokens, arch.n_heads))
    assert np.isfinite(loss)
    # random init should be near log(vocab), certainly below 2x it
    assert loss < 2 * np.log(arch.vocab)


def test_block_variants_match_raw(arch, params):
    """q8 block output must track the raw block closely; q4 less so; t2 worst.
    This ordering IS the paper's premise."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, arch.seq_len, arch.d_model)), jnp.float32)
    p = params["blocks"][0]
    y_raw = M.block_raw(x, p, arch.n_heads)

    errs = {}
    for fmt, fn in [("q8", M.block_q8), ("q4", M.block_q4), ("t2", M.block_t2)]:
        g1, g2, qs = M.quantize_block(p, fmt)
        y = fn(x, g1, g2, qs, arch.n_heads)
        errs[fmt] = float(jnp.abs(y - y_raw).max())
    assert errs["q8"] < 0.15
    assert errs["q8"] < errs["q4"] < errs["t2"]


def test_embed_head_roundtrip(arch, params, tokens):
    x = M.embed_fwd(tokens, params["embed"], params["pos"])
    assert x.shape == (2, arch.seq_len, arch.d_model)
    logits = M.head_fwd(x, params["gf"], params["head"])
    assert logits.shape == (2, arch.seq_len, arch.vocab)


def test_attention_is_causal(arch):
    rng = np.random.default_rng(2)
    d = arch.d_model
    q = jnp.asarray(rng.normal(size=(1, 8, d)), jnp.float32)
    k, v = q, q
    out1 = M.attention(q, k, v, arch.n_heads)
    # perturb a *future* position; earlier outputs must not change
    v2 = v.at[0, 7].add(10.0)
    k2 = k.at[0, 7].add(10.0)
    out2 = M.attention(q, k2, v2, arch.n_heads)
    np.testing.assert_allclose(out1[0, :7], out2[0, :7], atol=1e-5)
    assert float(jnp.abs(out1[0, 7] - out2[0, 7]).max()) > 1e-3


def test_quantize_block_covers_all_mats(arch, params):
    _, _, qs = M.quantize_block(params["blocks"][0], "q8")
    assert set(qs) == set(M.BLOCK_MATS)


def test_archs_are_well_formed():
    for a in M.ARCHS:
        assert a.d_model % a.n_heads == 0
        assert a.d_model % 4 == 0 and a.d_ff % 4 == 0  # t2 packing needs k%4==0
        assert a.vocab == 512 and a.seq_len == 32
