"""AOT lowering smoke: artifacts exist, are HLO text, entropy module computes."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.kernels.entropy import NEG_PAD, entropy_fixed
from compile.kernels import ref


def test_lower_arch_produces_hlo_text(tmp_path):
    aot.lower_arch(str(tmp_path), M.ARCHS[3])
    names = ["embed", "head", "block_raw", "block_q8", "block_q4", "block_t2"]
    for n in names:
        p = tmp_path / f"{n}.hlo.txt"
        assert p.exists()
        text = p.read_text()
        assert text.startswith("HloModule"), n
        assert "ROOT" in text


def test_entropy_fixed_matches_ref():
    rng = np.random.default_rng(0)
    n = 5000
    w = np.full(aot.ENTROPY_PAD, NEG_PAD, np.float32)
    w[:n] = rng.normal(0, 0.4, size=n)
    h = float(entropy_fixed(jnp.asarray(w))[0])
    h_ref = float(ref.softmax_entropy(w[:n]))
    assert abs(h - h_ref) < 2e-3


def test_entropy_pad_covers_largest_matrix():
    biggest = max(a.d_model * a.d_ff for a in M.ARCHS)
    assert aot.ENTROPY_PAD >= biggest


def test_schema_write(tmp_path):
    p = tmp_path / "schema.txt"
    aot.write_schema(str(p), M.ARCHS[0])
    kv = dict(line.split("=") for line in p.read_text().strip().splitlines())
    assert kv["name"] == "tl-llama"
    assert int(kv["n_blocks"]) == 8
    assert int(kv["eval_batch"]) == aot.EVAL_BATCH
