"""Synthetic corpus / SynthMMLU determinism and validity."""

import numpy as np
import pytest

from compile import corpus as C


def test_fact_table_deterministic_and_permutation():
    a = C.fact_table()
    b = C.fact_table()
    assert (a == b).all()
    for r in range(C.N_REL):
        objs = sorted(a[r].tolist())
        assert objs == list(range(C.ENT_BASE, C.ENT_BASE + C.N_ENT))


def test_sampler_sequences_have_shape_and_range():
    s = C.CorpusSampler(seed=1)
    batch = s.batch(4)
    assert batch.shape == (4, C.SEQ_LEN)
    assert batch.min() >= 0 and batch.max() < C.VOCAB


def test_fact_segments_are_consistent_with_table():
    s = C.CorpusSampler(seed=2, fact_frac=1.0)
    objs = C.fact_table()
    seq = s.sequence()
    # scan for [Q, s, r, A, o] windows
    found = 0
    for i in range(len(seq) - 4):
        if seq[i] == C.Q and seq[i + 3] == C.A:
            sub, rel, obj = int(seq[i + 1]), int(seq[i + 2]), int(seq[i + 4])
            assert objs[rel - C.REL_BASE, sub - C.ENT_BASE] == obj
            found += 1
    assert found >= 2


def test_eval_questions_valid():
    qs = C.eval_questions(per_subject=4)
    assert len(qs) == 4 * C.N_REL
    objs = C.fact_table()
    for subject, ctx, choices, correct in qs:
        assert 0 <= subject < C.N_REL
        assert len(ctx) == 4 and ctx[0] == C.Q and ctx[3] == C.A
        assert len(set(choices)) == 4
        s, r = ctx[1] - C.ENT_BASE, ctx[2] - C.REL_BASE
        assert choices[correct] == int(objs[r, s])


def test_eval_questions_deterministic():
    a = C.eval_questions(per_subject=2)
    b = C.eval_questions(per_subject=2)
    assert a == b


def test_write_facts_roundtrip(tmp_path):
    p = tmp_path / "facts.txt"
    C.write_facts(str(p))
    lines = p.read_text().strip().splitlines()
    assert lines[0].startswith("#")
    assert len(lines) - 1 == C.N_REL * C.N_ENT
    objs = C.fact_table()
    r, s, o = map(int, lines[1].split())
    assert objs[r - C.REL_BASE, s - C.ENT_BASE] == o
