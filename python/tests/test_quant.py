"""Quantization formats + fused Pallas dequant-matmul kernels vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import quant as kq


def rand_w(k, n, seed, scale=0.5):
    return np.random.default_rng(seed).normal(0, scale, size=(k, n)).astype(np.float32)


# ---- format round-trips ------------------------------------------------------
def test_q8_roundtrip_error_bounded():
    w = rand_w(64, 48, 0)
    q, s = ref.quantize_q8(w)
    wd = np.asarray(ref.dequant_q8(q, s))
    # error per element bounded by half a quantization step per column
    assert (np.abs(wd - w) <= 0.5 * np.asarray(s)[None, :] + 1e-7).all()


def test_q4_roundtrip_error_bounded():
    w = rand_w(64, 48, 1)
    p, s = ref.quantize_q4(w)
    wd = np.asarray(ref.dequant_q4(p, s))
    assert (np.abs(wd - w) <= 0.5 * np.asarray(s)[None, :] + 1e-7).all()
    assert p.shape == (32, 48) and p.dtype == np.uint8


def test_t2_codes_are_ternary():
    w = rand_w(64, 16, 2)
    p, s = ref.quantize_t2(w)
    wd = np.asarray(ref.dequant_t2(p, s))
    ratio = wd / np.maximum(np.asarray(s)[None, :], 1e-12)
    assert set(np.round(ratio.ravel()).astype(int)) <= {-1, 0, 1}


def test_q8_preserves_sign_of_large_entries():
    w = rand_w(32, 8, 3, scale=1.0)
    q, s = ref.quantize_q8(w)
    big = np.abs(w) > np.asarray(s)[None, :]
    assert (np.sign(np.asarray(q))[big] == np.sign(w)[big]).all()


@settings(max_examples=10, deadline=None)
@given(
    k=st.sampled_from([8, 32, 64, 96]),
    n=st.sampled_from([8, 16, 48, 128]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_q4_pack_unpack_exact(k, n, seed):
    w = rand_w(k, n, seed)
    p, s = ref.quantize_q4(w)
    wd = np.asarray(ref.dequant_q4(p, s))
    # re-quantizing the dequantized weights is a fixed point
    p2, s2 = ref.quantize_q4(wd)
    assert np.allclose(np.asarray(s), np.asarray(s2), rtol=1e-5)
    assert (np.asarray(p) == np.asarray(p2)).all()


# ---- fused kernels vs oracle ---------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([8, 64, 256]),
    k=st.sampled_from([64, 96, 112]),
    n=st.sampled_from([64, 96, 384]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_matmul_q8_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    q, s = ref.quantize_q8(rand_w(k, n, seed + 1))
    o_ref = np.asarray(ref.matmul_dequant_q8(x, q, s))
    o_pal = np.asarray(kq.matmul_q8(jnp.asarray(x), q, s))
    np.testing.assert_allclose(o_pal, o_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([8, 128]),
    k=st.sampled_from([64, 96]),
    n=st.sampled_from([48, 256]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_matmul_q4_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    p, s = ref.quantize_q4(rand_w(k, n, seed + 1))
    o_ref = np.asarray(ref.matmul_dequant_q4(x, p, s))
    o_pal = np.asarray(kq.matmul_q4(jnp.asarray(x), p, s))
    np.testing.assert_allclose(o_pal, o_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([8, 64]),
    k=st.sampled_from([64, 128]),
    n=st.sampled_from([32, 96]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_matmul_t2_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    p, s = ref.quantize_t2(rand_w(k, n, seed + 1))
    o_ref = np.asarray(ref.matmul_dequant_t2(x, p, s))
    o_pal = np.asarray(kq.matmul_t2(jnp.asarray(x), p, s))
    np.testing.assert_allclose(o_pal, o_ref, rtol=1e-4, atol=1e-4)


def test_tile_helper():
    assert kq._tile(256) == 128
    assert kq._tile(96) == 32
    assert kq._tile(112) == 16
    assert kq._tile(7) == 7
