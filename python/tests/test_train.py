"""Training-loop sanity: the hand-rolled Adam actually optimizes."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import corpus as C
from compile.model import ARCHS, init_params, loss_fn
from compile.train import adam_init, make_step, train


def test_loss_decreases_over_a_few_steps():
    arch = ARCHS[3]  # tl-phi
    params = init_params(arch, seed=3)
    m, v = adam_init(params)
    step = make_step(arch, lr_max=2e-3, steps=30, warmup=5)
    sampler = C.CorpusSampler(seed=C.SEED + 3, fact_frac=1.0)
    losses = []
    for i in range(30):
        tokens = jnp.asarray(sampler.batch(8))
        params, m, v, loss = step(params, m, v, tokens, jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, f"{losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(losses))


def test_train_wrapper_returns_log():
    arch = ARCHS[3]
    params, log = train(arch, steps=3, batch=4, log=lambda m: None)
    assert len(log) >= 1
    assert all(np.isfinite(l) for _, l in log)
    # params keep their structure
    assert params["embed"].shape == (arch.vocab, arch.d_model)
    assert len(params["blocks"]) == arch.n_blocks


def test_warmup_then_decay_lr_shape():
    # the cosine schedule must warm up then decay (probe via two short runs)
    arch = ARCHS[3]
    step = make_step(arch, lr_max=1e-2, steps=100, warmup=10)
    # indirectly verified by optimization stability above; here check the
    # step function is jittable and reusable across step indices
    params = init_params(arch, seed=1)
    m, v = adam_init(params)
    toks = jnp.asarray(C.CorpusSampler(seed=1).batch(4))
    for i in [0, 5, 50, 99]:
        params, m, v, loss = step(params, m, v, toks, jnp.asarray(i))
        assert np.isfinite(float(loss))
