"""ETS tensor-store round-trips (format shared with rust/src/tensor/store.rs)."""

import numpy as np
import pytest

from compile import ets


def test_roundtrip_all_dtypes(tmp_path):
    p = str(tmp_path / "t.ets")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": (np.arange(8, dtype=np.int8) - 4),
        "c": np.arange(16, dtype=np.uint8).reshape(2, 2, 4),
        "d": np.asarray([7, -9], dtype=np.int32),
    }
    ets.write_ets(p, tensors)
    out = ets.read_ets(p)
    assert set(out) == set(tensors)
    for k in tensors:
        assert out[k].dtype == tensors[k].dtype
        assert out[k].shape == tensors[k].shape
        assert (out[k] == tensors[k]).all()


def test_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "t.ets")
    ets.write_ets(p, {"w": np.ones((4, 4), np.float32)})
    raw = bytearray(open(p, "rb").read())
    raw[20] ^= 0xFF  # flip a data byte
    open(p, "wb").write(bytes(raw))
    with pytest.raises(ValueError):
        ets.read_ets(p)


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError):
        ets.write_ets(str(tmp_path / "x.ets"), {"w": np.ones(3, np.float64)})


def test_empty_store(tmp_path):
    p = str(tmp_path / "e.ets")
    ets.write_ets(p, {})
    assert ets.read_ets(p) == {}
