"""L1 entropy kernel vs pure-jnp/numpy oracle — the core correctness signal."""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.entropy import CHUNK, softmax_entropy_pallas, pad_to_chunks


def numpy_entropy(w, eps=1e-12):
    w = np.ravel(np.asarray(w, np.float64))
    m = w.max()
    e = np.exp(w - m)
    p = e / e.sum()
    return float(-(p * np.log(p + eps)).sum())


def test_uniform_weights_give_log_n():
    # all-equal weights -> uniform p -> H = log(n)
    w = np.zeros(4096, np.float32)
    assert math.isclose(float(ref.softmax_entropy(w)), math.log(4096), rel_tol=1e-5)
    assert math.isclose(
        float(softmax_entropy_pallas(jnp.asarray(w))), math.log(4096), rel_tol=1e-4
    )


def test_one_hot_gives_zero():
    w = np.zeros(2048, np.float32)
    w[7] = 200.0  # softmax ~ one-hot
    assert float(ref.softmax_entropy(w)) < 1e-3
    assert float(softmax_entropy_pallas(jnp.asarray(w))) < 1e-3


def test_pad_preserves_entropy():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.5, size=1234).astype(np.float32)  # not a CHUNK multiple
    h = float(softmax_entropy_pallas(jnp.asarray(w)))
    assert math.isclose(h, numpy_entropy(w), rel_tol=1e-4)


def test_padding_layout():
    w = np.ones(10, np.float32)
    padded = np.asarray(pad_to_chunks(jnp.asarray(w)))
    assert padded.shape[0] == CHUNK
    assert (padded[:10] == 1.0).all() and (padded[10:] < -1e29).all()


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=3 * CHUNK + 5),
    scale=st.floats(min_value=0.01, max_value=3.0),
    loc=st.floats(min_value=-5.0, max_value=5.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_matches_oracle(n, scale, loc, seed):
    rng = np.random.default_rng(seed)
    w = (rng.normal(loc, scale, size=n)).astype(np.float32)
    h_ref = numpy_entropy(w)
    h_jnp = float(ref.softmax_entropy(w))
    h_pal = float(softmax_entropy_pallas(jnp.asarray(w)))
    assert math.isclose(h_jnp, h_ref, rel_tol=2e-3, abs_tol=2e-3)
    assert math.isclose(h_pal, h_ref, rel_tol=2e-3, abs_tol=2e-3)


def test_eps_monotone():
    # entropy with larger eps is strictly smaller (log(p+eps) grows)
    rng = np.random.default_rng(1)
    w = rng.normal(size=4096).astype(np.float32)
    h_small = float(ref.softmax_entropy(w, eps=1e-12))
    h_big = float(ref.softmax_entropy(w, eps=1e-2))
    assert h_big < h_small


def test_shift_invariance():
    # softmax is shift invariant -> entropy must be too
    rng = np.random.default_rng(2)
    w = rng.normal(size=2048).astype(np.float32)
    h1 = float(ref.softmax_entropy(w))
    h2 = float(ref.softmax_entropy(w + 3.5))
    assert math.isclose(h1, h2, rel_tol=1e-4)


def test_block_entropy_weighting():
    # block entropy is the size-weighted mean: a 3x larger matrix dominates
    rng = np.random.default_rng(3)
    a = rng.normal(0, 0.1, size=(32, 32)).astype(np.float32)   # low spread
    b = rng.normal(0, 2.0, size=(96, 32)).astype(np.float32)   # high spread
    hb = float(ref.block_entropy([a, b]))
    ha_only = float(ref.softmax_entropy(a))
    hb_only = float(ref.softmax_entropy(b))
    lo, hi = min(ha_only, hb_only), max(ha_only, hb_only)
    assert lo <= hb <= hi
    expect = (a.size * ha_only + b.size * hb_only) / (a.size + b.size)
    assert math.isclose(hb, expect, rel_tol=1e-5)
