"""Synthetic fact corpus + SynthMMLU specification.

The paper evaluates on MMLU (57 subjects, 4-choice QA) over pretrained HF
models. Offline we substitute a *fact-retrieval language*: a vocabulary of
entities and 57 relation families ("subjects"); each relation `r` maps every
subject entity `s` to a deterministic object `obj_r(s)`. Models are trained
to memorize fact sentences `[Q s r A o SEP]` mixed with Markov background
noise, then evaluated on 4-choice questions `[Q s r A] -> ?` — accuracy and
the paper's Section-5.2 perplexity formulas apply verbatim.

Everything here is deterministic given SEED so the Rust side can rebuild the
same questions from `facts.txt`.
"""

import numpy as np

SEED = 20250711

# ---- token space ------------------------------------------------------------
VOCAB = 512
PAD, Q, A, SEP = 0, 1, 2, 3
NOISE_BASE, N_NOISE = 4, 96          # background "prose" tokens
REL_BASE, N_REL = 100, 57            # 57 relation families == MMLU subjects
ENT_BASE, N_ENT = 160, 16            # subject/object entities (57*16 = 912 facts,
                                     # sized so ~1M-param models can memorize)

SEQ_LEN = 32
FACT_LEN = 6                         # [Q, s, r, A, o, SEP]

assert REL_BASE + N_REL <= ENT_BASE
assert ENT_BASE + N_ENT <= VOCAB


def fact_table(seed: int = SEED) -> np.ndarray:
    """(N_REL, N_ENT) int array: obj[r, s] = object *entity id* for relation r,
    subject entity s. Objects are a per-relation permutation of the entities so
    every relation has a uniform object marginal (no degenerate priors)."""
    rng = np.random.default_rng(seed)
    objs = np.empty((N_REL, N_ENT), dtype=np.int64)
    for r in range(N_REL):
        objs[r] = ENT_BASE + rng.permutation(N_ENT)
    return objs


def noise_chain(seed: int = SEED) -> np.ndarray:
    """Sparse bigram transition table over the noise vocabulary: each noise
    token has 4 plausible successors. Gives the 'prose' filler structure."""
    rng = np.random.default_rng(seed + 1)
    return rng.integers(0, N_NOISE, size=(N_NOISE, 4))


class CorpusSampler:
    """Streams training batches of token sequences (fact-heavy LM data)."""

    def __init__(self, seed: int = SEED, fact_frac: float = 0.9):
        # facts/noise-chain are ALWAYS the canonical SEED tables (shared with
        # the rust eval side); `seed` only varies the sampling stream.
        self.rng = np.random.default_rng(seed + 2)
        self.objs = fact_table(SEED)
        self.chain = noise_chain(SEED)
        self.fact_frac = fact_frac

    def _fact_segment(self) -> list:
        r = int(self.rng.integers(0, N_REL))
        s = int(self.rng.integers(0, N_ENT))
        o = int(self.objs[r, s])
        return [Q, ENT_BASE + s, REL_BASE + r, A, o, SEP]

    def _noise_segment(self, n: int) -> list:
        t = int(self.rng.integers(0, N_NOISE))
        out = []
        for _ in range(n):
            out.append(NOISE_BASE + t)
            t = int(self.chain[t, int(self.rng.integers(0, 4))])
        return out

    def sequence(self) -> np.ndarray:
        toks: list = []
        while len(toks) < SEQ_LEN:
            if self.rng.random() < self.fact_frac:
                toks.extend(self._fact_segment())
            else:
                toks.extend(self._noise_segment(FACT_LEN))
        return np.asarray(toks[:SEQ_LEN], dtype=np.int32)

    def batch(self, batch_size: int) -> np.ndarray:
        return np.stack([self.sequence() for _ in range(batch_size)])


def eval_questions(per_subject: int = 16, seed: int = SEED):
    """SynthMMLU: per relation ('subject'), `per_subject` questions.
    Returns list of (subject, context_tokens, choices[4], correct_idx).
    Deterministic; Rust rebuilds the identical set from facts.txt + seed."""
    rng = np.random.default_rng(seed + 3)
    objs = fact_table(seed)
    questions = []
    for r in range(N_REL):
        subjects = rng.permutation(N_ENT)[:per_subject]
        for s in subjects:
            s = int(s)
            correct = int(objs[r, s])
            distractors = set()
            while len(distractors) < 3:
                d = int(objs[r, int(rng.integers(0, N_ENT))])
                if d != correct:
                    distractors.add(d)
            choices = sorted(distractors) + [correct]
            rng.shuffle(choices)
            ctx = [Q, ENT_BASE + s, REL_BASE + r, A]
            questions.append((r, ctx, list(choices), choices.index(correct)))
    return questions


def write_facts(path: str, seed: int = SEED) -> None:
    """facts.txt: header line with constants, then `r s o` token-id triples."""
    objs = fact_table(seed)
    with open(path, "w") as f:
        f.write(
            f"# vocab={VOCAB} pad={PAD} q={Q} a={A} sep={SEP} "
            f"rel_base={REL_BASE} n_rel={N_REL} ent_base={ENT_BASE} "
            f"n_ent={N_ENT} seq_len={SEQ_LEN} seed={SEED}\n"
        )
        for r in range(N_REL):
            for s in range(N_ENT):
                f.write(f"{REL_BASE + r} {ENT_BASE + s} {int(objs[r, s])}\n")
