"""ETS — the EWQ Tensor Store binary format (writer side; reader lives in
rust/src/tensor/store.rs — keep the two in lockstep).

Layout (little-endian):
    magic  b"ETS1"
    u32    n_tensors
    per tensor:
        u16  name_len, name utf-8 bytes
        u8   dtype     (0=f32, 1=i8, 2=u8, 3=i32)
        u8   ndim
        u32  dims[ndim]
        u64  data_len (bytes)
        data
        u32  crc32(data)
"""

import struct
import zlib

import numpy as np

MAGIC = b"ETS1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int8): 1,
          np.dtype(np.uint8): 2, np.dtype(np.int32): 3}
DTYPES_INV = {0: np.float32, 1: np.int8, 2: np.uint8, 3: np.int32}


def write_ets(path: str, tensors: dict) -> None:
    """tensors: {name: np.ndarray} with dtype in DTYPES."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            data = arr.tobytes()
            f.write(struct.pack("<Q", len(data)))
            f.write(data)
            f.write(struct.pack("<I", zlib.crc32(data) & 0xFFFFFFFF))


def read_ets(path: str) -> dict:
    """Reader (used by pytest round-trip checks against the rust reader)."""
    out = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError("bad magic")
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<I", f.read(4))[0] for _ in range(nd)]
            (dl,) = struct.unpack("<Q", f.read(8))
            data = f.read(dl)
            (crc,) = struct.unpack("<I", f.read(4))
            if crc != (zlib.crc32(data) & 0xFFFFFFFF):
                raise ValueError(f"{name}: crc mismatch")
            out[name] = np.frombuffer(data, DTYPES_INV[dt]).reshape(dims).copy()
    return out
