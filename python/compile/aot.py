"""AOT driver: python runs ONCE here — train the flagship tiny models, save
weights (.ets) + schemas, and lower every HLO artifact the rust runtime
loads. After `make artifacts`, the rust binary is self-contained.

Interchange is HLO TEXT (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifact layout:
    artifacts/
      entropy.hlo.txt                  # shared softmax-entropy module (padded 65536)
      corpus/facts.txt                 # fact table the rust eval rebuilds SynthMMLU from
      models/<arch>/schema.txt         # key=value architecture schema
      models/<arch>/weights.ets        # trained fp32 parameters
      models/<arch>/train_log.txt      # loss curve (recorded in EXPERIMENTS.md)
      models/<arch>/{embed,head,block_raw,block_q8,block_q4,block_t2}.hlo.txt
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, ets
from .kernels.entropy import entropy_fixed
from .model import (ARCHS, EVAL_BATCH, Arch, block_q4, block_q8, block_raw,
                    block_t2, embed_fwd, head_fwd)
from .train import train

ENTROPY_PAD = 65536  # >= the largest block matrix (112*448 = 50176)

# Sized to land just past the fact-memorization transition (~step 1000 at
# batch 24 / fact_frac 0.97); staggered so flagship raw accuracies spread out
# like the paper's four models do.
TRAIN_STEPS = {"tl-llama": 1600, "tl-qwen": 1500, "tl-gemma": 1400, "tl-phi": 1300}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path: str, fn, *specs):
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i8(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int8)


def u8(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.uint8)


def i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def flatten_params(params, arch: Arch) -> dict:
    out = {"embed": params["embed"], "pos": params["pos"],
           "gf": params["gf"], "head": params["head"]}
    for i, p in enumerate(params["blocks"]):
        for k, v in p.items():
            out[f"blocks.{i}.{k}"] = v
    return {k: np.asarray(v) for k, v in out.items()}


def write_schema(path: str, arch: Arch) -> None:
    with open(path, "w") as f:
        f.write(f"name={arch.name}\n")
        f.write(f"n_blocks={arch.n_blocks}\n")
        f.write(f"d_model={arch.d_model}\n")
        f.write(f"n_heads={arch.n_heads}\n")
        f.write(f"d_ff={arch.d_ff}\n")
        f.write(f"vocab={arch.vocab}\n")
        f.write(f"seq_len={arch.seq_len}\n")
        f.write(f"eval_batch={EVAL_BATCH}\n")


# ---- per-arch lowering -------------------------------------------------------------
def lower_arch(outdir: str, arch: Arch) -> None:
    b, s, d, ff, v = EVAL_BATCH, arch.seq_len, arch.d_model, arch.d_ff, arch.vocab
    nh = arch.n_heads

    lower_to(os.path.join(outdir, "embed.hlo.txt"),
             lambda t, e, p: (embed_fwd(t, e, p),),
             i32(b, s), f32(v, d), f32(s, d))

    lower_to(os.path.join(outdir, "head.hlo.txt"),
             lambda x, g, h: (head_fwd(x, g, h),),
             f32(b, s, d), f32(d), f32(d, v))

    lower_to(os.path.join(outdir, "block_raw.hlo.txt"),
             lambda x, g1, wq, wk, wv, wo, g2, w1, w2: (block_raw(
                 x, {"g1": g1, "wq": wq, "wk": wk, "wv": wv, "wo": wo,
                     "g2": g2, "w1": w1, "w2": w2}, nh),),
             f32(b, s, d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
             f32(d), f32(d, ff), f32(ff, d))

    def qspecs(qdt, kdiv):
        # (q, s) pairs for wq wk wv wo (k=d) then w1 (k=d) then w2 (k=ff)
        sp = []
        for _ in range(4):
            sp += [qdt(d // kdiv, d), f32(d)]
        sp += [qdt(d // kdiv, ff), f32(ff)]
        sp += [qdt(ff // kdiv, d), f32(d)]
        return sp

    def qblock(fn):
        def wrapped(x, g1, g2, *qs_flat):
            names = ["wq", "wk", "wv", "wo", "w1", "w2"]
            qs = {n: (qs_flat[2 * i], qs_flat[2 * i + 1]) for i, n in enumerate(names)}
            return (fn(x, g1, g2, qs, nh),)
        return wrapped

    lower_to(os.path.join(outdir, "block_q8.hlo.txt"), qblock(block_q8),
             f32(b, s, d), f32(d), f32(d), *qspecs(i8, 1))
    lower_to(os.path.join(outdir, "block_q4.hlo.txt"), qblock(block_q4),
             f32(b, s, d), f32(d), f32(d), *qspecs(u8, 2))
    lower_to(os.path.join(outdir, "block_t2.hlo.txt"), qblock(block_t2),
             f32(b, s, d), f32(d), f32(d), *qspecs(u8, 4))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training run (CI / pytest smoke)")
    ap.add_argument("--arch", default=None, help="only this arch")
    args = ap.parse_args()

    out = os.path.abspath(args.out)
    os.makedirs(os.path.join(out, "corpus"), exist_ok=True)

    corpus.write_facts(os.path.join(out, "corpus", "facts.txt"))

    # shared entropy module
    lower_to(os.path.join(out, "entropy.hlo.txt"),
             lambda w: (entropy_fixed(w),), f32(ENTROPY_PAD))

    for arch in ARCHS:
        if args.arch and arch.name != args.arch:
            continue
        adir = os.path.join(out, "models", arch.name)
        os.makedirs(adir, exist_ok=True)
        write_schema(os.path.join(adir, "schema.txt"), arch)

        wpath = os.path.join(adir, "weights.ets")
        if not os.path.exists(wpath):
            steps = 30 if args.quick else TRAIN_STEPS[arch.name]
            log_lines = []

            def log(msg):
                print(msg, flush=True)
                log_lines.append(msg)

            params, _ = train(arch, steps=steps, log=log)
            ets.write_ets(wpath, flatten_params(params, arch))
            with open(os.path.join(adir, "train_log.txt"), "w") as f:
                f.write("\n".join(log_lines) + "\n")
        else:
            print(f"[{arch.name}] weights.ets exists, skipping training")

        lower_arch(adir, arch)
        print(f"[{arch.name}] artifacts written to {adir}")


if __name__ == "__main__":
    main()
