"""L1 Pallas kernel: streaming softmax-entropy of a flattened weight tensor.

TPU mental model: the tensor is streamed HBM->VMEM in (8,128)-aligned chunks;
each grid step reduces its chunk into a scalar accumulator that lives in the
output block (grid iterations are sequential on TPU, so the accumulator is
carried across steps — the Pallas analogue of the paper's single-core
streaming pass). Three passes: global max, partition Z, entropy sum.

Everything runs under interpret=True — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One chunk = 16 TPU sublane rows of 128 lanes.
CHUNK = 2048
NEG_PAD = -1e30  # padding value: exp(NEG_PAD - max) == 0, contributes nothing


def _max_kernel(w_ref, o_ref):
    i = pl.program_id(0)
    m = jnp.max(w_ref[...])

    @pl.when(i == 0)
    def _init():
        o_ref[0] = m

    @pl.when(i > 0)
    def _acc():
        o_ref[0] = jnp.maximum(o_ref[0], m)


def _sumexp_kernel(w_ref, m_ref, o_ref):
    i = pl.program_id(0)
    z = jnp.sum(jnp.exp(w_ref[...] - m_ref[0]))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = z

    @pl.when(i > 0)
    def _acc():
        o_ref[0] = o_ref[0] + z


def _plogp_kernel(w_ref, m_ref, z_ref, o_ref, *, eps: float):
    i = pl.program_id(0)
    p = jnp.exp(w_ref[...] - m_ref[0]) / z_ref[0]
    h = -jnp.sum(p * jnp.log(p + eps))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = h

    @pl.when(i > 0)
    def _acc():
        o_ref[0] = o_ref[0] + h


def _scalar_spec():
    # every grid step maps to the same (1,)-block: a carried accumulator
    return pl.BlockSpec((1,), lambda i: (0,))


def _reduce(kernel, grid, args):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))]
        + [_scalar_spec() for _ in args[1:]],
        out_specs=_scalar_spec(),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(*args)


def pad_to_chunks(w):
    """Flatten and pad with NEG_PAD to a CHUNK multiple."""
    w = jnp.ravel(w).astype(jnp.float32)
    n = w.shape[0]
    rem = (-n) % CHUNK
    if rem:
        w = jnp.concatenate([w, jnp.full((rem,), NEG_PAD, jnp.float32)])
    return w


def softmax_entropy_pallas(w, eps: float = 1e-12):
    """Pallas counterpart of ref.softmax_entropy. Accepts any shape/size."""
    w = pad_to_chunks(w)
    grid = (w.shape[0] // CHUNK,)
    m = _reduce(_max_kernel, grid, (w,))
    z = _reduce(_sumexp_kernel, grid, (w, m))
    h = _reduce(functools.partial(_plogp_kernel, eps=eps), grid, (w, m, z))
    return h[0]


def entropy_fixed(w, eps: float = 1e-12):
    """Fixed-size variant for AOT lowering: `w` is already padded (rust pads
    with NEG_PAD). Returns a (1,)-shaped tensor for a stable HLO signature."""
    grid = (w.shape[0] // CHUNK,)
    m = _reduce(_max_kernel, grid, (w,))
    z = _reduce(_sumexp_kernel, grid, (w, m))
    h = _reduce(functools.partial(_plogp_kernel, eps=eps), grid, (w, m, z))
    return h
