"""L1 Pallas kernels: fused dequantize->matmul — the weight-only-quantization
inference hot-spot (the paper's GPTQ-style "convert quantized weights to
float during the matmul" path, Section 1).

TPU mapping (DESIGN.md §Hardware-Adaptation): output is tiled (bm, bn) with
bm/bn MXU-friendly (128 when divisible); the packed weight tile is unpacked
and rescaled in VMEM registers immediately before feeding the MXU, so HBM
traffic is 1/4 (q8), 1/8 (q4) or 1/16 (t2) of the f32 baseline. The reduction
dimension k is carried whole per tile — model dims here (<=448) keep the
x-tile + w-tile VMEM footprint under 1 MiB (see EXPERIMENTS.md §Perf).

interpret=True everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(n: int, pref: int = 128) -> int:
    """Largest MXU-friendly tile that divides n (fall back to n itself)."""
    for cand in (pref, 64, 32, 16, 8):
        if n % cand == 0 and cand <= n:
            return cand
    return n


# ---- int8 ---------------------------------------------------------------------
def _mm_q8_kernel(x_ref, q_ref, s_ref, o_ref):
    w = q_ref[...].astype(jnp.float32) * s_ref[...][None, :]
    o_ref[...] = jnp.dot(x_ref[...], w)


def matmul_q8(x, q, s):
    """x[m,k] @ (q[k,n] i8 * s[n]) -> f32[m,n]"""
    m, k = x.shape
    _, n = q.shape
    bm, bn = _tile(m), _tile(n)
    return pl.pallas_call(
        _mm_q8_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, q, s)


# ---- int4 (two nibbles per byte along k) ---------------------------------------
def _mm_q4_kernel(x_ref, p_ref, s_ref, o_ref):
    p = p_ref[...].astype(jnp.int32)
    lo = ((p & 0xF) - 8).astype(jnp.float32)
    hi = (((p >> 4) & 0xF) - 8).astype(jnp.float32)
    s = s_ref[...][None, :]
    x = x_ref[...]
    # rows 0::2 of W multiply x columns 0::2 — split-x formulation avoids an
    # interleave/scatter in VMEM: x @ W = x[:,0::2] @ W[0::2] + x[:,1::2] @ W[1::2]
    o_ref[...] = jnp.dot(x[:, 0::2], lo * s) + jnp.dot(x[:, 1::2], hi * s)


def matmul_q4(x, packed, s):
    """x[m,k] @ dequant_q4(packed[k//2,n], s[n]) -> f32[m,n]"""
    m, k = x.shape
    k2, n = packed.shape
    assert k2 * 2 == k
    bm, bn = _tile(m), _tile(n)
    return pl.pallas_call(
        _mm_q4_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, s)


# ---- ternary 1.58-bit (four 2-bit codes per byte along k) ------------------------
def _mm_t2_kernel(x_ref, p_ref, s_ref, o_ref):
    p = p_ref[...].astype(jnp.int32)
    s = s_ref[...][None, :]
    x = x_ref[...]
    acc = jnp.dot(x[:, 0::4], ((p & 3) - 1).astype(jnp.float32) * s)
    acc += jnp.dot(x[:, 1::4], (((p >> 2) & 3) - 1).astype(jnp.float32) * s)
    acc += jnp.dot(x[:, 2::4], (((p >> 4) & 3) - 1).astype(jnp.float32) * s)
    acc += jnp.dot(x[:, 3::4], (((p >> 6) & 3) - 1).astype(jnp.float32) * s)
    o_ref[...] = acc


def matmul_t2(x, packed, s):
    """x[m,k] @ dequant_t2(packed[k//4,n], s[n]) -> f32[m,n]"""
    m, k = x.shape
    k4, n = packed.shape
    assert k4 * 4 == k
    bm, bn = _tile(m), _tile(n)
    return pl.pallas_call(
        _mm_t2_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k4, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, s)
