"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest suite checks kernels against, and the
same formulas the Rust `entropy`/`quant` modules mirror (cross-checked via
exported HLO in integration tests).
"""

import jax.numpy as jnp

EPS_DEFAULT = 1e-12


# ---- entropy (paper Section 3.1) --------------------------------------------
def softmax_entropy(w, eps: float = EPS_DEFAULT):
    """H = -sum_i p_i * log(p_i + eps), p = softmax(flatten(w)).

    Numerically stable via max-shift. `eps` is the paper's stability constant;
    we default it tiny (1e-12) because for n >= 1e4 parameters a large eps
    (the paper's illustrative 0.01) saturates log(p+eps) ~= log(eps) and
    washes out inter-block differences. Configurable everywhere.
    """
    w = jnp.ravel(w).astype(jnp.float32)
    m = jnp.max(w)
    e = jnp.exp(w - m)
    z = jnp.sum(e)
    p = e / z
    return -jnp.sum(p * jnp.log(p + eps))


def block_entropy(mats, eps: float = EPS_DEFAULT):
    """Weighted block entropy (paper eq. 3.2): size-weighted mean of H(W_i)."""
    num = 0.0
    den = 0.0
    for w in mats:
        n = w.size
        num = num + n * softmax_entropy(w, eps)
        den += n
    return num / den


# ---- quantization formats ----------------------------------------------------
# Per-output-column symmetric scales; packing layouts match rust/src/quant/.
def quantize_q8(w):
    """w[k,n] -> (q i8[k,n], scale f32[n]); q = round(w/s) clamp [-127,127]."""
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-12) / 127.0
    q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def dequant_q8(q, s):
    return q.astype(jnp.float32) * s[None, :]


def quantize_q4(w):
    """w[k,n] -> (packed u8[k//2,n], scale f32[n]).

    q = round(w/s) clamp [-7,7], stored biased (q+8 in [1,15]), two per byte
    along k: byte = lo | hi<<4 with lo = row 2i, hi = row 2i+1.
    """
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-12) / 7.0
    q = jnp.clip(jnp.round(w / s), -7, 7).astype(jnp.int32) + 8
    lo = q[0::2, :]
    hi = q[1::2, :]
    packed = (lo | (hi << 4)).astype(jnp.uint8)
    return packed, s.astype(jnp.float32)


def dequant_q4(packed, s):
    p = packed.astype(jnp.int32)
    lo = (p & 0xF) - 8
    hi = ((p >> 4) & 0xF) - 8
    k2, n = packed.shape
    q = jnp.zeros((k2 * 2, n), dtype=jnp.int32)
    q = q.at[0::2, :].set(lo)
    q = q.at[1::2, :].set(hi)
    return q.astype(jnp.float32) * s[None, :]


def quantize_t2(w):
    """Ternary 1.58-bit (BitNet-style): scale = mean|w| per column,
    q = clamp(round(w/s), -1, 1); code = q+1 in {0,1,2}; 4 codes per byte
    along k: byte = c0 | c1<<2 | c2<<4 | c3<<6."""
    s = jnp.maximum(jnp.mean(jnp.abs(w), axis=0), 1e-12)
    q = jnp.clip(jnp.round(w / s), -1, 1).astype(jnp.int32)
    c = q + 1
    c0, c1, c2, c3 = c[0::4, :], c[1::4, :], c[2::4, :], c[3::4, :]
    packed = (c0 | (c1 << 2) | (c2 << 4) | (c3 << 6)).astype(jnp.uint8)
    return packed, s.astype(jnp.float32)


def dequant_t2(packed, s):
    p = packed.astype(jnp.int32)
    k4, n = packed.shape
    q = jnp.zeros((k4 * 4, n), dtype=jnp.int32)
    q = q.at[0::4, :].set((p & 3) - 1)
    q = q.at[1::4, :].set(((p >> 2) & 3) - 1)
    q = q.at[2::4, :].set(((p >> 4) & 3) - 1)
    q = q.at[3::4, :].set(((p >> 6) & 3) - 1)
    return q.astype(jnp.float32) * s[None, :]


# ---- fused dequant-matmul references ------------------------------------------
def matmul_dequant_q8(x, q, s):
    """x[m,k] @ dequant_q8(q,s)[k,n] -> [m,n]"""
    return x @ dequant_q8(q, s)


def matmul_dequant_q4(x, packed, s):
    return x @ dequant_q4(packed, s)


def matmul_dequant_t2(x, packed, s):
    return x @ dequant_t2(packed, s)
