"""L2: JAX transformer model — forward passes (raw + quantized block variants
that call the L1 Pallas kernels), parameter init, and training loss.

Architecture: pre-RMSNorm decoder blocks (LLaMA-style, no biases):
    h = x + Attn(rms(x, g1); Wq, Wk, Wv, Wo)
    y = h + W2 @ gelu(W1 @ rms(h, g2))
Embedding and LM head stay fp32 (the paper quantizes transformer blocks'
Linear/Embedding layers; embed/head sit outside the block pool, §6.2).

Per-block quantizable matrices (the EWQ unit of analysis):
    wq, wk, wv, wo [d,d], w1 [d,ff], w2 [ff,d]   — 6 matrices
plus fp32 RMSNorm gains g1, g2 (never quantized; negligible size).
"""

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quant as kq
from .kernels import ref as kr


class Arch(NamedTuple):
    name: str
    n_blocks: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    seq_len: int


# The flagship zoo: four families mirroring the paper's evaluated models in
# depth/width ratios (Llama: deep+wide, Qwen: wider, Gemma: deepest, Phi:
# smallest). Tiny absolute sizes — see DESIGN.md §2 substitutions.
ARCHS = [
    Arch("tl-llama", n_blocks=8, d_model=96, n_heads=4, d_ff=384, vocab=512, seq_len=32),
    Arch("tl-qwen", n_blocks=7, d_model=112, n_heads=4, d_ff=448, vocab=512, seq_len=32),
    Arch("tl-gemma", n_blocks=10, d_model=80, n_heads=4, d_ff=320, vocab=512, seq_len=32),
    Arch("tl-phi", n_blocks=8, d_model=64, n_heads=4, d_ff=256, vocab=512, seq_len=32),
]

EVAL_BATCH = 8  # static batch dim of the AOT-lowered artifacts

BLOCK_MATS = ["wq", "wk", "wv", "wo", "w1", "w2"]


# ---- init ----------------------------------------------------------------------
def init_params(arch: Arch, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    d, ff, v = arch.d_model, arch.d_ff, arch.vocab

    def dense(k, n):
        return rng.normal(0.0, 1.0 / math.sqrt(k), size=(k, n)).astype(np.float32)

    params = {
        "embed": rng.normal(0.0, 0.02, size=(v, d)).astype(np.float32),
        "pos": rng.normal(0.0, 0.02, size=(arch.seq_len, d)).astype(np.float32),
        "gf": np.ones((d,), np.float32),
        "head": dense(d, v),
        "blocks": [],
    }
    for _ in range(arch.n_blocks):
        params["blocks"].append(
            {
                "g1": np.ones((d,), np.float32),
                "wq": dense(d, d),
                "wk": dense(d, d),
                "wv": dense(d, d),
                "wo": dense(d, d),
                "g2": np.ones((d,), np.float32),
                "w1": dense(d, ff),
                "w2": dense(ff, d),
            }
        )
    return jax.tree_util.tree_map(jnp.asarray, params)


# ---- building blocks -------------------------------------------------------------
def rms(x, g, eps=1e-6):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def attention(q, k, v, n_heads):
    """q,k,v: [B,S,d] -> [B,S,d], causal multi-head attention (plain jnp —
    attention is activation-only and never weight-quantized)."""
    b, s, d = q.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, s, d)


def _block_core(x, n_heads, g1, g2, mm):
    """Shared block skeleton; `mm(x2d, name)` performs the named matmul so the
    same code path serves raw fp32 and every quantized variant."""
    b, s, d = x.shape

    def flat(t):
        return t.reshape(b * s, -1)

    def unflat(t):
        return t.reshape(b, s, -1)

    xn = rms(x, g1)
    q = unflat(mm(flat(xn), "wq"))
    k = unflat(mm(flat(xn), "wk"))
    v = unflat(mm(flat(xn), "wv"))
    a = attention(q, k, v, n_heads)
    x = x + unflat(mm(flat(a), "wo"))

    hn = rms(x, g2)
    h1 = jax.nn.gelu(mm(flat(hn), "w1"))
    return x + unflat(mm(h1, "w2"))


def block_raw(x, p, n_heads):
    return _block_core(x, n_heads, p["g1"], p["g2"], lambda t, n: t @ p[n])


def block_q8(x, g1, g2, qs, n_heads):
    """qs: {name: (q i8, scale f32)} for the six matrices. Pallas fused path."""
    return _block_core(
        x, n_heads, g1, g2, lambda t, n: kq.matmul_q8(t, qs[n][0], qs[n][1])
    )


def block_q4(x, g1, g2, qs, n_heads):
    return _block_core(
        x, n_heads, g1, g2, lambda t, n: kq.matmul_q4(t, qs[n][0], qs[n][1])
    )


def block_t2(x, g1, g2, qs, n_heads):
    return _block_core(
        x, n_heads, g1, g2, lambda t, n: kq.matmul_t2(t, qs[n][0], qs[n][1])
    )


def embed_fwd(tokens, embed, pos):
    return embed[tokens] + pos[None, : tokens.shape[1], :]


def head_fwd(x, gf, head):
    return rms(x, gf) @ head


# ---- whole-model (training / reference eval) ---------------------------------------
def model_fwd(params, tokens, n_heads):
    x = embed_fwd(tokens, params["embed"], params["pos"])
    for p in params["blocks"]:
        x = block_raw(x, p, n_heads)
    return head_fwd(x, params["gf"], params["head"])


def loss_fn(params, tokens, n_heads):
    """Next-token cross-entropy over the full sequence (PAD positions excluded).

    Fact-answer positions (the token right after the `A` marker) are weighted
    4x: they are the retrieval signal SynthMMLU evaluates, everything else is
    background prose.
    """
    logits = model_fwd(params, tokens[:, :-1], n_heads)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != 0).astype(jnp.float32)
    answer = (tokens[:, :-1] == 2).astype(jnp.float32)  # prev token == A
    w = mask * (1.0 + 4.0 * answer)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---- quantization of a block's parameter dict ---------------------------------------
def quantize_block(p, fmt: str):
    """Return (g1, g2, {name: (q, s)}) using the ref (= rust) format `fmt`."""
    fn = {"q8": kr.quantize_q8, "q4": kr.quantize_q4, "t2": kr.quantize_t2}[fmt]
    qs = {n: fn(p[n]) for n in BLOCK_MATS}
    return p["g1"], p["g2"], qs
