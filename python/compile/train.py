"""Build-time training of the flagship tiny models on the synthetic fact
corpus. Hand-rolled Adam (optax unavailable offline); jitted step; cosine LR
with warmup. Python runs ONCE — never on the request path.
"""

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import Arch, init_params, loss_fn


def adam_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return jax.tree_util.tree_map(zeros, params), jax.tree_util.tree_map(zeros, params)


def make_step(arch: Arch, lr_max: float, steps: int, warmup: int):
    b1, b2, eps = 0.9, 0.999, 1e-8

    def lr_at(step):
        warm = lr_max * (step + 1) / warmup
        prog = jnp.clip((step - warmup) / max(steps - warmup, 1), 0.0, 1.0)
        cos = lr_max * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    @jax.jit
    def step(params, m, v, tokens, i):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, arch.n_heads)
        lr = lr_at(i)
        t = i.astype(jnp.float32) + 1.0

        def upd(p, g, mm, vv):
            mm = b1 * mm + (1 - b1) * g
            vv = b2 * vv + (1 - b2) * g * g
            mhat = mm / (1 - b1**t)
            vhat = vv / (1 - b2**t)
            return p - lr * mhat / (jnp.sqrt(vhat) + eps), mm, vv

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(m)
        flat_v = jax.tree_util.tree_leaves(v)
        out = [upd(p, g, mm, vv) for p, g, mm, vv in zip(flat_p, flat_g, flat_m, flat_v)]
        params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return params, m, v, loss

    return step


def train(arch: Arch, steps: int, batch: int = 24, lr: float = 2e-3, seed: int = 7,
          fact_frac: float = 0.97, log=print):
    params = init_params(arch, seed)
    m, v = adam_init(params)
    step = make_step(arch, lr, steps, warmup=max(20, steps // 20))
    sampler = corpus.CorpusSampler(seed=corpus.SEED + seed, fact_frac=fact_frac)
    t0 = time.time()
    losses = []
    for i in range(steps):
        tokens = jnp.asarray(sampler.batch(batch))
        params, m, v, loss = step(params, m, v, tokens, jnp.asarray(i))
        if i % 50 == 0 or i == steps - 1:
            losses.append((i, float(loss)))
            log(f"[{arch.name}] step {i:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    return params, losses
