//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! `Bench::run` warms up, then samples wall-clock over adaptive iteration
//! counts and reports min/median/mean/p95 per iteration. Used by every
//! `benches/bench_*.rs` target (`cargo bench`, harness = false).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Sample {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }

    /// How many times faster this sample is than `baseline` (mean over mean).
    pub fn speedup_over(&self, baseline: &Sample) -> f64 {
        baseline.mean.as_secs_f64() / self.mean.as_secs_f64().max(1e-12)
    }
}

/// Print one parallel-vs-serial comparison line (used by the bench targets'
/// comparison groups).
pub fn report_speedup(label: &str, serial: &Sample, parallel: &Sample) {
    println!(
        "    => {label}: {:.2}x speedup (serial {:?} -> parallel {:?})",
        parallel.speedup_over(serial),
        serial.mean,
        parallel.mean
    );
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} min {:>10?}  med {:>10?}  mean {:>10?}  p95 {:>10?}  ({} iters)",
            self.name, self.min, self.median, self.mean, self.p95, self.iters
        )
    }
}

pub struct Bench {
    /// Target total measurement time per benchmark.
    pub budget: Duration,
    /// Number of timed samples.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { budget: Duration::from_secs(2), samples: 20 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { budget: Duration::from_millis(400), samples: 8 }
    }

    /// Measure `f`, printing and returning the sample.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Sample {
        // warmup + calibration: how many iters fit in budget/samples?
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = self.budget / self.samples as u32;
        let iters = (per_sample.as_secs_f64() / once.as_secs_f64()).ceil().max(1.0) as u64;

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed() / iters as u32);
        }
        times.sort();
        let sample = Sample {
            name: name.to_string(),
            iters: iters * self.samples as u64,
            min: times[0],
            median: times[times.len() / 2],
            mean: times.iter().sum::<Duration>() / times.len() as u32,
            p95: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
        };
        println!("{sample}");
        sample
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench { budget: Duration::from_millis(50), samples: 4 };
        let s = b.run("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.p95);
        assert!(s.iters >= 4);
    }

    #[test]
    fn speedup_ratio() {
        let mk = |us: u64| Sample {
            name: "s".into(),
            iters: 1,
            min: Duration::from_micros(us),
            median: Duration::from_micros(us),
            mean: Duration::from_micros(us),
            p95: Duration::from_micros(us),
        };
        let serial = mk(400);
        let parallel = mk(100);
        assert!((parallel.speedup_over(&serial) - 4.0).abs() < 1e-9);
        assert!((serial.speedup_over(&serial) - 1.0).abs() < 1e-9);
        report_speedup("ratio", &serial, &parallel); // must not panic
    }

    #[test]
    fn throughput_positive() {
        let b = Bench { budget: Duration::from_millis(30), samples: 3 };
        let s = b.run("tp", || {
            black_box(vec![0u8; 1024]);
        });
        assert!(s.throughput(1024.0) > 0.0);
    }
}
