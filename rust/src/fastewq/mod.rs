//! FastEWQ (paper Section 4): an O(1) classifier that predicts a block's
//! quantization suitability from schema metadata alone — `num_parameters`,
//! `exec_index`, `num_blocks` — eliminating the O(n) weight scan.

use std::path::Path;

use anyhow::{Context, Result};

use crate::ewq::{analyze_blocks, decide, EwqConfig};
use crate::ml::{Classifier, RandomForest, StandardScaler};
use crate::par::Pool;
use crate::quant::Precision;
use crate::zoo::gen::{gen_block_mats, synthetic_archs, SyntheticArch};
use crate::zoo::{ModelDir, Schema};

/// Feature order used everywhere (paper Fig. 5): num_parameters, exec_index,
/// num_blocks.
pub const FEATURES: [&str; 3] = ["num_parameters", "exec_index", "num_blocks"];

/// One row of the model dataset (paper Table 2).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetRow {
    pub model_name: String,
    pub num_blocks: usize,
    pub exec_index: usize,
    pub num_parameters: usize,
    pub quantization_type: Precision,
    pub quantized: bool,
}

impl DatasetRow {
    pub fn features(&self) -> Vec<f64> {
        vec![self.num_parameters as f64, self.exec_index as f64, self.num_blocks as f64]
    }

    pub fn label(&self) -> u8 {
        u8::from(self.quantized)
    }
}

/// Convert rows to (X, y).
pub fn rows_to_xy(rows: &[DatasetRow]) -> (Vec<Vec<f64>>, Vec<u8>) {
    (rows.iter().map(|r| r.features()).collect(), rows.iter().map(|r| r.label()).collect())
}

/// Build the FastEWQ training dataset by running the FULL EWQ analysis over
/// synthetic zoo architectures (and optionally the trained flagships),
/// labelling every block with its decision — the offline stand-in for the
/// paper's 700-row HF survey.
pub fn build_dataset(
    target_rows: usize,
    seed: u64,
    flagships: &[&ModelDir],
    cfg: &EwqConfig,
) -> Vec<DatasetRow> {
    build_dataset_pooled(target_rows, seed, flagships, cfg, &Pool::serial())
}

fn rows_for_model(name: &str, analysis: &crate::ewq::ModelAnalysis, cfg: &EwqConfig) -> Vec<DatasetRow> {
    let plan = decide(analysis, cfg);
    analysis
        .blocks
        .iter()
        .zip(&plan.assignments)
        .map(|(b, &p)| DatasetRow {
            model_name: name.to_string(),
            num_blocks: analysis.blocks.len(),
            exec_index: b.exec_index,
            num_parameters: b.params,
            quantization_type: p,
            quantized: p != Precision::Raw,
        })
        .collect()
}

/// `build_dataset` with one analysis task per model fanned out over `pool`.
/// The arch sweep is bounded up front from the schemas alone (cheap — no
/// weights needed), so the parallel build analyzes exactly the same model
/// set as the serial early-exit loop and returns identical rows, while
/// keeping at most one generated model per worker in memory.
pub fn build_dataset_pooled(
    target_rows: usize,
    seed: u64,
    flagships: &[&ModelDir],
    cfg: &EwqConfig,
    pool: &Pool,
) -> Vec<DatasetRow> {
    let mut rows = Vec::with_capacity(target_rows + 64);

    let flagship_rows = pool.par_map_indexed(flagships, |_, m| {
        rows_for_model(&m.schema.name, &crate::ewq::analyze_model(m, cfg), cfg)
    });
    rows.extend(flagship_rows.into_iter().flatten());

    // synthetic sweep: the serial loop stops once cumulative rows reach the
    // target; the prefix it would process is known from the schemas
    let archs = synthetic_archs(64, seed);
    let mut need = target_rows.saturating_sub(rows.len());
    let mut take = 0usize;
    for arch in &archs {
        if need == 0 {
            break;
        }
        take += 1;
        need = need.saturating_sub(arch.schema.n_blocks);
    }

    let arch_rows = pool.par_map_indexed(&archs[..take], |_, arch: &SyntheticArch| {
        let mats: Vec<Vec<crate::tensor::Tensor>> =
            (0..arch.schema.n_blocks).map(|b| gen_block_mats(arch, b)).collect();
        let analysis =
            analyze_blocks(&arch.schema.name, arch.schema.n_blocks, &arch.schema, cfg.eps, |i| {
                mats[i].iter().map(|t| t.data.as_slice()).collect()
            });
        rows_for_model(&arch.schema.name, &analysis, cfg)
    });
    rows.extend(arch_rows.into_iter().flatten());
    rows.truncate(target_rows);
    rows
}

// ---- CSV cache (also feeds Figs. 2–4) ------------------------------------------
pub fn rows_to_csv(rows: &[DatasetRow]) -> String {
    let mut s =
        String::from("model_name,num_blocks,exec_index,num_parameters,quantization_type,quantized\n");
    for r in rows {
        s.push_str(&format!(
            "{},{},{},{},{},{}\n",
            r.model_name,
            r.num_blocks,
            r.exec_index,
            r.num_parameters,
            r.quantization_type.label(),
            r.quantized as u8
        ));
    }
    s
}

pub fn rows_from_csv(text: &str) -> Result<Vec<DatasetRow>> {
    let mut rows = Vec::new();
    for (i, line) in text.lines().enumerate().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 6 {
            anyhow::bail!("line {i}: expected 6 fields");
        }
        let prec = match f[4] {
            "raw" => Precision::Raw,
            "8bit" => Precision::Q8,
            "4bit" => Precision::Q4,
            "3bit" => Precision::Q3,
            "1.58bit" => Precision::T2,
            other => anyhow::bail!("line {i}: bad precision {other}"),
        };
        rows.push(DatasetRow {
            model_name: f[0].to_string(),
            num_blocks: f[1].parse()?,
            exec_index: f[2].parse()?,
            num_parameters: f[3].parse()?,
            quantization_type: prec,
            quantized: f[5].trim() == "1",
        });
    }
    Ok(rows)
}

/// Load the dataset from the artifacts cache or build + cache it.
pub fn load_or_build_dataset(
    artifacts: &Path,
    target_rows: usize,
    seed: u64,
    flagships: &[&ModelDir],
    cfg: &EwqConfig,
) -> Result<Vec<DatasetRow>> {
    load_or_build_dataset_pooled(artifacts, target_rows, seed, flagships, cfg, &Pool::serial())
}

/// `load_or_build_dataset` building cache misses on `pool` (identical rows
/// and cache bytes for any worker count).
pub fn load_or_build_dataset_pooled(
    artifacts: &Path,
    target_rows: usize,
    seed: u64,
    flagships: &[&ModelDir],
    cfg: &EwqConfig,
    pool: &Pool,
) -> Result<Vec<DatasetRow>> {
    let cache = artifacts.join("fastewq_dataset.csv");
    if cache.exists() {
        let rows = rows_from_csv(&std::fs::read_to_string(&cache)?)?;
        if rows.len() == target_rows {
            return Ok(rows);
        }
    }
    let rows = build_dataset_pooled(target_rows, seed, flagships, cfg, pool);
    std::fs::write(&cache, rows_to_csv(&rows))?;
    Ok(rows)
}

/// The trained FastEWQ classifier: StandardScaler + random forest.
#[derive(Clone, Debug)]
pub struct FastEwq {
    pub scaler: StandardScaler,
    pub forest: RandomForest,
}

impl FastEwq {
    /// Train on rows (paper: random forest, 80% accuracy on a 70:30 split;
    /// or "overfitted" on 100% of the data for the centralized variant).
    pub fn train(rows: &[DatasetRow], n_trees: usize, max_depth: usize, seed: u64) -> Self {
        let (x, y) = rows_to_xy(rows);
        let (scaler, xs) = StandardScaler::fit_transform(&x);
        let mut forest = RandomForest::new(n_trees, max_depth, seed);
        forest.fit(&xs, &y);
        Self { scaler, forest }
    }

    /// O(1) per-block decision from schema metadata only.
    pub fn classify_block(&self, schema: &Schema, block: usize) -> bool {
        let row = vec![
            schema.block_params() as f64,
            schema.exec_index(block) as f64,
            schema.n_blocks as f64,
        ];
        self.forest.predict(&self.scaler.transform_row(&row)) == 1
    }

    /// Whole-model selection mask.
    pub fn classify_model(&self, schema: &Schema) -> Vec<bool> {
        (0..schema.n_blocks).map(|b| self.classify_block(schema, b)).collect()
    }

    // ---- persistence: scaler header + forest body -------------------------------
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut s = String::from("FASTEWQ1\n");
        s.push_str(&format!(
            "mean {}\n",
            self.scaler.mean.iter().map(|v| format!("{v:.17}")).collect::<Vec<_>>().join(" ")
        ));
        s.push_str(&format!(
            "std {}\n",
            self.scaler.std.iter().map(|v| format!("{v:.17}")).collect::<Vec<_>>().join(" ")
        ));
        s.push_str(&self.forest.serialize());
        std::fs::write(path, s)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.splitn(4, '\n');
        if lines.next() != Some("FASTEWQ1") {
            anyhow::bail!("bad FastEWQ magic");
        }
        let parse_vec = |line: &str, tag: &str| -> Result<Vec<f64>> {
            line.strip_prefix(tag)
                .with_context(|| format!("missing {tag}"))?
                .split_whitespace()
                .map(|v| Ok(v.parse()?))
                .collect()
        };
        let mean = parse_vec(lines.next().context("missing mean")?, "mean ")?;
        let std = parse_vec(lines.next().context("missing std")?, "std ")?;
        let forest = RandomForest::deserialize(lines.next().context("missing forest")?)?;
        Ok(Self { scaler: StandardScaler { mean, std }, forest })
    }

    /// Best-effort load for optional classifier artifacts (the serving
    /// requant controller): a missing file is a normal deployment state and
    /// returns `None` silently; an unreadable or corrupt file is warned
    /// about (it names a real artifact that failed) and also returns `None`
    /// so serving starts with the conservative all-blocks-eligible policy
    /// instead of refusing to boot.
    pub fn load_optional(path: &Path) -> Option<Self> {
        if !path.exists() {
            return None;
        }
        match Self::load(path) {
            Ok(c) => Some(c),
            Err(e) => {
                eprintln!("fastewq: ignoring classifier at {}: {e:#}", path.display());
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::{predict_all, train_test_split};

    fn dataset() -> Vec<DatasetRow> {
        build_dataset(700, 2025, &[], &EwqConfig::default())
    }

    #[test]
    fn dataset_has_paper_shape() {
        let rows = dataset();
        assert_eq!(rows.len(), 700);
        let quantized = rows.iter().filter(|r| r.quantized).count();
        let frac = quantized as f64 / rows.len() as f64;
        // paper Fig. 4: 42% quantized / 58% raw — ours should be in the band
        assert!((0.25..0.60).contains(&frac), "quantized frac {frac}");
        // 4-bit is a small minority (paper: 7%)
        let q4 =
            rows.iter().filter(|r| r.quantization_type == Precision::Q4).count() as f64 / 700.0;
        assert!(q4 < 0.30, "q4 frac {q4}");
        // exec_index starts at 2
        assert!(rows.iter().all(|r| r.exec_index >= 2));
        assert!(rows.iter().all(|r| r.exec_index <= r.num_blocks + 1));
    }

    #[test]
    fn pooled_dataset_matches_serial_exactly() {
        let cfg = EwqConfig::default();
        let serial = build_dataset(300, 2025, &[], &cfg);
        for workers in [2usize, 4] {
            let pooled = build_dataset_pooled(300, 2025, &[], &cfg, &Pool::new(workers));
            assert_eq!(serial, pooled, "workers={workers}");
        }
    }

    #[test]
    fn csv_roundtrip() {
        let rows = build_dataset(60, 7, &[], &EwqConfig::default());
        let csv = rows_to_csv(&rows);
        let back = rows_from_csv(&csv).unwrap();
        assert_eq!(rows, back);
    }

    #[test]
    fn forest_beats_chance_on_split() {
        let rows = dataset();
        let (x, y) = rows_to_xy(&rows);
        let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.3, 42);
        let (scaler, xtr_s) = StandardScaler::fit_transform(&xtr);
        let xte_s = scaler.transform(&xte);
        let mut rf = RandomForest::new(120, 8, 1);
        rf.fit(&xtr_s, &ytr);
        let pred = predict_all(&rf, &xte_s);
        let acc =
            pred.iter().zip(&yte).filter(|(a, b)| a == b).count() as f64 / yte.len() as f64;
        assert!(acc > 0.70, "forest accuracy {acc} (paper: 0.80)");
    }

    #[test]
    fn save_load_preserves_decisions() {
        let rows = build_dataset(200, 9, &[], &EwqConfig::default());
        let fe = FastEwq::train(&rows, 30, 6, 3);
        let dir = std::env::temp_dir().join("ewq_fastewq_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("clf.fewq");
        fe.save(&p).unwrap();
        let fe2 = FastEwq::load(&p).unwrap();
        let schema = crate::zoo::gen::synthetic_archs(1, 77)[0].schema.clone();
        assert_eq!(fe.classify_model(&schema), fe2.classify_model(&schema));
    }

    #[test]
    fn load_optional_tolerates_missing_and_corrupt_artifacts() {
        let dir = std::env::temp_dir().join("ewq_fastewq_optional_test");
        std::fs::create_dir_all(&dir).unwrap();
        // missing: a normal deployment state, silently None
        assert!(FastEwq::load_optional(&dir.join("nope.fewq")).is_none());
        // corrupt: warned about, still None — serving must not refuse to boot
        let bad = dir.join("bad.fewq");
        std::fs::write(&bad, "NOT_A_CLASSIFIER\n").unwrap();
        assert!(FastEwq::load_optional(&bad).is_none());
        // intact: decisions identical to a plain load
        let rows = build_dataset(200, 9, &[], &EwqConfig::default());
        let fe = FastEwq::train(&rows, 30, 6, 3);
        let good = dir.join("good.fewq");
        fe.save(&good).unwrap();
        let fe2 = FastEwq::load_optional(&good).expect("intact artifact loads");
        let schema = crate::zoo::gen::synthetic_archs(1, 77)[0].schema.clone();
        assert_eq!(fe.classify_model(&schema), fe2.classify_model(&schema));
    }

    #[test]
    fn classify_is_deterministic_and_total() {
        let rows = build_dataset(200, 11, &[], &EwqConfig::default());
        let fe = FastEwq::train(&rows, 30, 6, 5);
        let schema = crate::zoo::gen::synthetic_archs(3, 13)[2].schema.clone();
        let a = fe.classify_model(&schema);
        let b = fe.classify_model(&schema);
        assert_eq!(a, b);
        assert_eq!(a.len(), schema.n_blocks);
    }
}
