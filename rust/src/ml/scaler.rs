//! StandardScaler (paper §4.2): z = (x − μ) / σ per feature, fitted on the
//! training split only and applied to both splits.

#[derive(Clone, Debug, Default)]
pub struct StandardScaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl StandardScaler {
    pub fn fit(x: &[Vec<f64>]) -> Self {
        assert!(!x.is_empty());
        let d = x[0].len();
        let n = x.len() as f64;
        let mut mean = vec![0.0; d];
        for row in x {
            for (m, &v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; d];
        for row in x {
            for j in 0..d {
                let c = row[j] - mean[j];
                var[j] += c * c;
            }
        }
        let std = var.into_iter().map(|v| (v / n).sqrt().max(1e-12)).collect();
        Self { mean, std }
    }

    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(&v, (&m, &s))| (v - m) / s)
            .collect()
    }

    pub fn transform(&self, x: &[Vec<f64>]) -> Vec<Vec<f64>> {
        x.iter().map(|r| self.transform_row(r)).collect()
    }

    pub fn fit_transform(x: &[Vec<f64>]) -> (Self, Vec<Vec<f64>>) {
        let s = Self::fit(x);
        let t = s.transform(x);
        (s, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mean_unit_std() {
        let x = vec![vec![1.0, 10.0], vec![2.0, 20.0], vec![3.0, 30.0]];
        let (_, t) = StandardScaler::fit_transform(&x);
        for j in 0..2 {
            let m: f64 = t.iter().map(|r| r[j]).sum::<f64>() / 3.0;
            let v: f64 = t.iter().map(|r| (r[j] - m) * (r[j] - m)).sum::<f64>() / 3.0;
            assert!(m.abs() < 1e-12);
            assert!((v - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn constant_feature_does_not_blow_up() {
        let x = vec![vec![5.0], vec![5.0], vec![5.0]];
        let (s, t) = StandardScaler::fit_transform(&x);
        assert!(t.iter().all(|r| r[0].is_finite() && r[0].abs() < 1e-6));
        assert!(s.std[0] > 0.0);
    }

    #[test]
    fn transform_uses_train_statistics() {
        let train = vec![vec![0.0], vec![2.0]];
        let s = StandardScaler::fit(&train);
        let out = s.transform_row(&[4.0]);
        // mean 1, std 1 -> (4-1)/1 = 3
        assert!((out[0] - 3.0).abs() < 1e-12);
    }
}
