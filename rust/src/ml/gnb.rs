//! Gaussian naive Bayes — per-class feature means/variances, independent
//! likelihoods. Deliberately the weakest of the line-up on correlated
//! features (the paper's Table 3 shows exactly this failure mode).

use super::Classifier;

#[derive(Clone, Debug, Default)]
pub struct GaussianNb {
    /// [class][feature]
    mean: [Vec<f64>; 2],
    var: [Vec<f64>; 2],
    log_prior: [f64; 2],
    fitted: bool,
}

impl Classifier for GaussianNb {
    fn name(&self) -> &'static str {
        "Gaussian naive Bayes"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        let d = x[0].len();
        for c in 0..2 {
            let rows: Vec<&Vec<f64>> =
                x.iter().zip(y).filter(|(_, &t)| t as usize == c).map(|(r, _)| r).collect();
            let n = rows.len().max(1) as f64;
            let mut mean = vec![0.0; d];
            for r in &rows {
                for (m, &v) in mean.iter_mut().zip(r.iter()) {
                    *m += v;
                }
            }
            for m in &mut mean {
                *m /= n;
            }
            let mut var = vec![0.0; d];
            for r in &rows {
                for j in 0..d {
                    let c = r[j] - mean[j];
                    var[j] += c * c;
                }
            }
            for v in &mut var {
                *v = (*v / n).max(1e-9);
            }
            self.mean[c] = mean;
            self.var[c] = var;
            self.log_prior[c] = (rows.len().max(1) as f64 / x.len() as f64).ln();
        }
        self.fitted = true;
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        assert!(self.fitted, "GaussianNb not fitted");
        let loglik = |c: usize| -> f64 {
            let mut ll = self.log_prior[c];
            for j in 0..row.len() {
                let m = self.mean[c][j];
                let v = self.var[c][j];
                ll += -0.5 * ((2.0 * std::f64::consts::PI * v).ln() + (row[j] - m) * (row[j] - m) / v);
            }
            ll
        };
        let l0 = loglik(0);
        let l1 = loglik(1);
        let m = l0.max(l1);
        let e0 = (l0 - m).exp();
        let e1 = (l1 - m).exp();
        e1 / (e0 + e1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn separates_gaussian_clusters() {
        let mut r = Xoshiro256pp::new(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..200 {
            let c = (i % 2) as u8;
            let mu = if c == 0 { -2.0 } else { 2.0 };
            x.push(vec![mu + r.normal() * 0.5]);
            y.push(c);
        }
        let mut m = GaussianNb::default();
        m.fit(&x, &y);
        assert_eq!(m.predict(&[-2.0]), 0);
        assert_eq!(m.predict(&[2.0]), 1);
        assert!(m.predict_proba(&[3.0]) > 0.99);
    }

    #[test]
    fn respects_class_prior() {
        // 90% of mass in class 0; ambiguous point should lean class 0
        let mut x = vec![vec![0.0]; 90];
        x.extend(vec![vec![0.5]; 10]);
        let mut y = vec![0u8; 90];
        y.extend(vec![1u8; 10]);
        let mut m = GaussianNb::default();
        m.fit(&x, &y);
        assert!(m.predict_proba(&[0.25]) < 0.5);
    }

    #[test]
    fn zero_variance_feature_is_guarded() {
        let x = vec![vec![1.0, 0.0], vec![1.0, 1.0], vec![1.0, 2.0], vec![1.0, 3.0]];
        let y = vec![0, 0, 1, 1];
        let mut m = GaussianNb::default();
        m.fit(&x, &y);
        let p = m.predict_proba(&[1.0, 2.5]);
        assert!(p.is_finite() && p > 0.5);
    }
}
