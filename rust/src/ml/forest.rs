//! Random forest — bootstrap-bagged gini trees with sqrt(d) feature
//! subsampling, impurity-based feature importances (Fig. 5) and a text
//! serialization (`.fewq`) so FastEWQ can ship a pre-trained classifier.

use super::tree::{fit_classification, Node, Tree, TreeConfig};
use super::Classifier;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Debug)]
pub struct RandomForest {
    pub n_trees: usize,
    pub max_depth: usize,
    pub seed: u64,
    pub trees: Vec<Tree>,
    pub n_features: usize,
}

impl RandomForest {
    pub fn new(n_trees: usize, max_depth: usize, seed: u64) -> Self {
        Self { n_trees, max_depth, seed, trees: Vec::new(), n_features: 0 }
    }

    /// Normalized impurity-decrease feature importances (sums to 1).
    pub fn feature_importances(&self) -> Vec<f64> {
        let mut imp = vec![0.0; self.n_features];
        for t in &self.trees {
            for (a, b) in imp.iter_mut().zip(&t.importance) {
                *a += b;
            }
        }
        let s: f64 = imp.iter().sum();
        if s > 0.0 {
            for v in &mut imp {
                *v /= s;
            }
        }
        imp
    }

    // ---- text serialization: one line per node -----------------------------
    pub fn serialize(&self) -> String {
        let mut out = format!("FEWQ1 trees={} features={}\n", self.trees.len(), self.n_features);
        for t in &self.trees {
            out.push_str(&format!("T {}\n", t.nodes.len()));
            for n in &t.nodes {
                match n {
                    Node::Leaf { value } => out.push_str(&format!("L {value:.17}\n")),
                    Node::Split { feat, thr, left, right } => {
                        out.push_str(&format!("S {feat} {thr:.17} {left} {right}\n"))
                    }
                }
            }
        }
        out
    }

    pub fn deserialize(text: &str) -> anyhow::Result<Self> {
        use anyhow::{bail, Context};
        let mut lines = text.lines();
        let header = lines.next().context("empty forest file")?;
        let mut parts = header.split_whitespace();
        if parts.next() != Some("FEWQ1") {
            bail!("bad magic in forest file");
        }
        let mut n_trees = 0usize;
        let mut n_features = 0usize;
        for kv in parts {
            let (k, v) = kv.split_once('=').context("bad header kv")?;
            match k {
                "trees" => n_trees = v.parse()?,
                "features" => n_features = v.parse()?,
                _ => {}
            }
        }
        let mut trees = Vec::with_capacity(n_trees);
        for _ in 0..n_trees {
            let tl = lines.next().context("missing tree header")?;
            let n_nodes: usize =
                tl.strip_prefix("T ").context("bad tree header")?.trim().parse()?;
            let mut nodes = Vec::with_capacity(n_nodes);
            for _ in 0..n_nodes {
                let l = lines.next().context("missing node")?;
                let mut f = l.split_whitespace();
                match f.next() {
                    Some("L") => nodes.push(Node::Leaf {
                        value: f.next().context("leaf value")?.parse()?,
                    }),
                    Some("S") => nodes.push(Node::Split {
                        feat: f.next().context("feat")?.parse()?,
                        thr: f.next().context("thr")?.parse()?,
                        left: f.next().context("left")?.parse()?,
                        right: f.next().context("right")?.parse()?,
                    }),
                    other => bail!("bad node tag {other:?}"),
                }
            }
            trees.push(Tree { nodes, importance: vec![0.0; n_features] });
        }
        Ok(Self { n_trees, max_depth: 0, seed: 0, trees, n_features })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.serialize())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::deserialize(&std::fs::read_to_string(path)?)
    }
}

impl Classifier for RandomForest {
    fn name(&self) -> &'static str {
        "random forest"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        let n = x.len();
        let d = x[0].len();
        self.n_features = d;
        let mtry = (d as f64).sqrt().round().max(1.0) as usize;
        let cfg = TreeConfig {
            max_depth: self.max_depth,
            min_samples_split: 2,
            max_features: Some(mtry),
        };
        let mut rng = Xoshiro256pp::new(self.seed);
        self.trees = (0..self.n_trees)
            .map(|_| {
                let idx = rng.bootstrap(n);
                fit_classification(x, y, &idx, &cfg, &mut rng)
            })
            .collect();
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        if self.trees.is_empty() {
            return 0.5;
        }
        self.trees.iter().map(|t| t.predict(row)).sum::<f64>() / self.trees.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn fits_nonlinear_boundary() {
        let mut r = Xoshiro256pp::new(5);
        let x: Vec<Vec<f64>> =
            (0..400).map(|_| vec![r.uniform(-1.0, 1.0), r.uniform(-1.0, 1.0)]).collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[0] * p[1] > 0.0)).collect();
        let mut rf = RandomForest::new(60, 8, 1);
        rf.fit(&x, &y);
        let acc = x
            .iter()
            .zip(&y)
            .filter(|(row, &t)| rf.predict(row) == t)
            .count() as f64
            / x.len() as f64;
        assert!(acc > 0.93, "acc={acc}");
    }

    #[test]
    fn importances_identify_signal_feature() {
        let mut r = Xoshiro256pp::new(6);
        let x: Vec<Vec<f64>> = (0..300)
            .map(|_| vec![r.normal(), r.normal(), r.normal()])
            .collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[1] > 0.0)).collect(); // only feat 1 matters
        let mut rf = RandomForest::new(60, 6, 2);
        rf.fit(&x, &y);
        let imp = rf.feature_importances();
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(imp[1] > 0.6, "importances {imp:?}");
        assert!(imp[1] > imp[0] && imp[1] > imp[2]);
    }

    #[test]
    fn serialize_roundtrip_preserves_predictions() {
        let mut r = Xoshiro256pp::new(7);
        let x: Vec<Vec<f64>> = (0..120).map(|_| vec![r.normal(), r.normal()]).collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[0] + p[1] > 0.0)).collect();
        let mut rf = RandomForest::new(20, 5, 3);
        rf.fit(&x, &y);
        let rf2 = RandomForest::deserialize(&rf.serialize()).unwrap();
        for row in &x {
            assert!((rf.predict_proba(row) - rf2.predict_proba(row)).abs() < 1e-15);
        }
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(RandomForest::deserialize("not a forest").is_err());
        assert!(RandomForest::deserialize("").is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r = Xoshiro256pp::new(8);
        let x: Vec<Vec<f64>> = (0..80).map(|_| vec![r.normal()]).collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[0] > 0.0)).collect();
        let mut a = RandomForest::new(10, 4, 9);
        let mut b = RandomForest::new(10, 4, 9);
        a.fit(&x, &y);
        b.fit(&x, &y);
        assert_eq!(a.serialize(), b.serialize());
    }
}
