//! Logistic regression — full-batch gradient descent with L2 regularization.

use super::Classifier;

#[derive(Clone, Debug)]
pub struct LogReg {
    pub lr: f64,
    pub epochs: usize,
    pub l2: f64,
    pub w: Vec<f64>,
    pub b: f64,
}

impl Default for LogReg {
    fn default() -> Self {
        Self { lr: 0.3, epochs: 400, l2: 1e-4, w: Vec::new(), b: 0.0 }
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl Classifier for LogReg {
    fn name(&self) -> &'static str {
        "logistic regression"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        let n = x.len();
        let d = x[0].len();
        self.w = vec![0.0; d];
        self.b = 0.0;
        let inv_n = 1.0 / n as f64;
        for _ in 0..self.epochs {
            let mut gw = vec![0.0; d];
            let mut gb = 0.0;
            for (row, &t) in x.iter().zip(y) {
                let z: f64 = self.b + row.iter().zip(&self.w).map(|(a, b)| a * b).sum::<f64>();
                let err = sigmoid(z) - t as f64;
                for (g, &v) in gw.iter_mut().zip(row) {
                    *g += err * v;
                }
                gb += err;
            }
            for (w, g) in self.w.iter_mut().zip(&gw) {
                *w -= self.lr * (g * inv_n + self.l2 * *w);
            }
            self.b -= self.lr * gb * inv_n;
        }
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        let z: f64 = self.b + row.iter().zip(&self.w).map(|(a, b)| a * b).sum::<f64>();
        sigmoid(z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;

    #[test]
    fn learns_linearly_separable() {
        // y = 1 iff x0 > 0
        let x: Vec<Vec<f64>> = (-50..50).map(|i| vec![i as f64 / 10.0]).collect();
        let y: Vec<u8> = x.iter().map(|r| u8::from(r[0] > 0.0)).collect();
        let mut m = LogReg::default();
        m.fit(&x, &y);
        assert!(m.predict(&[2.0]) == 1 && m.predict(&[-2.0]) == 0);
        assert!(m.predict_proba(&[3.0]) > 0.95);
        assert!(m.predict_proba(&[-3.0]) < 0.05);
    }

    #[test]
    fn sigmoid_stable_extremes() {
        assert!(sigmoid(1000.0) <= 1.0 && sigmoid(1000.0) > 0.999);
        assert!(sigmoid(-1000.0) >= 0.0 && sigmoid(-1000.0) < 1e-6);
    }

    #[test]
    fn weights_shrink_with_l2() {
        let x: Vec<Vec<f64>> = (-20..20).map(|i| vec![i as f64]).collect();
        let y: Vec<u8> = x.iter().map(|r| u8::from(r[0] > 0.0)).collect();
        let mut weak = LogReg { l2: 1.0, ..Default::default() };
        let mut strong = LogReg { l2: 0.0, ..Default::default() };
        weak.fit(&x, &y);
        strong.fit(&x, &y);
        assert!(weak.w[0].abs() < strong.w[0].abs());
    }
}
