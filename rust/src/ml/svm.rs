//! Linear SVM — Pegasos-style stochastic subgradient descent on the hinge
//! loss. Probability output via a logistic squash of the margin (Platt-lite);
//! ROC uses the raw margin ordering, which the squash preserves.

use super::Classifier;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Debug)]
pub struct LinearSvm {
    pub lambda: f64,
    pub epochs: usize,
    pub seed: u64,
    pub w: Vec<f64>,
    pub b: f64,
}

impl Default for LinearSvm {
    fn default() -> Self {
        Self { lambda: 1e-3, epochs: 60, seed: 17, w: Vec::new(), b: 0.0 }
    }
}

impl LinearSvm {
    pub fn margin(&self, row: &[f64]) -> f64 {
        self.b + row.iter().zip(&self.w).map(|(a, b)| a * b).sum::<f64>()
    }
}

impl Classifier for LinearSvm {
    fn name(&self) -> &'static str {
        "SVM"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        let n = x.len();
        let d = x[0].len();
        self.w = vec![0.0; d];
        self.b = 0.0;
        let mut rng = Xoshiro256pp::new(self.seed);
        let mut t = 0usize;
        for _ in 0..self.epochs {
            for _ in 0..n {
                t += 1;
                let i = rng.below(n);
                let target = if y[i] == 1 { 1.0 } else { -1.0 };
                let eta = 1.0 / (self.lambda * t as f64);
                let m = self.margin(&x[i]) * target;
                // w <- (1 - eta*lambda) w [+ eta*y*x if margin violated]
                let shrink = 1.0 - eta * self.lambda;
                for w in &mut self.w {
                    *w *= shrink;
                }
                if m < 1.0 {
                    for (w, &v) in self.w.iter_mut().zip(&x[i]) {
                        *w += eta * target * v;
                    }
                    self.b += eta * target;
                }
            }
        }
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        let m = self.margin(row);
        1.0 / (1.0 + (-2.0 * m).exp())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;

    #[test]
    fn separates_margin_data() {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..100 {
            let off = (i % 10) as f64 / 10.0;
            x.push(vec![1.0 + off, 0.5]);
            y.push(1u8);
            x.push(vec![-1.0 - off, -0.5]);
            y.push(0u8);
        }
        let mut m = LinearSvm::default();
        m.fit(&x, &y);
        assert_eq!(m.predict(&[1.5, 0.5]), 1);
        assert_eq!(m.predict(&[-1.5, -0.5]), 0);
        assert!(m.margin(&[2.0, 0.5]) > 0.5);
    }

    #[test]
    fn proba_monotone_in_margin() {
        let m = LinearSvm { w: vec![1.0], b: 0.0, ..Default::default() };
        assert!(m.predict_proba(&[2.0]) > m.predict_proba(&[1.0]));
        assert!(m.predict_proba(&[0.0]) == 0.5);
    }

    #[test]
    fn deterministic_given_seed() {
        let x: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64 - 20.0]).collect();
        let y: Vec<u8> = x.iter().map(|r| u8::from(r[0] > 0.0)).collect();
        let mut a = LinearSvm::default();
        let mut b = LinearSvm::default();
        a.fit(&x, &y);
        b.fit(&x, &y);
        assert_eq!(a.w, b.w);
        assert_eq!(a.b, b.b);
    }
}
