//! From-scratch ML toolkit backing FastEWQ (paper Section 4).
//!
//! Six classifiers (logistic regression, linear SVM, random forest, gradient
//! boosting ("XGB"), kNN, Gaussian naive Bayes) + StandardScaler, stratified
//! split, classification metrics, ROC/AUC and feature importances — enough
//! to regenerate Tables 3/5 and Figures 5/6 without sklearn/xgboost.

pub mod crossval;
pub mod forest;
pub mod gbdt;
pub mod gnb;
pub mod knn;
pub mod logreg;
pub mod metrics;
pub mod scaler;
pub mod svm;
pub mod tree;

pub use crossval::{cross_val_accuracy, stratified_folds, wilson_interval};
pub use forest::RandomForest;
pub use gbdt::Gbdt;
pub use gnb::GaussianNb;
pub use knn::Knn;
pub use logreg::LogReg;
pub use metrics::{auc, confusion, roc_curve, ClassificationReport, Confusion};
pub use scaler::StandardScaler;
pub use svm::LinearSvm;

use crate::rng::Xoshiro256pp;

/// Binary classifier over dense f64 feature rows.
pub trait Classifier {
    fn name(&self) -> &'static str;
    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]);
    /// Score in [0,1] interpreted as P(class = 1).
    fn predict_proba(&self, row: &[f64]) -> f64;
    fn predict(&self, row: &[f64]) -> u8 {
        u8::from(self.predict_proba(row) >= 0.5)
    }
}

/// Predictions for a whole matrix.
pub fn predict_all<C: Classifier + ?Sized>(c: &C, x: &[Vec<f64>]) -> Vec<u8> {
    x.iter().map(|r| c.predict(r)).collect()
}

pub fn proba_all<C: Classifier + ?Sized>(c: &C, x: &[Vec<f64>]) -> Vec<f64> {
    x.iter().map(|r| c.predict_proba(r)).collect()
}

/// Stratified train/test split preserving class balance (paper: 70:30).
pub fn train_test_split(
    x: &[Vec<f64>],
    y: &[u8],
    test_frac: f64,
    seed: u64,
) -> (Vec<Vec<f64>>, Vec<u8>, Vec<Vec<f64>>, Vec<u8>) {
    assert_eq!(x.len(), y.len());
    let mut rng = Xoshiro256pp::new(seed);
    let mut train_idx = Vec::new();
    let mut test_idx = Vec::new();
    for class in [0u8, 1u8] {
        let mut idx: Vec<usize> = (0..y.len()).filter(|&i| y[i] == class).collect();
        rng.shuffle(&mut idx);
        let n_test = (idx.len() as f64 * test_frac).round() as usize;
        test_idx.extend_from_slice(&idx[..n_test]);
        train_idx.extend_from_slice(&idx[n_test..]);
    }
    rng.shuffle(&mut train_idx);
    rng.shuffle(&mut test_idx);
    let pick = |idx: &[usize]| -> (Vec<Vec<f64>>, Vec<u8>) {
        (idx.iter().map(|&i| x[i].clone()).collect(), idx.iter().map(|&i| y[i]).collect())
    };
    let (xtr, ytr) = pick(&train_idx);
    let (xte, yte) = pick(&test_idx);
    (xtr, ytr, xte, yte)
}

/// Build the paper's full classifier line-up with its default hyperparameters.
pub fn all_classifiers(seed: u64) -> Vec<Box<dyn Classifier>> {
    vec![
        Box::new(LogReg::default()),
        Box::new(LinearSvm::default()),
        Box::new(RandomForest::new(120, 8, seed)),
        Box::new(Gbdt::new(80, 3, 0.15, seed)),
        Box::new(Knn::new(7)),
        Box::new(GaussianNb::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    /// Noisy two-cluster dataset every sane classifier should beat 85% on.
    pub(crate) fn blobs(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<u8>) {
        let mut r = Xoshiro256pp::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let c = (i % 2) as u8;
            let center = if c == 0 { -1.0 } else { 1.0 };
            x.push(vec![
                center + r.normal() * 0.6,
                -center + r.normal() * 0.6,
                r.normal(), // pure-noise feature
            ]);
            y.push(c);
        }
        (x, y)
    }

    /// XOR-ish dataset only non-linear models solve.
    pub(crate) fn xor(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<u8>) {
        let mut r = Xoshiro256pp::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = r.uniform(-1.0, 1.0);
            let b = r.uniform(-1.0, 1.0);
            x.push(vec![a, b]);
            y.push(u8::from(a * b > 0.0));
        }
        (x, y)
    }

    #[test]
    fn split_is_stratified_and_disjoint() {
        let (x, y) = blobs(200, 1);
        let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.3, 42);
        assert_eq!(xtr.len() + xte.len(), 200);
        assert_eq!(xtr.len(), ytr.len());
        assert_eq!(xte.len(), yte.len());
        let pos_te = yte.iter().filter(|&&v| v == 1).count() as f64 / yte.len() as f64;
        assert!((pos_te - 0.5).abs() < 0.05, "stratification broken: {pos_te}");
    }

    #[test]
    fn every_classifier_learns_blobs() {
        let (x, y) = blobs(300, 2);
        let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.3, 7);
        for mut c in all_classifiers(5) {
            c.fit(&xtr, &ytr);
            let pred = predict_all(c.as_ref(), &xte);
            let acc = pred.iter().zip(&yte).filter(|(a, b)| a == b).count() as f64
                / yte.len() as f64;
            assert!(acc > 0.85, "{} only reached {acc}", c.name());
        }
    }

    #[test]
    fn nonlinear_models_beat_linear_on_xor() {
        let (x, y) = xor(400, 3);
        let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.3, 9);
        let acc_of = |c: &mut dyn Classifier| {
            c.fit(&xtr, &ytr);
            predict_all(c, &xte).iter().zip(&yte).filter(|(a, b)| a == b).count() as f64
                / yte.len() as f64
        };
        let mut rf = RandomForest::new(80, 8, 1);
        let mut lr = LogReg::default();
        let rf_acc = acc_of(&mut rf);
        let lr_acc = acc_of(&mut lr);
        assert!(rf_acc > 0.9, "rf {rf_acc}");
        assert!(lr_acc < 0.7, "logreg should fail xor, got {lr_acc}");
    }

    #[test]
    fn proba_in_unit_interval() {
        let (x, y) = blobs(120, 4);
        for mut c in all_classifiers(11) {
            c.fit(&x, &y);
            for row in &x {
                let p = c.predict_proba(row);
                assert!((0.0..=1.0).contains(&p), "{}: p={p}", c.name());
            }
        }
    }
}
