//! Gradient-boosted trees ("XGB" in the paper's line-up): logistic loss,
//! regression trees on negative gradients, shrinkage learning rate.

use super::tree::{fit_regression, Tree, TreeConfig};
use super::Classifier;
use crate::rng::Xoshiro256pp;

#[derive(Clone, Debug)]
pub struct Gbdt {
    pub n_rounds: usize,
    pub max_depth: usize,
    pub lr: f64,
    pub seed: u64,
    pub base: f64,
    pub trees: Vec<Tree>,
}

impl Gbdt {
    pub fn new(n_rounds: usize, max_depth: usize, lr: f64, seed: u64) -> Self {
        Self { n_rounds, max_depth, lr, seed, base: 0.0, trees: Vec::new() }
    }

    fn raw_score(&self, row: &[f64]) -> f64 {
        self.base + self.lr * self.trees.iter().map(|t| t.predict(row)).sum::<f64>()
    }
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl Classifier for Gbdt {
    fn name(&self) -> &'static str {
        "XGB"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        let n = x.len();
        let pos = y.iter().filter(|&&v| v == 1).count() as f64;
        // log-odds prior
        let p = (pos / n as f64).clamp(1e-6, 1.0 - 1e-6);
        self.base = (p / (1.0 - p)).ln();
        self.trees.clear();

        let cfg = TreeConfig {
            max_depth: self.max_depth,
            min_samples_split: 4,
            max_features: None,
        };
        let mut rng = Xoshiro256pp::new(self.seed);
        let idx: Vec<usize> = (0..n).collect();
        let mut raw: Vec<f64> = vec![self.base; n];
        for _ in 0..self.n_rounds {
            // negative gradient of logloss: y - sigmoid(raw)
            let grad: Vec<f64> =
                raw.iter().zip(y).map(|(&r, &t)| t as f64 - sigmoid(r)).collect();
            let tree = fit_regression(x, &grad, &idx, &cfg, &mut rng);
            for (i, r) in raw.iter_mut().enumerate() {
                *r += self.lr * tree.predict(&x[i]);
            }
            self.trees.push(tree);
        }
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        sigmoid(self.raw_score(row))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn fits_xor() {
        let mut r = Xoshiro256pp::new(1);
        let x: Vec<Vec<f64>> =
            (0..300).map(|_| vec![r.uniform(-1.0, 1.0), r.uniform(-1.0, 1.0)]).collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[0] * p[1] > 0.0)).collect();
        let mut g = Gbdt::new(60, 3, 0.2, 2);
        g.fit(&x, &y);
        let acc =
            x.iter().zip(&y).filter(|(row, &t)| g.predict(row) == t).count() as f64 / x.len() as f64;
        assert!(acc > 0.9, "acc={acc}");
    }

    #[test]
    fn base_is_class_prior() {
        let x = vec![vec![0.0]; 10];
        let y = [1, 1, 1, 1, 1, 1, 1, 1, 0, 0]; // 80% positive
        let mut g = Gbdt::new(0, 3, 0.1, 0);
        g.fit(&x, &y);
        assert!((sigmoid(g.base) - 0.8).abs() < 1e-9);
    }

    #[test]
    fn more_rounds_reduce_training_error() {
        let mut r = Xoshiro256pp::new(3);
        let x: Vec<Vec<f64>> = (0..200).map(|_| vec![r.normal(), r.normal()]).collect();
        let y: Vec<u8> = x.iter().map(|p| u8::from(p[0].sin() + p[1] > 0.0)).collect();
        let err_of = |rounds: usize| {
            let mut g = Gbdt::new(rounds, 3, 0.2, 4);
            g.fit(&x, &y);
            x.iter().zip(&y).filter(|(row, &t)| g.predict(row) != t).count()
        };
        assert!(err_of(50) <= err_of(5));
    }
}
