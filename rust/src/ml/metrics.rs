//! Classification metrics: confusion matrix, precision/recall/F1 report
//! (paper Tables 3–5), ROC curve and AUC (Fig. 6).

/// Binary confusion counts with the paper's Table 5 orientation:
/// class 0 = "not quantized" (negative), class 1 = "quantized" (positive).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tn: usize,
    pub fp: usize,
    pub fn_: usize,
    pub tp: usize,
}

impl Confusion {
    pub fn accuracy(&self) -> f64 {
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    pub fn total(&self) -> usize {
        self.tp + self.tn + self.fp + self.fn_
    }

    /// Precision/recall/F1 for one class (0 or 1).
    pub fn prf(&self, class: u8) -> (f64, f64, f64) {
        let (tp, fp, fn_) = if class == 1 {
            (self.tp, self.fp, self.fn_)
        } else {
            (self.tn, self.fn_, self.fp)
        };
        let p = if tp + fp == 0 { 0.0 } else { tp as f64 / (tp + fp) as f64 };
        let r = if tp + fn_ == 0 { 0.0 } else { tp as f64 / (tp + fn_) as f64 };
        let f1 = if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
        (p, r, f1)
    }

    pub fn support(&self, class: u8) -> usize {
        if class == 1 {
            self.tp + self.fn_
        } else {
            self.tn + self.fp
        }
    }
}

pub fn confusion(y_true: &[u8], y_pred: &[u8]) -> Confusion {
    assert_eq!(y_true.len(), y_pred.len());
    let mut c = Confusion::default();
    for (&t, &p) in y_true.iter().zip(y_pred) {
        match (t, p) {
            (0, 0) => c.tn += 1,
            (0, 1) => c.fp += 1,
            (1, 0) => c.fn_ += 1,
            (1, 1) => c.tp += 1,
            _ => panic!("labels must be binary"),
        }
    }
    c
}

/// Full classification report (mirrors sklearn's layout used in Table 3).
#[derive(Clone, Debug)]
pub struct ClassificationReport {
    pub confusion: Confusion,
    /// (precision, recall, f1, support) for class 0 and class 1
    pub per_class: [(f64, f64, f64, usize); 2],
    pub accuracy: f64,
    pub macro_avg: (f64, f64, f64),
    pub weighted_avg: (f64, f64, f64),
}

impl ClassificationReport {
    pub fn from_predictions(y_true: &[u8], y_pred: &[u8]) -> Self {
        let c = confusion(y_true, y_pred);
        let (p0, r0, f0) = c.prf(0);
        let (p1, r1, f1) = c.prf(1);
        let (s0, s1) = (c.support(0), c.support(1));
        let n = (s0 + s1) as f64;
        let macro_avg = ((p0 + p1) / 2.0, (r0 + r1) / 2.0, (f0 + f1) / 2.0);
        let weighted_avg = (
            (p0 * s0 as f64 + p1 * s1 as f64) / n,
            (r0 * s0 as f64 + r1 * s1 as f64) / n,
            (f0 * s0 as f64 + f1 * s1 as f64) / n,
        );
        Self {
            confusion: c,
            per_class: [(p0, r0, f0, s0), (p1, r1, f1, s1)],
            accuracy: c.accuracy(),
            macro_avg,
            weighted_avg,
        }
    }
}

/// ROC curve points (fpr, tpr) sorted by descending score threshold,
/// beginning at (0,0) and ending at (1,1).
pub fn roc_curve(y_true: &[u8], scores: &[f64]) -> Vec<(f64, f64)> {
    assert_eq!(y_true.len(), scores.len());
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let pos = y_true.iter().filter(|&&y| y == 1).count() as f64;
    let neg = y_true.len() as f64 - pos;
    let mut pts = vec![(0.0, 0.0)];
    let (mut tp, mut fp) = (0.0, 0.0);
    let mut i = 0;
    while i < order.len() {
        // advance over ties as a group
        let s = scores[order[i]];
        while i < order.len() && scores[order[i]] == s {
            if y_true[order[i]] == 1 {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
            i += 1;
        }
        pts.push((if neg > 0.0 { fp / neg } else { 0.0 }, if pos > 0.0 { tp / pos } else { 0.0 }));
    }
    pts
}

/// Area under the ROC curve (trapezoidal).
pub fn auc(y_true: &[u8], scores: &[f64]) -> f64 {
    let pts = roc_curve(y_true, scores);
    let mut area = 0.0;
    for w in pts.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        area += (x1 - x0) * (y0 + y1) / 2.0;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_counts() {
        let c = confusion(&[0, 0, 1, 1, 1], &[0, 1, 1, 0, 1]);
        assert_eq!(c, Confusion { tn: 1, fp: 1, fn_: 1, tp: 2 });
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
        assert_eq!(c.support(0), 2);
        assert_eq!(c.support(1), 3);
    }

    #[test]
    fn report_matches_hand_computation() {
        let y = [0, 0, 0, 1, 1];
        let p = [0, 0, 1, 1, 0];
        let r = ClassificationReport::from_predictions(&y, &p);
        // class 1: tp=1 fp=1 fn=1 -> p=0.5 r=0.5 f1=0.5
        let (p1, r1, f1, s1) = r.per_class[1];
        assert!((p1 - 0.5).abs() < 1e-12 && (r1 - 0.5).abs() < 1e-12 && (f1 - 0.5).abs() < 1e-12);
        assert_eq!(s1, 2);
        assert!((r.accuracy - 0.6).abs() < 1e-12);
        // weighted avg weights by support 3/2
        let (wp, _, _) = r.weighted_avg;
        let (p0, ..) = r.per_class[0];
        assert!((wp - (p0 * 3.0 + 0.5 * 2.0) / 5.0).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_random_and_inverted() {
        let y = [0, 0, 1, 1];
        assert!((auc(&y, &[0.1, 0.2, 0.8, 0.9]) - 1.0).abs() < 1e-12);
        assert!((auc(&y, &[0.9, 0.8, 0.2, 0.1]) - 0.0).abs() < 1e-12);
        // all-equal scores -> diagonal -> 0.5
        assert!((auc(&y, &[0.5, 0.5, 0.5, 0.5]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn roc_endpoints() {
        let y = [0, 1, 0, 1, 1];
        let s = [0.1, 0.9, 0.4, 0.35, 0.8];
        let pts = roc_curve(&y, &s);
        assert_eq!(pts.first(), Some(&(0.0, 0.0)));
        assert_eq!(pts.last(), Some(&(1.0, 1.0)));
        // monotone non-decreasing in both axes
        for w in pts.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1);
        }
    }
}
