//! CART trees: a gini classification tree (random-forest base learner, with
//! optional per-split feature subsampling) and a variance-reduction
//! regression tree (GBDT base learner). Flat node-array representation so
//! forests serialize trivially.

use crate::rng::Xoshiro256pp;

/// One node: internal (feature, threshold, children) or leaf (value).
/// `value` is P(class=1) for classification, mean target for regression.
#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    Split { feat: usize, thr: f64, left: usize, right: usize },
    Leaf { value: f64 },
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
    /// Total impurity decrease contributed by each feature (classification
    /// trees only; feeds Fig. 5 importances).
    pub importance: Vec<f64>,
}

impl Tree {
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feat, thr, left, right } => {
                    i = if row[*feat] <= *thr { *left } else { *right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        fn go(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 1,
                Node::Split { left, right, .. } => 1 + go(nodes, *left).max(go(nodes, *right)),
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(&self.nodes, 0)
        }
    }
}

pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features to consider per split; None = all (sqrt(d) for forests).
    pub max_features: Option<usize>,
}

impl Default for TreeConfig {
    fn default() -> Self {
        Self { max_depth: 8, min_samples_split: 2, max_features: None }
    }
}

fn gini(pos: f64, total: f64) -> f64 {
    if total == 0.0 {
        return 0.0;
    }
    let p = pos / total;
    2.0 * p * (1.0 - p)
}

/// Grow a gini classification tree on rows indexed by `idx`.
/// `y` in {0,1}; sample weights are implicit (uniform).
pub fn fit_classification(
    x: &[Vec<f64>],
    y: &[u8],
    idx: &[usize],
    cfg: &TreeConfig,
    rng: &mut Xoshiro256pp,
) -> Tree {
    let d = x[0].len();
    let mut tree = Tree { nodes: Vec::new(), importance: vec![0.0; d] };
    let mut idx = idx.to_vec();
    build_cls(x, y, &mut idx, cfg, rng, &mut tree, 0);
    tree
}

fn leaf_cls(y: &[u8], idx: &[usize]) -> Node {
    let pos = idx.iter().filter(|&&i| y[i] == 1).count() as f64;
    Node::Leaf { value: pos / idx.len().max(1) as f64 }
}

fn build_cls(
    x: &[Vec<f64>],
    y: &[u8],
    idx: &mut [usize],
    cfg: &TreeConfig,
    rng: &mut Xoshiro256pp,
    tree: &mut Tree,
    depth: usize,
) -> usize {
    let node_id = tree.nodes.len();
    let n = idx.len();
    let pos = idx.iter().filter(|&&i| y[i] == 1).count() as f64;
    if depth >= cfg.max_depth || n < cfg.min_samples_split || pos == 0.0 || pos == n as f64 {
        tree.nodes.push(leaf_cls(y, idx));
        return node_id;
    }

    // choose candidate features
    let d = x[0].len();
    let feats: Vec<usize> = match cfg.max_features {
        Some(k) if k < d => rng.sample_indices(d, k),
        _ => (0..d).collect(),
    };

    let parent_impurity = gini(pos, n as f64);
    let mut best: Option<(f64, usize, f64)> = None; // (gain, feat, thr)
    let mut vals: Vec<(f64, u8)> = Vec::with_capacity(n);
    for &f in &feats {
        vals.clear();
        vals.extend(idx.iter().map(|&i| (x[i][f], y[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let total_pos = pos;
        let mut left_pos = 0.0f64;
        for (k, pair) in vals.iter().enumerate().take(n - 1) {
            left_pos += pair.1 as f64;
            // only split between distinct values
            if pair.0 == vals[k + 1].0 {
                continue;
            }
            let nl = (k + 1) as f64;
            let nr = n as f64 - nl;
            let imp = (nl * gini(left_pos, nl) + nr * gini(total_pos - left_pos, nr)) / n as f64;
            let gain = parent_impurity - imp;
            if best.map(|(g, ..)| gain > g).unwrap_or(gain > 1e-12) {
                best = Some((gain, f, (pair.0 + vals[k + 1].0) / 2.0));
            }
        }
    }

    let Some((gain, feat, thr)) = best else {
        tree.nodes.push(leaf_cls(y, idx));
        return node_id;
    };
    tree.importance[feat] += gain * n as f64;

    tree.nodes.push(Node::Leaf { value: 0.0 }); // placeholder
    let split_at = partition(x, idx, feat, thr);
    let (l_idx, r_idx) = idx.split_at_mut(split_at);
    let left = build_cls(x, y, l_idx, cfg, rng, tree, depth + 1);
    let right = build_cls(x, y, r_idx, cfg, rng, tree, depth + 1);
    tree.nodes[node_id] = Node::Split { feat, thr, left, right };
    node_id
}

/// Grow a variance-reduction regression tree on residual targets `g`.
pub fn fit_regression(
    x: &[Vec<f64>],
    g: &[f64],
    idx: &[usize],
    cfg: &TreeConfig,
    rng: &mut Xoshiro256pp,
) -> Tree {
    let d = x[0].len();
    let mut tree = Tree { nodes: Vec::new(), importance: vec![0.0; d] };
    let mut idx = idx.to_vec();
    build_reg(x, g, &mut idx, cfg, rng, &mut tree, 0);
    tree
}

fn build_reg(
    x: &[Vec<f64>],
    g: &[f64],
    idx: &mut [usize],
    cfg: &TreeConfig,
    rng: &mut Xoshiro256pp,
    tree: &mut Tree,
    depth: usize,
) -> usize {
    let node_id = tree.nodes.len();
    let n = idx.len();
    let sum: f64 = idx.iter().map(|&i| g[i]).sum();
    let mean = sum / n as f64;
    if depth >= cfg.max_depth || n < cfg.min_samples_split {
        tree.nodes.push(Node::Leaf { value: mean });
        return node_id;
    }

    let d = x[0].len();
    let feats: Vec<usize> = match cfg.max_features {
        Some(k) if k < d => rng.sample_indices(d, k),
        _ => (0..d).collect(),
    };

    // maximize sum-of-squares reduction: SSL = suml^2/nl + sumr^2/nr
    let mut best: Option<(f64, usize, f64)> = None;
    let base = sum * sum / n as f64;
    let mut vals: Vec<(f64, f64)> = Vec::with_capacity(n);
    for &f in &feats {
        vals.clear();
        vals.extend(idx.iter().map(|&i| (x[i][f], g[i])));
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut left_sum = 0.0;
        for (k, pair) in vals.iter().enumerate().take(n - 1) {
            left_sum += pair.1;
            if pair.0 == vals[k + 1].0 {
                continue;
            }
            let nl = (k + 1) as f64;
            let nr = n as f64 - nl;
            let right_sum = sum - left_sum;
            let score = left_sum * left_sum / nl + right_sum * right_sum / nr - base;
            if best.map(|(s, ..)| score > s).unwrap_or(score > 1e-12) {
                best = Some((score, f, (pair.0 + vals[k + 1].0) / 2.0));
            }
        }
    }

    let Some((_, feat, thr)) = best else {
        tree.nodes.push(Node::Leaf { value: mean });
        return node_id;
    };

    tree.nodes.push(Node::Leaf { value: 0.0 });
    let split_at = partition(x, idx, feat, thr);
    let (l_idx, r_idx) = idx.split_at_mut(split_at);
    let left = build_reg(x, g, l_idx, cfg, rng, tree, depth + 1);
    let right = build_reg(x, g, r_idx, cfg, rng, tree, depth + 1);
    tree.nodes[node_id] = Node::Split { feat, thr, left, right };
    node_id
}

/// In-place partition of idx by `x[i][feat] <= thr`; returns boundary.
fn partition(x: &[Vec<f64>], idx: &mut [usize], feat: usize, thr: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = idx.len();
    while lo < hi {
        if x[idx[lo]][feat] <= thr {
            lo += 1;
        } else {
            hi -= 1;
            idx.swap(lo, hi);
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::new(0)
    }

    #[test]
    fn classification_splits_cleanly() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y: Vec<u8> = (0..20).map(|i| u8::from(i >= 10)).collect();
        let idx: Vec<usize> = (0..20).collect();
        let t = fit_classification(&x, &y, &idx, &TreeConfig::default(), &mut rng());
        assert_eq!(t.predict(&[3.0]), 0.0);
        assert_eq!(t.predict(&[15.0]), 1.0);
        assert!(t.importance[0] > 0.0);
    }

    #[test]
    fn depth_limit_respected() {
        let mut r = rng();
        let x: Vec<Vec<f64>> = (0..128).map(|_| vec![r.next_f64(), r.next_f64()]).collect();
        let y: Vec<u8> = (0..128).map(|_| (r.next_u64() & 1) as u8).collect();
        let idx: Vec<usize> = (0..128).collect();
        let cfg = TreeConfig { max_depth: 3, ..Default::default() };
        let t = fit_classification(&x, &y, &idx, &cfg, &mut rng());
        assert!(t.depth() <= 4); // root at depth 0 => 4 levels of nodes
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = vec![vec![0.0], vec![1.0], vec![2.0]];
        let y = vec![1, 1, 1];
        let t = fit_classification(&x, &y, &[0, 1, 2], &TreeConfig::default(), &mut rng());
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict(&[5.0]), 1.0);
    }

    #[test]
    fn regression_fits_step() {
        let x: Vec<Vec<f64>> = (0..30).map(|i| vec![i as f64]).collect();
        let g: Vec<f64> = (0..30).map(|i| if i < 15 { -2.0 } else { 3.0 }).collect();
        let idx: Vec<usize> = (0..30).collect();
        let t = fit_regression(&x, &g, &idx, &TreeConfig::default(), &mut rng());
        assert!((t.predict(&[2.0]) + 2.0).abs() < 1e-9);
        assert!((t.predict(&[25.0]) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn partition_invariant() {
        let x: Vec<Vec<f64>> = vec![vec![5.0], vec![1.0], vec![3.0], vec![2.0], vec![4.0]];
        let mut idx = vec![0, 1, 2, 3, 4];
        let at = partition(&x, &mut idx, 0, 2.5);
        assert_eq!(at, 2);
        for &i in &idx[..at] {
            assert!(x[i][0] <= 2.5);
        }
        for &i in &idx[at..] {
            assert!(x[i][0] > 2.5);
        }
    }

    #[test]
    fn constant_features_yield_single_leaf() {
        let x = vec![vec![1.0], vec![1.0], vec![1.0], vec![1.0]];
        let y = vec![0, 1, 0, 1];
        let t = fit_classification(&x, &y, &[0, 1, 2, 3], &TreeConfig::default(), &mut rng());
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict(&[1.0]), 0.5);
    }
}
