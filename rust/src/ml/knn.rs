//! k-nearest-neighbours (euclidean); probability = positive fraction among
//! the k nearest training rows.

use super::Classifier;

#[derive(Clone, Debug)]
pub struct Knn {
    pub k: usize,
    x: Vec<Vec<f64>>,
    y: Vec<u8>,
}

impl Knn {
    pub fn new(k: usize) -> Self {
        Self { k, x: Vec::new(), y: Vec::new() }
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&p, &q)| (p - q) * (p - q)).sum()
}

impl Classifier for Knn {
    fn name(&self) -> &'static str {
        "kNN"
    }

    fn fit(&mut self, x: &[Vec<f64>], y: &[u8]) {
        self.x = x.to_vec();
        self.y = y.to_vec();
    }

    fn predict_proba(&self, row: &[f64]) -> f64 {
        assert!(!self.x.is_empty(), "kNN not fitted");
        let k = self.k.min(self.x.len());
        // partial selection of the k smallest distances
        let mut d: Vec<(f64, u8)> =
            self.x.iter().zip(&self.y).map(|(r, &t)| (dist2(row, r), t)).collect();
        d.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
        let pos = d[..k].iter().filter(|(_, t)| *t == 1).count();
        pos as f64 / k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;

    #[test]
    fn nearest_neighbour_wins() {
        let x = vec![vec![0.0], vec![0.1], vec![10.0], vec![10.1], vec![10.2]];
        let y = vec![0, 0, 1, 1, 1];
        let mut m = Knn::new(3);
        m.fit(&x, &y);
        assert_eq!(m.predict(&[0.05]), 0);
        assert_eq!(m.predict(&[10.05]), 1);
    }

    #[test]
    fn proba_is_neighbour_fraction() {
        let x = vec![vec![0.0], vec![0.2], vec![0.4]];
        let y = vec![1, 0, 1];
        let mut m = Knn::new(3);
        m.fit(&x, &y);
        assert!((m.predict_proba(&[0.1]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn k_larger_than_dataset_is_clamped() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0, 1];
        let mut m = Knn::new(50);
        m.fit(&x, &y);
        assert!((m.predict_proba(&[0.5]) - 0.5).abs() < 1e-12);
    }
}
