//! Stratified k-fold cross-validation + binomial confidence intervals —
//! robustness checks behind the Table 3 classifier comparison (a single
//! 70:30 split can flatter or punish a classifier; CV bounds that).

use super::{predict_all, Classifier};
use crate::rng::Xoshiro256pp;

/// Stratified fold assignment: returns fold index per sample, balanced per
/// class. Deterministic given the seed.
pub fn stratified_folds(y: &[u8], k: usize, seed: u64) -> Vec<usize> {
    assert!(k >= 2);
    let mut rng = Xoshiro256pp::new(seed);
    let mut folds = vec![0usize; y.len()];
    for class in [0u8, 1u8] {
        let mut idx: Vec<usize> = (0..y.len()).filter(|&i| y[i] == class).collect();
        rng.shuffle(&mut idx);
        for (j, &i) in idx.iter().enumerate() {
            folds[i] = j % k;
        }
    }
    folds
}

/// Cross-validated accuracy of a classifier factory: `make()` must return a
/// fresh unfitted classifier. Returns per-fold accuracies.
pub fn cross_val_accuracy<F, C>(
    x: &[Vec<f64>],
    y: &[u8],
    k: usize,
    seed: u64,
    mut make: F,
) -> Vec<f64>
where
    F: FnMut() -> C,
    C: Classifier,
{
    let folds = stratified_folds(y, k, seed);
    (0..k)
        .map(|fold| {
            let (mut xtr, mut ytr, mut xte, mut yte) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            for i in 0..y.len() {
                if folds[i] == fold {
                    xte.push(x[i].clone());
                    yte.push(y[i]);
                } else {
                    xtr.push(x[i].clone());
                    ytr.push(y[i]);
                }
            }
            let mut c = make();
            c.fit(&xtr, &ytr);
            let pred = predict_all(&c, &xte);
            pred.iter().zip(&yte).filter(|(a, b)| a == b).count() as f64 / yte.len() as f64
        })
        .collect()
}

/// Wilson score interval for a binomial proportion (95% when z = 1.96).
pub fn wilson_interval(successes: usize, n: usize, z: f64) -> (f64, f64) {
    assert!(n > 0);
    let p = successes as f64 / n as f64;
    let n = n as f64;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = (z / denom) * ((p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt());
    ((center - half).max(0.0), (center + half).min(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::{LogReg, RandomForest};
    use crate::rng::Xoshiro256pp;

    fn blobs(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<u8>) {
        let mut r = Xoshiro256pp::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let c = (i % 2) as u8;
            let mu = if c == 0 { -1.0 } else { 1.0 };
            x.push(vec![mu + r.normal() * 0.5, r.normal()]);
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn folds_are_balanced_and_cover() {
        let y: Vec<u8> = (0..100).map(|i| (i % 2) as u8).collect();
        let folds = stratified_folds(&y, 5, 1);
        for f in 0..5 {
            let in_fold: Vec<usize> =
                (0..100).filter(|&i| folds[i] == f).collect();
            assert_eq!(in_fold.len(), 20);
            let pos = in_fold.iter().filter(|&&i| y[i] == 1).count();
            assert_eq!(pos, 10, "fold {f} class-imbalanced");
        }
    }

    #[test]
    fn cv_accuracy_high_on_separable_data() {
        let (x, y) = blobs(300, 2);
        let accs = cross_val_accuracy(&x, &y, 5, 3, LogReg::default);
        assert_eq!(accs.len(), 5);
        let mean = accs.iter().sum::<f64>() / 5.0;
        assert!(mean > 0.9, "cv accs {accs:?}");
    }

    #[test]
    fn cv_detects_chance_on_random_labels() {
        let mut r = Xoshiro256pp::new(4);
        let x: Vec<Vec<f64>> = (0..200).map(|_| vec![r.normal(), r.normal()]).collect();
        let y: Vec<u8> = (0..200).map(|_| (r.next_u64() & 1) as u8).collect();
        let accs = cross_val_accuracy(&x, &y, 5, 5, || RandomForest::new(20, 4, 1));
        let mean = accs.iter().sum::<f64>() / 5.0;
        assert!((0.3..0.7).contains(&mean), "should hover near chance: {accs:?}");
    }

    #[test]
    fn wilson_interval_properties() {
        let (lo, hi) = wilson_interval(80, 100, 1.96);
        assert!(lo < 0.8 && 0.8 < hi);
        assert!(hi - lo < 0.2);
        // shrinks with n
        let (lo2, hi2) = wilson_interval(800, 1000, 1.96);
        assert!(hi2 - lo2 < hi - lo);
        // degenerate cases stay in [0,1]
        let (lo3, hi3) = wilson_interval(0, 10, 1.96);
        assert!(lo3 >= 0.0 && hi3 <= 1.0 && hi3 > 0.0);
    }

    #[test]
    fn fastewq_dataset_cv_confirms_forest_advantage() {
        use crate::ewq::EwqConfig;
        use crate::fastewq::{build_dataset, rows_to_xy};
        use crate::ml::StandardScaler;
        let rows = build_dataset(350, 99, &[], &EwqConfig::default());
        let (x, y) = rows_to_xy(&rows);
        let (_, xs) = StandardScaler::fit_transform(&x);
        let rf = cross_val_accuracy(&xs, &y, 4, 7, || RandomForest::new(60, 8, 1));
        let lr = cross_val_accuracy(&xs, &y, 4, 7, LogReg::default);
        let rf_mean = rf.iter().sum::<f64>() / rf.len() as f64;
        let lr_mean = lr.iter().sum::<f64>() / lr.len() as f64;
        assert!(rf_mean > lr_mean, "rf {rf_mean} vs logreg {lr_mean}");
    }
}
