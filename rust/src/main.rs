//! `ewq` — CLI for the EWQ/FastEWQ reproduction.
//!
//! ```text
//! ewq exp <id|all> [--per-subject N]     regenerate a paper table/figure
//! ewq analyze --model <name> [--workers N]  entropy analysis + EWQ plan
//! ewq plan --model <name> [--budget-mb M --machines K]  Algorithm 1
//! ewq dataset [--rows N --workers N]     (re)build the FastEWQ dataset
//! ewq train-classifier [--out PATH --workers N]  train + save the forest
//! ewq serve --model <name> [--requests N --batch B --variant V --workers W
//!                            --dispatch work_steal|shortest_queue|round_robin
//!                            --decode-tokens N --kv-precision raw|8bit|4bit
//!                            --max-decode-batch M --kv-budget-mb MB
//!                            --max-queued-windows Q
//!                            --max-live-seqs L --deadline-ms D
//!                            --prefix-cache on|off --requant on|off
//!                            --requant-low-mb MB --requant-high-mb MB
//!                            --pin on|off]
//! ```
//!
//! Overload safety (DESIGN.md §13): `--max-queued-windows` bounds the
//! per-shard queue (excess sheds with a terminal `busy` status),
//! `--max-live-seqs` caps concurrent decode streams per shard, and
//! `--deadline-ms` applies a default per-request deadline (`expired` past
//! it). All three default to 0 = off. Prefix caching (DESIGN.md §14) is on
//! by default; `--prefix-cache off` is the always-ingest-fresh oracle.
//! Online requantization (DESIGN.md §15) is off by default; `--requant on`
//! starts a per-shard precision controller that demotes blocks Q8→Q4→Q3
//! above `--requant-high-mb` of resident-weight + KV pressure and promotes
//! them back below `--requant-low-mb` when the shard queue is idle, using
//! the trained FastEWQ classifier (when present in the artifacts dir) to
//! pick eligible blocks. `--pin on` (DESIGN.md §16, off by default) pins
//! each shard worker and its forward pool to a disjoint block of host
//! cores — best-effort `sched_setaffinity`, bit-identical output either
//! way, purely a locality/throughput knob.

use anyhow::{bail, Context, Result};

use ewq::cluster::{optimize_distribution, Cluster};
use ewq::config::{Args, ParallelConfig, ServeConfig};
use ewq::ewq::{analyze_model, analyze_model_par, decide, EwqConfig};
use ewq::exp::{self, ExpContext};
use ewq::fastewq::{load_or_build_dataset_pooled, FastEwq};
use ewq::par::Pool;
use ewq::report::Table;
use ewq::serving::Coordinator;
use ewq::zoo::ModelDir;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("exp") => cmd_exp(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("plan") => cmd_plan(&args),
        Some("dataset") => cmd_dataset(&args),
        Some("train-classifier") => cmd_train_classifier(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => bail!(
            "unknown command {other:?} (try: exp, analyze, plan, dataset, train-classifier, serve)"
        ),
        None => {
            println!("ewq — Entropy-Weighted Quantization (see README for usage)");
            println!("commands: exp | analyze | plan | dataset | train-classifier | serve");
            Ok(())
        }
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let per_subject = args.opt("per-subject", 8usize)?;
    let mut ctx = ExpContext::new(per_subject)?;
    let out = if id == "all" { exp::run_all(&mut ctx)? } else { exp::run(id, &mut ctx)? };
    println!("{out}");
    Ok(())
}

fn load_model(args: &Args) -> Result<ModelDir> {
    let name: String = args.opt("model", "tl-llama".to_string())?;
    ModelDir::load(ewq::artifacts_dir().join("models").join(&name))
        .with_context(|| format!("load model {name} (run `make artifacts`?)"))
}

/// `--workers N` (default: one per hardware thread; 1 = serial scan).
fn pool_from_args(args: &Args) -> Result<Pool> {
    let workers = args.opt("workers", ParallelConfig::auto().workers)?;
    Ok(Pool::from_config(&ParallelConfig::with_workers(workers)))
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let x = args.opt("x", 1.0f64)?;
    let pool = pool_from_args(args)?;
    let cfg = EwqConfig { x, ..Default::default() };
    let a = analyze_model_par(&model, &cfg, &pool);
    let plan = decide(&a, &cfg);
    let mut t = Table::new(
        &format!("EWQ analysis — {} (X={x})", model.schema.name),
        &["block", "exec_index", "entropy", "decision"],
    );
    for (b, &p) in a.blocks.iter().zip(&plan.assignments) {
        t.row(vec![
            b.block.to_string(),
            b.exec_index.to_string(),
            format!("{:.4}", b.entropy),
            p.label().into(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "mu={:.4} sigma={:.4} T={:.4} | {} | blocks {:.2} MB -> {:.2} MB",
        a.stats.mean,
        a.stats.std,
        a.stats.threshold(x),
        plan.summary(),
        model.schema.blocks_raw_bytes() as f64 / 1e6,
        plan.blocks_bytes(&model.schema) as f64 / 1e6,
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = load_model(args)?;
    let budget_mb = args.opt("budget-mb", 2.0f64)?;
    let machines = args.opt("machines", 2usize)?;
    let per = (budget_mb * 1e6 / machines as f64) as usize;
    let cluster = Cluster::uniform(machines, per, per);
    let a = analyze_model(&model, &EwqConfig::default());
    let d = optimize_distribution(&a, &model.schema, &cluster, &EwqConfig::default());
    println!(
        "cluster: {machines} x {:.2} MB (R = {:.2} MB)",
        per as f64 / 1e6,
        cluster.total_resources() as f64 / 1e6
    );
    println!("fits: {} | {}", d.fits, d.plan.summary());
    println!(
        "total {:.2} MB | placement {:?} | hops {} | +{} us/pass",
        d.total_bytes(&model.schema) as f64 / 1e6,
        d.placement,
        d.hops,
        d.network_latency_us(&cluster)
    );
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let rows = args.opt("rows", exp::context::DATASET_ROWS)?;
    let pool = pool_from_args(args)?;
    let artifacts = ewq::artifacts_dir();
    let flagships = ewq::zoo::load_flagships(&artifacts)?;
    let refs: Vec<&ModelDir> = flagships.iter().collect();
    let ds = load_or_build_dataset_pooled(
        &artifacts,
        rows,
        exp::context::DATASET_SEED,
        &refs,
        &EwqConfig::default(),
        &pool,
    )?;
    let q = ds.iter().filter(|r| r.quantized).count();
    println!(
        "dataset: {} rows ({} quantized / {} raw) -> {}",
        ds.len(),
        q,
        ds.len() - q,
        artifacts.join("fastewq_dataset.csv").display()
    );
    Ok(())
}

fn cmd_train_classifier(args: &Args) -> Result<()> {
    let artifacts = ewq::artifacts_dir();
    let out: String = args.opt("out", artifacts.join("fastewq.fewq").display().to_string())?;
    let pool = pool_from_args(args)?;
    let flagships = ewq::zoo::load_flagships(&artifacts)?;
    let refs: Vec<&ModelDir> = flagships.iter().collect();
    let rows = load_or_build_dataset_pooled(
        &artifacts,
        exp::context::DATASET_ROWS,
        exp::context::DATASET_SEED,
        &refs,
        &EwqConfig::default(),
        &pool,
    )?;
    let fe = FastEwq::train(&rows, 120, 8, 1);
    fe.save(std::path::Path::new(&out))?;
    println!("trained FastEWQ forest on {} rows -> {out}", rows.len());
    for m in &flagships {
        let mask = fe.classify_model(&m.schema);
        let sel: Vec<usize> =
            (0..mask.len()).filter(|&b| mask[b]).map(|b| m.schema.exec_index(b)).collect();
        println!("  {}: quantize exec_index {sel:?}", m.schema.name);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let variant: String = args.opt("variant", "8bit".to_string())?;
    let requests = args.opt("requests", 64usize)?;
    let batch = args.opt("batch", 8usize)?;
    let workers = args.opt("workers", 1usize)?;
    let dispatch: ewq::config::DispatchPolicy = args.opt("dispatch", Default::default())?;
    let decode_tokens = args.opt("decode-tokens", 0usize)?;
    let kv_precision: ewq::quant::Precision =
        args.opt("kv-precision", ewq::quant::Precision::Raw)?;
    let max_decode_batch =
        args.opt("max-decode-batch", ewq::config::ServeConfig::default().max_decode_batch)?;
    let kv_budget_mb = args.opt("kv-budget-mb", ewq::config::ServeConfig::default().kv_budget_mb)?;
    let max_queued_windows = args.opt("max-queued-windows", 0usize)?;
    let max_live_sequences = args.opt("max-live-seqs", 0usize)?;
    let default_deadline_ms = args.opt("deadline-ms", 0u64)?;
    let prefix_cache = match args.opt("prefix-cache", "on".to_string())?.as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => bail!("unknown --prefix-cache value {other} (on|off)"),
    };
    let requant = match args.opt("requant", "off".to_string())?.as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => bail!("unknown --requant value {other} (on|off)"),
    };
    let pin_workers = match args.opt("pin", "off".to_string())?.as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => bail!("unknown --pin value {other} (on|off)"),
    };
    let requant_low_mb =
        args.opt("requant-low-mb", ewq::config::ServeConfig::default().requant_low_mb)?;
    let requant_high_mb =
        args.opt("requant-high-mb", ewq::config::ServeConfig::default().requant_high_mb)?;
    // the trained forest gates eligibility when present; serving still
    // starts without it (all on-ladder blocks eligible)
    let requant_classifier =
        if requant { Some(ewq::artifacts_dir().join("fastewq.fewq")) } else { None };
    let cfg = ServeConfig {
        max_batch: batch,
        workers,
        dispatch,
        decode_tokens,
        kv_precision,
        max_decode_batch,
        kv_budget_mb,
        max_queued_windows,
        max_live_sequences,
        default_deadline_ms,
        prefix_cache,
        pin_workers,
        requant,
        requant_low_mb,
        requant_high_mb,
        requant_classifier,
        ..Default::default()
    };
    // fail fast on degenerate knobs, before any model or artifact work
    cfg.validate()?;
    let model = load_model(args)?;
    let n = model.schema.n_blocks;
    let plan = match variant.as_str() {
        "raw" => ewq::ewq::QuantPlan::uniform(&model.schema.name, n, ewq::quant::Precision::Raw),
        "8bit" => ewq::ewq::QuantPlan::uniform(&model.schema.name, n, ewq::quant::Precision::Q8),
        "4bit" => ewq::ewq::QuantPlan::uniform(&model.schema.name, n, ewq::quant::Precision::Q4),
        "mixed" => {
            let a = analyze_model(&model, &EwqConfig::default());
            decide(&a, &EwqConfig::default())
        }
        other => bail!("unknown variant {other} (raw|8bit|4bit|mixed)"),
    };
    println!(
        "serving {} [{}] with {workers} shard worker(s), {} dispatch — {}",
        model.schema.name,
        variant,
        dispatch.label(),
        plan.summary()
    );
    if decode_tokens > 1 {
        println!(
            "generation mode: {decode_tokens} tokens/request, {} kv cache, \
             decode batch <= {max_decode_batch}, prefix cache {}",
            kv_precision.label(),
            if prefix_cache { "on" } else { "off" },
        );
    }

    if pin_workers {
        println!("pinning: shard workers + forward pools on disjoint cores (best-effort)");
    }

    let vocab = model.schema.vocab as i32;
    if requant {
        println!(
            "requant: on (low {requant_low_mb} MB, high {requant_high_mb} MB, classifier {})",
            cfg.requant_classifier
                .as_ref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "none".into())
        );
    }
    let coord = Coordinator::start_with_model(model, plan, cfg, 1, 200)?;
    let mut rxs = Vec::new();
    for i in 0..requests {
        let ctx = vec![
            1 % vocab,
            (160 + (i as i32 % 16)) % vocab,
            (100 + (i as i32 % 57)) % vocab,
            2 % vocab,
        ];
        rxs.push(if decode_tokens > 1 {
            coord.submit_gen(ctx, decode_tokens)
        } else {
            coord.submit(ctx)
        });
    }
    let mut tokens_streamed = 0usize;
    for rx in rxs {
        tokens_streamed += rx.iter().count();
    }
    let m = coord.shutdown();
    if decode_tokens > 1 {
        println!("streamed {tokens_streamed} tokens across {requests} generation requests");
    }
    println!("{}", m.summary());
    Ok(())
}
