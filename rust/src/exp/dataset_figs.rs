//! Dataset- and classifier-side artifacts: Figures 2–6, Tables 2–5.
//! These need the 700-row FastEWQ dataset + the ML stack, not the runtime.

use anyhow::Result;

use crate::fastewq::rows_to_xy;
use crate::ml::{
    all_classifiers, auc, predict_all, proba_all, roc_curve, train_test_split,
    ClassificationReport, RandomForest, StandardScaler,
};
use crate::ml::Classifier;
use crate::quant::Precision;
use crate::report::{bar_chart, histogram, scatter, Table};
use crate::stats::pearson;

use super::context::ExpContext;

const SPLIT_SEED: u64 = 42;

/// Shared: 70:30 scaled split + the fitted scaler.
fn split_scaled(
    ctx: &mut ExpContext,
) -> Result<(Vec<Vec<f64>>, Vec<u8>, Vec<Vec<f64>>, Vec<u8>)> {
    let rows = ctx.dataset()?;
    let (x, y) = rows_to_xy(rows);
    let (xtr, ytr, xte, yte) = train_test_split(&x, &y, 0.3, SPLIT_SEED);
    let (scaler, xtr_s) = StandardScaler::fit_transform(&xtr);
    Ok((xtr_s, ytr, scaler.transform(&xte), yte))
}

/// Fig. 2 — feature distributions of the dataset.
pub fn fig2(ctx: &mut ExpContext) -> Result<String> {
    let rows = ctx.dataset()?;
    let nb: Vec<f64> = rows.iter().map(|r| r.num_blocks as f64).collect();
    let ei: Vec<f64> = rows.iter().map(|r| r.exec_index as f64).collect();
    let np: Vec<f64> = rows.iter().map(|r| r.num_parameters as f64).collect();
    let qz: Vec<f64> = rows.iter().map(|r| r.label() as f64).collect();
    let mut out = String::new();
    out.push_str(&histogram("num_blocks", &nb, 8, 50));
    out.push_str(&histogram("exec_index", &ei, 8, 50));
    out.push_str(&histogram("num_parameters", &np, 8, 50));
    out.push_str(&bar_chart(
        "quantized",
        &["0 (raw)".into(), "1 (quantized)".into()],
        &[
            qz.iter().filter(|&&v| v == 0.0).count() as f64,
            qz.iter().filter(|&&v| v == 1.0).count() as f64,
        ],
        50,
    ));
    Ok(out)
}

/// Fig. 3 — correlation matrix over features + label.
pub fn fig3(ctx: &mut ExpContext) -> Result<String> {
    let rows = ctx.dataset()?;
    let cols: [(&str, Vec<f64>); 4] = [
        ("num_blocks", rows.iter().map(|r| r.num_blocks as f64).collect()),
        ("exec_index", rows.iter().map(|r| r.exec_index as f64).collect()),
        ("num_parameters", rows.iter().map(|r| r.num_parameters as f64).collect()),
        ("quantized", rows.iter().map(|r| r.label() as f64).collect()),
    ];
    let mut t = Table::new(
        "Fig 3 — correlation matrix",
        &["", "num_blocks", "exec_index", "num_parameters", "quantized"],
    );
    for (name, a) in &cols {
        let mut cells = vec![name.to_string()];
        for (_, b) in &cols {
            cells.push(format!("{:+.3}", pearson(a, b)));
        }
        t.row(cells);
    }
    Ok(t.render())
}

/// Fig. 4 — quantization-type distribution ("pie chart" as counts).
pub fn fig4(ctx: &mut ExpContext) -> Result<String> {
    let rows = ctx.dataset()?;
    let count =
        |p: Precision| rows.iter().filter(|r| r.quantization_type == p).count() as f64;
    let raw = count(Precision::Raw);
    let q8 = count(Precision::Q8);
    let q4 = count(Precision::Q4);
    let total = rows.len() as f64;
    let mut out = bar_chart(
        "Fig 4 — quantization type distribution",
        &["raw".into(), "8-bit".into(), "4-bit".into()],
        &[raw, q8, q4],
        50,
    );
    out.push_str(&format!(
        "raw {:.1}% | 8bit {:.1}% | 4bit {:.1}%  (paper: 58% / 33% / 9% of 700)\n",
        100.0 * raw / total,
        100.0 * q8 / total,
        100.0 * q4 / total
    ));
    Ok(out)
}

/// Table 2 — illustrative dataset rows (first row per model family).
pub fn table2(ctx: &mut ExpContext) -> Result<String> {
    let rows = ctx.dataset()?;
    let mut t = Table::new(
        "Table 2 — example dataset rows",
        &["model_name", "num_blocks", "exec_index", "num_parameters", "quantization_type", "quantized"],
    );
    let mut seen = std::collections::BTreeSet::new();
    for r in rows {
        let family = r.model_name.rsplit_once('-').map(|(f, _)| f).unwrap_or(&r.model_name);
        if seen.insert(family.to_string()) {
            t.row(vec![
                r.model_name.clone(),
                r.num_blocks.to_string(),
                r.exec_index.to_string(),
                r.num_parameters.to_string(),
                r.quantization_type.label().to_string(),
                (r.quantized as u8).to_string(),
            ]);
        }
    }
    Ok(t.render())
}

/// Fig. 5 — random-forest feature importances.
pub fn fig5(ctx: &mut ExpContext) -> Result<String> {
    let (xtr, ytr, _, _) = split_scaled(ctx)?;
    let mut rf = RandomForest::new(120, 8, 1);
    rf.fit(&xtr, &ytr);
    let imp = rf.feature_importances();
    let labels: Vec<String> =
        crate::fastewq::FEATURES.iter().map(|s| s.to_string()).collect();
    let mut out = bar_chart("Fig 5 — RF feature importances", &labels, &imp, 50);
    out.push_str(&format!(
        "(paper: exec_index 66.4%, num_parameters 19.0%, num_blocks 14.6%)\n\
         ours:  num_parameters {:.1}%, exec_index {:.1}%, num_blocks {:.1}%\n",
        100.0 * imp[0],
        100.0 * imp[1],
        100.0 * imp[2]
    ));
    Ok(out)
}

/// Table 3 — classification report for all six classifiers.
pub fn table3(ctx: &mut ExpContext) -> Result<String> {
    let (xtr, ytr, xte, yte) = split_scaled(ctx)?;
    let mut t = Table::new(
        "Table 3 — classification report (70:30 split)",
        &["Classifier", "Class", "Precision", "Recall", "F1-Score", "Support"],
    );
    for mut c in all_classifiers(5) {
        c.fit(&xtr, &ytr);
        let pred = predict_all(c.as_ref(), &xte);
        let rep = ClassificationReport::from_predictions(&yte, &pred);
        for class in [0usize, 1usize] {
            let (p, r, f1, s) = rep.per_class[class];
            t.row(vec![
                c.name().into(),
                class.to_string(),
                format!("{p:.2}"),
                format!("{r:.2}"),
                format!("{f1:.2}"),
                s.to_string(),
            ]);
        }
        t.row(vec![
            c.name().into(),
            "Accuracy".into(),
            "-".into(),
            "-".into(),
            format!("{:.2}", rep.accuracy),
            yte.len().to_string(),
        ]);
        let (mp, mr, mf) = rep.macro_avg;
        t.row(vec![
            c.name().into(),
            "Macro avg".into(),
            format!("{mp:.2}"),
            format!("{mr:.2}"),
            format!("{mf:.2}"),
            yte.len().to_string(),
        ]);
        let (wp, wr, wf) = rep.weighted_avg;
        t.row(vec![
            c.name().into(),
            "Weighted avg".into(),
            format!("{wp:.2}"),
            format!("{wr:.2}"),
            format!("{wf:.2}"),
            yte.len().to_string(),
        ]);
    }
    Ok(t.render())
}

/// Table 4 — metric definitions (static).
pub fn table4() -> Result<String> {
    let mut t = Table::new("Table 4 — classification metrics", &["Metric", "Formula"]);
    for (m, f) in [
        ("Precision", "TP / (TP + FP)"),
        ("Recall", "TP / (TP + FN)"),
        ("F1 Score", "2 * P * R / (P + R)"),
        ("Accuracy", "(TP + TN) / total"),
        ("Macro Average", "mean over classes"),
        ("Weighted Average", "support-weighted mean over classes"),
    ] {
        t.row(vec![m.into(), f.into()]);
    }
    Ok(t.render())
}

/// Table 5 — confusion matrices.
pub fn table5(ctx: &mut ExpContext) -> Result<String> {
    let (xtr, ytr, xte, yte) = split_scaled(ctx)?;
    let mut t = Table::new(
        "Table 5 — confusion matrices",
        &["Classifier", "True Negative", "False Negative", "False Positive", "True Positive"],
    );
    for mut c in all_classifiers(5) {
        c.fit(&xtr, &ytr);
        let pred = predict_all(c.as_ref(), &xte);
        let cm = crate::ml::confusion(&yte, &pred);
        t.row(vec![
            c.name().into(),
            cm.tn.to_string(),
            cm.fn_.to_string(),
            cm.fp.to_string(),
            cm.tp.to_string(),
        ]);
    }
    Ok(t.render())
}

/// Fig. 6 — ROC curves + AUC per classifier.
pub fn fig6(ctx: &mut ExpContext) -> Result<String> {
    let (xtr, ytr, xte, yte) = split_scaled(ctx)?;
    let mut out = String::new();
    let mut aucs = Table::new("Fig 6 — AUC scores", &["Classifier", "AUC"]);
    for mut c in all_classifiers(5) {
        c.fit(&xtr, &ytr);
        let scores = proba_all(c.as_ref(), &xte);
        let pts = roc_curve(&yte, &scores);
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        out.push_str(&scatter(&format!("ROC — {}", c.name()), &xs, &ys, 10, 40));
        aucs.row(vec![c.name().into(), format!("{:.3}", auc(&yte, &scores))]);
    }
    out.push_str(&aucs.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    // dataset_figs drivers are exercised through the `exp::run` integration
    // tests (rust/tests/) because they need built artifacts; the pure pieces
    // (ml metrics, report rendering) are unit-tested in their own modules.
}
