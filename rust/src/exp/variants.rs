//! The nine evaluation variants of Tables 6/7/14 and how each builds its
//! `QuantPlan`.

use anyhow::Result;

use crate::ewq::{analyze_model, decide, EwqConfig, QuantPlan};
use crate::fastewq::FastEwq;
use crate::quant::Precision;
use crate::zoo::ModelDir;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Variant {
    Raw,
    Uniform4,
    Uniform8,
    Mixed8,
    Mixed48,
    Fast8,
    Fast48,
    FastTrain8,
    FastTrain48,
}

impl Variant {
    pub const ALL: [Variant; 9] = [
        Variant::Raw,
        Variant::Uniform4,
        Variant::Uniform8,
        Variant::Mixed8,
        Variant::Mixed48,
        Variant::Fast8,
        Variant::Fast48,
        Variant::FastTrain8,
        Variant::FastTrain48,
    ];

    /// Paper row labels.
    pub fn label(self) -> &'static str {
        match self {
            Variant::Raw => "raw",
            Variant::Uniform4 => "4bit",
            Variant::Uniform8 => "8bit",
            Variant::Mixed8 => "8bit mixed",
            Variant::Mixed48 => "4bit/8bit mixed",
            Variant::Fast8 => "fast 8bit mixed",
            Variant::Fast48 => "fast 4bit/8bit mixed",
            Variant::FastTrain8 => "fast train 8bit mixed",
            Variant::FastTrain48 => "fast train 4bit/8bit mixed",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        Variant::ALL.into_iter().find(|v| v.label() == s)
    }

    /// Analysis complexity column of Table 14.
    pub fn complexity(self) -> &'static str {
        match self {
            Variant::Raw => "-",
            Variant::Uniform4 | Variant::Uniform8 => "O(1)",
            Variant::Mixed8 | Variant::Mixed48 => "O(n)",
            _ => "O(1)",
        }
    }

    pub fn is_fast(self) -> bool {
        matches!(
            self,
            Variant::Fast8 | Variant::Fast48 | Variant::FastTrain8 | Variant::FastTrain48
        )
    }
}

/// FastEWQ mixed plan from a selection mask: selected blocks get 8-bit; in
/// the 4/8 variant the selected blocks with the HIGHEST exec_index drop to
/// 4-bit (the paper's "maximal compression for final transformer blocks",
/// §6.3 — their Table 8 shows exactly the tail block at 4-bit).
pub fn fast_plan(model: &str, selected: &[bool], four_bit_tail: bool) -> QuantPlan {
    let n = selected.len();
    let mut assignments: Vec<Precision> = selected
        .iter()
        .map(|&q| if q { Precision::Q8 } else { Precision::Raw })
        .collect();
    if four_bit_tail {
        let n_sel = selected.iter().filter(|&&q| q).count();
        let n_q4 = (n_sel / 12).max(1);
        let mut demoted = 0;
        for b in (0..n).rev() {
            if selected[b] {
                assignments[b] = Precision::Q4;
                demoted += 1;
                if demoted >= n_q4 {
                    break;
                }
            }
        }
    }
    QuantPlan { model: model.into(), assignments, priority: (0..n).rev().collect() }
}

/// Build the plan for a variant. `fast_full`/`fast_train` are the FastEWQ
/// classifiers trained on 100% / 70% of the dataset.
pub fn plan_for(
    variant: Variant,
    model: &ModelDir,
    fast_full: &FastEwq,
    fast_train: &FastEwq,
) -> Result<QuantPlan> {
    let n = model.schema.n_blocks;
    let name = &model.schema.name;
    Ok(match variant {
        Variant::Raw => QuantPlan::uniform(name, n, Precision::Raw),
        Variant::Uniform4 => QuantPlan::uniform(name, n, Precision::Q4),
        Variant::Uniform8 => QuantPlan::uniform(name, n, Precision::Q8),
        Variant::Mixed8 => {
            let a = analyze_model(model, &EwqConfig::mixed8());
            decide(&a, &EwqConfig::mixed8())
        }
        Variant::Mixed48 => {
            let a = analyze_model(model, &EwqConfig::default());
            decide(&a, &EwqConfig::default())
        }
        Variant::Fast8 => fast_plan(name, &fast_full.classify_model(&model.schema), false),
        Variant::Fast48 => fast_plan(name, &fast_full.classify_model(&model.schema), true),
        Variant::FastTrain8 => {
            fast_plan(name, &fast_train.classify_model(&model.schema), false)
        }
        Variant::FastTrain48 => {
            fast_plan(name, &fast_train.classify_model(&model.schema), true)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::from_label(v.label()), Some(v));
        }
        assert_eq!(Variant::from_label("nope"), None);
    }

    #[test]
    fn fast_plan_shapes() {
        let sel = vec![true, false, true, true, false, true];
        let p8 = fast_plan("m", &sel, false);
        assert_eq!(p8.counts().0, 2); // raw = unselected
        assert_eq!(p8.counts().1, 4); // q8 = selected
        let p48 = fast_plan("m", &sel, true);
        let (raw, q8, q4, ..) = p48.counts();
        assert_eq!(raw, 2);
        assert_eq!(q4, 1, "one tail block at 4-bit");
        assert_eq!(q8, 3);
        // the 4-bit block is the selected block with the highest index
        assert_eq!(p48.assignments[5], Precision::Q4);
    }

    #[test]
    fn fast_plan_scales_q4_count() {
        let sel = vec![true; 26];
        let p = fast_plan("m", &sel, true);
        assert_eq!(p.counts().2, 2); // 26/12 = 2 tail blocks
    }

    #[test]
    fn complexity_labels() {
        assert_eq!(Variant::Mixed48.complexity(), "O(n)");
        assert_eq!(Variant::Fast48.complexity(), "O(1)");
        assert!(Variant::Fast8.is_fast());
        assert!(!Variant::Mixed8.is_fast());
    }
}
