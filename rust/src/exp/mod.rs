//! Experiment drivers: one entry per paper table/figure (`ewq exp <id>`).
//! Each driver renders its artifact to stdout AND persists it under
//! `artifacts/reports/<id>.txt` so EXPERIMENTS.md can reference stable runs.

pub mod context;
pub mod dataset_figs;
pub mod model_tables;
pub mod variants;

use anyhow::{bail, Result};

pub use context::ExpContext;
pub use variants::Variant;

/// Every regenerable experiment id, in paper order.
pub const ALL_IDS: [&str; 20] = [
    "fig1", "table1", "fig2", "fig3", "fig4", "table2", "fig5", "table3", "table4", "table5",
    "fig6", "table6", "table7", "table8", "table9", "table10", "fig7", "table13", "table14",
    "alg1",
];

/// Run one experiment (or `all`). Returns the rendered report.
pub fn run(id: &str, ctx: &mut ExpContext) -> Result<String> {
    let out = match id {
        "fig1" => model_tables::fig1(ctx)?,
        "table1" => model_tables::table1(ctx)?,
        "fig2" => dataset_figs::fig2(ctx)?,
        "fig3" => dataset_figs::fig3(ctx)?,
        "fig4" => dataset_figs::fig4(ctx)?,
        "table2" => dataset_figs::table2(ctx)?,
        "fig5" => dataset_figs::fig5(ctx)?,
        "table3" => dataset_figs::table3(ctx)?,
        "table4" => dataset_figs::table4()?,
        "table5" => dataset_figs::table5(ctx)?,
        "fig6" => dataset_figs::fig6(ctx)?,
        "table6" => model_tables::table6(ctx)?,
        "table7" => model_tables::table7(ctx)?,
        "table8" => model_tables::table8(ctx)?,
        "table9" => model_tables::table9(ctx)?,
        "table10" => model_tables::table10(ctx)?,
        "fig7" => model_tables::fig7(ctx)?,
        "table13" => model_tables::table13(ctx)?,
        "table14" => model_tables::table14(ctx)?,
        "alg1" => model_tables::alg1(ctx)?,
        other => bail!("unknown experiment id {other:?}; known: {ALL_IDS:?} or `all`"),
    };
    persist(ctx, id, &out)?;
    Ok(out)
}

pub fn run_all(ctx: &mut ExpContext) -> Result<String> {
    let mut full = String::new();
    for id in ALL_IDS {
        eprintln!("== running {id} ==");
        let out = run(id, ctx)?;
        full.push_str(&format!("\n################ {id} ################\n"));
        full.push_str(&out);
    }
    Ok(full)
}

fn persist(ctx: &ExpContext, id: &str, out: &str) -> Result<()> {
    // quick runs (tiny question budgets, e.g. the test suite) must not
    // clobber the canonical full-budget reports
    let dir = if ctx.per_subject >= 4 {
        ctx.artifacts.join("reports")
    } else {
        ctx.artifacts.join("reports/quick")
    };
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{id}.txt")), out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_ids_unique() {
        let mut ids = super::ALL_IDS.to_vec();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), super::ALL_IDS.len());
    }
}
