//! Shared experiment context: artifacts, flagship models, the FastEWQ
//! dataset + classifiers, and a persistent cache of per-(model, variant)
//! evaluation results so tables 6/7/10/13/14 and fig. 7 don't re-run the
//! expensive MMLU sweep.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context as _, Result};

use crate::eval::{build_questions, evaluate, FactTable, Question};
use crate::ewq::EwqConfig;
use crate::fastewq::{load_or_build_dataset, DatasetRow, FastEwq};
use crate::model::{ModelExecutor, QuantizedModel};
use crate::runtime::Runtime;
use crate::zoo::{load_flagships, ModelDir};

use super::variants::{plan_for, Variant};

pub const DATASET_ROWS: usize = 700;
pub const DATASET_SEED: u64 = 2025;
pub const QUESTION_SEED: u64 = 4242;

/// Cached evaluation record for one (model, variant).
#[derive(Clone, Debug)]
pub struct VariantResult {
    pub model: String,
    pub variant: Variant,
    pub accuracy: f64,
    pub perplexity: f64,
    pub blocks_bytes: usize,
    pub total_bytes: usize,
    pub n_raw: usize,
    pub n_q8: usize,
    pub n_q4: usize,
}

impl VariantResult {
    pub fn blocks_mb(&self) -> f64 {
        self.blocks_bytes as f64 / 1e6
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes as f64 / 1e6
    }
}

pub struct ExpContext {
    pub artifacts: PathBuf,
    pub flagships: Vec<ModelDir>,
    pub facts: FactTable,
    pub per_subject: usize,
    dataset: Option<Vec<DatasetRow>>,
    /// Lazily initialized via [`Self::fast_full`] / [`Self::fast_train`] /
    /// [`Self::runtime`]; public so benches/examples can borrow immutably
    /// after initialization.
    pub fast_full: Option<FastEwq>,
    pub fast_train: Option<FastEwq>,
    pub runtime: Option<Runtime>,
    eval_cache: BTreeMap<(String, Variant), VariantResult>,
}

impl ExpContext {
    pub fn new(per_subject: usize) -> Result<Self> {
        let artifacts = crate::artifacts_dir();
        let flagships = load_flagships(&artifacts)
            .context("load flagship models — run `make artifacts` first")?;
        let facts = FactTable::load(&artifacts.join("corpus/facts.txt"))?;
        let mut ctx = Self {
            artifacts,
            flagships,
            facts,
            per_subject,
            dataset: None,
            fast_full: None,
            fast_train: None,
            runtime: None,
            eval_cache: BTreeMap::new(),
        };
        ctx.load_eval_cache()?;
        Ok(ctx)
    }

    pub fn flagship(&self, name: &str) -> Result<&ModelDir> {
        self.flagships
            .iter()
            .find(|m| m.schema.name == name)
            .with_context(|| format!("unknown flagship {name}"))
    }

    pub fn runtime(&mut self) -> Result<&Runtime> {
        if self.runtime.is_none() {
            self.runtime = Some(Runtime::cpu()?);
        }
        Ok(self.runtime.as_ref().unwrap())
    }

    pub fn questions(&self) -> Vec<Question> {
        build_questions(&self.facts, self.per_subject, QUESTION_SEED)
    }

    /// The 700-row FastEWQ dataset (cached on disk).
    pub fn dataset(&mut self) -> Result<&[DatasetRow]> {
        if self.dataset.is_none() {
            let flagships: Vec<&ModelDir> = self.flagships.iter().collect();
            self.dataset = Some(load_or_build_dataset(
                &self.artifacts,
                DATASET_ROWS,
                DATASET_SEED,
                &flagships,
                &EwqConfig::default(),
            )?);
        }
        Ok(self.dataset.as_ref().unwrap())
    }

    /// FastEWQ classifier trained on 100% of the dataset ("fast": the
    /// paper's overfitted centralized variant, 99% train accuracy).
    pub fn fast_full(&mut self) -> Result<&FastEwq> {
        if self.fast_full.is_none() {
            let rows = self.dataset()?.to_vec();
            self.fast_full = Some(FastEwq::train(&rows, 200, 16, 11));
        }
        Ok(self.fast_full.as_ref().unwrap())
    }

    /// FastEWQ classifier trained on a 70% split ("fast train").
    pub fn fast_train(&mut self) -> Result<&FastEwq> {
        if self.fast_train.is_none() {
            let rows = self.dataset()?.to_vec();
            let (x, y) = crate::fastewq::rows_to_xy(&rows);
            let (xtr, ytr, _, _) = crate::ml::train_test_split(&x, &y, 0.3, 42);
            // rebuild rows from split indices is awkward; train directly
            let (scaler, xs) = crate::ml::StandardScaler::fit_transform(&xtr);
            let mut forest = crate::ml::RandomForest::new(120, 8, 1);
            use crate::ml::Classifier;
            forest.fit(&xs, &ytr);
            self.fast_train = Some(FastEwq { scaler, forest });
        }
        Ok(self.fast_train.as_ref().unwrap())
    }

    // ---- eval cache ----------------------------------------------------------
    fn cache_path(&self) -> PathBuf {
        self.artifacts.join(format!("eval_cache_ps{}.csv", self.per_subject))
    }

    fn load_eval_cache(&mut self) -> Result<()> {
        let p = self.cache_path();
        if !p.exists() {
            return Ok(());
        }
        for line in std::fs::read_to_string(&p)?.lines().skip(1) {
            let f: Vec<&str> = line.split(';').collect();
            if f.len() != 9 {
                continue;
            }
            let Some(variant) = Variant::from_label(f[1]) else { continue };
            let r = VariantResult {
                model: f[0].to_string(),
                variant,
                accuracy: f[2].parse()?,
                perplexity: f[3].parse()?,
                blocks_bytes: f[4].parse()?,
                total_bytes: f[5].parse()?,
                n_raw: f[6].parse()?,
                n_q8: f[7].parse()?,
                n_q4: f[8].parse()?,
            };
            self.eval_cache.insert((r.model.clone(), variant), r);
        }
        Ok(())
    }

    fn save_eval_cache(&self) -> Result<()> {
        let mut s = String::from(
            "model;variant;accuracy;perplexity;blocks_bytes;total_bytes;n_raw;n_q8;n_q4\n",
        );
        for r in self.eval_cache.values() {
            s.push_str(&format!(
                "{};{};{:.6};{:.6};{};{};{};{};{}\n",
                r.model,
                r.variant.label(),
                r.accuracy,
                r.perplexity,
                r.blocks_bytes,
                r.total_bytes,
                r.n_raw,
                r.n_q8,
                r.n_q4
            ));
        }
        std::fs::write(self.cache_path(), s)?;
        Ok(())
    }

    /// Evaluate (or fetch cached) one model × variant.
    pub fn eval_variant(&mut self, model_name: &str, variant: Variant) -> Result<VariantResult> {
        let key = (model_name.to_string(), variant);
        if let Some(r) = self.eval_cache.get(&key) {
            return Ok(r.clone());
        }
        // prerequisites first (mutable borrows)
        self.fast_full()?;
        self.fast_train()?;
        self.runtime()?;
        let questions = self.questions();

        let model = self.flagships.iter().find(|m| m.schema.name == model_name).unwrap();
        let plan =
            plan_for(variant, model, self.fast_full.as_ref().unwrap(), self.fast_train.as_ref().unwrap())?;
        let qm = QuantizedModel::build(model, &plan)?;
        let rt = self.runtime.as_ref().unwrap();
        let ex = ModelExecutor::new(rt, model);
        eprintln!("  evaluating {model_name} / {} ...", variant.label());
        let e = evaluate(&ex, &qm, &questions)?;
        let (n_raw, n_q8, n_q4, _, _) = plan.counts();
        let r = VariantResult {
            model: model_name.to_string(),
            variant,
            accuracy: e.accuracy,
            perplexity: e.perplexity,
            blocks_bytes: plan.blocks_bytes(&model.schema),
            total_bytes: plan.total_bytes(&model.schema),
            n_raw,
            n_q8,
            n_q4,
        };
        self.eval_cache.insert(key, r.clone());
        self.save_eval_cache()?;
        Ok(r)
    }

    /// All nine variants for all four flagships (Tables 6/7/14 backbone).
    pub fn eval_all(&mut self) -> Result<Vec<VariantResult>> {
        let names: Vec<String> =
            self.flagships.iter().map(|m| m.schema.name.clone()).collect();
        let mut out = Vec::new();
        for name in names {
            for v in Variant::ALL {
                out.push(self.eval_variant(&name, v)?);
            }
        }
        Ok(out)
    }
}
