//! Model-side artifacts: Fig. 1/7, Tables 1/6/7/8/9/10/13/14 and the
//! Algorithm-1 walkthrough. These run the AOT executables via PJRT.

use anyhow::Result;

use crate::cluster::{optimize_distribution, Cluster};
use crate::eval::similarity::{answer_consistency, answer_similarity};
use crate::eval::evaluate;
use crate::ewq::{analyze_model, decide, EwqConfig, QuantPlan};
use crate::model::{ModelExecutor, QuantizedModel};
use crate::quant::Precision;
use crate::report::{scatter, Table};
use crate::rng::Xoshiro256pp;
use crate::stats::{cohens_d, composite_score, effect_size_label, paired_t_test};
use crate::zoo::FLAGSHIPS;

use super::context::{ExpContext, VariantResult};
use super::variants::Variant;

fn mb(bytes: usize) -> f64 {
    bytes as f64 / 1e6
}

/// Fig. 1 — entropy distribution across blocks (paper shows
/// Meta-Llama-3.1-8B; we show tl-llama plus the μ and T = μ−σ lines).
pub fn fig1(ctx: &mut ExpContext) -> Result<String> {
    let mut out = String::new();
    for name in FLAGSHIPS {
        let model = ctx.flagship(name)?;
        let a = analyze_model(model, &EwqConfig::default());
        let xs: Vec<f64> = a.blocks.iter().map(|b| b.exec_index as f64).collect();
        let ys: Vec<f64> = a.blocks.iter().map(|b| b.entropy).collect();
        out.push_str(&scatter(&format!("Fig 1 — entropy by block ({name})"), &xs, &ys, 10, 60));
        out.push_str(&format!(
            "mu = {:.4}, sigma = {:.4}, T = mu - sigma = {:.4}\n",
            a.stats.mean,
            a.stats.std,
            a.stats.threshold(1.0)
        ));
        let mut t = Table::new("", &["exec_index", "entropy", "band"]);
        for b in &a.blocks {
            let band = if b.entropy <= a.stats.threshold(1.0) {
                "<=T (aggressive)"
            } else if b.entropy <= a.stats.mean {
                "<=mu (8-bit)"
            } else {
                ">mu (raw)"
            };
            t.row(vec![b.exec_index.to_string(), format!("{:.4}", b.entropy), band.into()]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    Ok(out)
}

/// Table 1 — early QA benchmark: random 60/40 mixed vs uniform 8-bit vs
/// uniform 4-bit, scored by answer similarity + consistency vs raw.
pub fn table1(ctx: &mut ExpContext) -> Result<String> {
    let questions = ctx.questions();
    ctx.runtime()?;
    let model = ctx.flagships.iter().find(|m| m.schema.name == "tl-gemma").unwrap();
    let n = model.schema.n_blocks;
    let rt = ctx.runtime.as_ref().unwrap();
    let ex = ModelExecutor::new(rt, model);

    // 60% 8-bit / 40% 4-bit assigned RANDOMLY (the paper's initial probe
    // predates the entropy criterion)
    let mut rng = Xoshiro256pp::new(7);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let cut = (n as f64 * 0.6).round() as usize;
    let mut mixed = QuantPlan::uniform("tl-gemma", n, Precision::Q8);
    for &b in &idx[cut..] {
        mixed.assignments[b] = Precision::Q4;
    }

    let plans = [
        ("Mixed Precision (8-bit: 60%, 4-bit: 40%)", mixed),
        ("Fully 8-bit Quantization", QuantPlan::uniform("tl-gemma", n, Precision::Q8)),
        ("Fully 4-bit Quantization", QuantPlan::uniform("tl-gemma", n, Precision::Q4)),
    ];

    let raw_plan = QuantPlan::uniform("tl-gemma", n, Precision::Raw);
    let raw = evaluate(&ex, &QuantizedModel::build(model, &raw_plan)?, &questions)?;

    let mut t = Table::new(
        "Table 1 — QA benchmark (similarity/consistency vs raw reference)",
        &["Configuration", "Similarity", "Consistency", "Accuracy"],
    );
    for (label, plan) in plans {
        let e = evaluate(&ex, &QuantizedModel::build(model, &plan)?, &questions)?;
        let sim = answer_similarity(&e.choice_probs, &raw.choice_probs);
        let cons = answer_consistency(&e.choice_probs, 0.7, 3, 99);
        t.row(vec![
            label.into(),
            format!("{:.0}%", 100.0 * sim),
            format!("{:.0}%", 100.0 * cons),
            format!("{:.4}", e.accuracy),
        ]);
    }
    Ok(t.render())
}

fn result_row(r: &VariantResult) -> Vec<String> {
    vec![
        r.model.clone(),
        r.variant.label().into(),
        format!("{:.4}", r.accuracy),
        format!("{:.4}", r.perplexity),
        format!("{:.2} / {:.2}", r.blocks_mb(), r.total_mb()),
        format!("{}/{}/{}", r.n_raw, r.n_q8, r.n_q4),
    ]
}

/// Table 6 — EWQ variants × flagships.
pub fn table6(ctx: &mut ExpContext) -> Result<String> {
    let mut t = Table::new(
        "Table 6 — model performance and size (EWQ)",
        &["Model", "Variant", "Accuracy", "Perplexity", "Blocks / Total (MB)", "raw / 8bit / 4bit"],
    );
    for name in FLAGSHIPS {
        for v in [Variant::Raw, Variant::Uniform4, Variant::Uniform8, Variant::Mixed8, Variant::Mixed48]
        {
            let r = ctx.eval_variant(name, v)?;
            t.row(result_row(&r));
        }
    }
    Ok(t.render())
}

/// Table 7 — FastEWQ variants × flagships (EWQ mixed rows repeated for
/// comparison, like the paper).
pub fn table7(ctx: &mut ExpContext) -> Result<String> {
    let mut t = Table::new(
        "Table 7 — model performance and size (FastEWQ)",
        &["Model", "Variant", "Accuracy", "Perplexity", "Blocks / Total (MB)", "raw / 8bit / 4bit"],
    );
    for name in FLAGSHIPS {
        for v in [
            Variant::Mixed8,
            Variant::Mixed48,
            Variant::Fast8,
            Variant::Fast48,
            Variant::FastTrain8,
            Variant::FastTrain48,
        ] {
            let r = ctx.eval_variant(name, v)?;
            t.row(result_row(&r));
        }
    }
    Ok(t.render())
}

/// Table 8 — which blocks each method selects, by exec_index.
pub fn table8(ctx: &mut ExpContext) -> Result<String> {
    ctx.fast_full()?;
    ctx.fast_train()?;
    let mut t = Table::new(
        "Table 8 — blocks selected for quantization (by exec_index, priority order)",
        &["Model", "Variant", "Quantization by exec_index", "4bit blocks", "Total"],
    );
    for name in FLAGSHIPS {
        let model = ctx.flagships.iter().find(|m| m.schema.name == name).unwrap();
        let schema = &model.schema;
        let a = analyze_model(model, &EwqConfig::default());
        let ewq_plan = decide(&a, &EwqConfig::default());

        // EWQ: priority = ascending entropy, selected = quantized blocks
        let sel_order: Vec<usize> = ewq_plan
            .priority
            .iter()
            .filter(|&&b| ewq_plan.assignments[b] != Precision::Raw)
            .map(|&b| schema.exec_index(b))
            .collect();
        let q4: Vec<usize> = ewq_plan
            .assignments
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == Precision::Q4)
            .map(|(b, _)| schema.exec_index(b))
            .collect();
        let fmt = |v: &[usize]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
        };
        t.row(vec![
            name.into(),
            "ewq".into(),
            fmt(&sel_order),
            fmt(&q4),
            sel_order.len().to_string(),
        ]);

        for (label, fe) in [
            ("fast", ctx.fast_full.as_ref().unwrap()),
            ("fast train", ctx.fast_train.as_ref().unwrap()),
        ] {
            let mask = fe.classify_model(schema);
            let plan = super::variants::fast_plan(name, &mask, true);
            // fast priority: descending exec_index among selected
            let mut sel: Vec<usize> = (0..schema.n_blocks)
                .filter(|&b| mask[b])
                .map(|b| schema.exec_index(b))
                .collect();
            sel.sort_unstable_by(|x, y| y.cmp(x));
            let q4: Vec<usize> = plan
                .assignments
                .iter()
                .enumerate()
                .filter(|(_, &p)| p == Precision::Q4)
                .map(|(b, _)| schema.exec_index(b))
                .collect();
            t.row(vec![
                name.into(),
                label.into(),
                fmt(&sel),
                fmt(&q4),
                sel.len().to_string(),
            ]);
        }
    }
    Ok(t.render())
}

/// Table 9 — average block size by precision.
pub fn table9(ctx: &mut ExpContext) -> Result<String> {
    let mut t = Table::new(
        "Table 9 — average transformer block size (MB)",
        &["Model", "Blocks", "raw", "8bit", "4bit", "1.58bit"],
    );
    for name in FLAGSHIPS {
        let schema = &ctx.flagship(name)?.schema;
        let avg = |p: Precision| {
            let mats: usize =
                schema.mat_shapes().iter().map(|&(k, n)| p.matrix_bytes(k, n)).sum();
            mb(mats + 4 * 2 * schema.d_model)
        };
        t.row(vec![
            name.into(),
            schema.n_blocks.to_string(),
            format!("{:.4}", avg(Precision::Raw)),
            format!("{:.4}", avg(Precision::Q8)),
            format!("{:.4}", avg(Precision::Q4)),
            format!("{:.4}", avg(Precision::T2)),
        ]);
    }
    Ok(t.render())
}

const FAST_VARIANTS: [Variant; 4] =
    [Variant::Fast8, Variant::Fast48, Variant::FastTrain8, Variant::FastTrain48];

fn composite_inputs(ctx: &mut ExpContext) -> Result<Vec<(Variant, Vec<f64>, Vec<f64>)>> {
    let mut out = Vec::new();
    for v in FAST_VARIANTS {
        let mut accs = Vec::new();
        let mut ppls = Vec::new();
        for name in FLAGSHIPS {
            let r = ctx.eval_variant(name, v)?;
            accs.push(r.accuracy);
            ppls.push(r.perplexity);
        }
        out.push((v, accs, ppls));
    }
    Ok(out)
}

/// Table 10 — composite-score inputs.
pub fn table10(ctx: &mut ExpContext) -> Result<String> {
    let data = composite_inputs(ctx)?;
    let mut t = Table::new(
        "Table 10 — composite score inputs (per flagship, order: llama/qwen/gemma/phi)",
        &["Variant", "Accuracy", "Perplexity"],
    );
    for (v, accs, ppls) in &data {
        t.row(vec![
            v.label().into(),
            accs.iter().map(|a| format!("{a:.4}")).collect::<Vec<_>>().join(", "),
            ppls.iter().map(|p| format!("{p:.4}")).collect::<Vec<_>>().join(", "),
        ]);
    }
    Ok(t.render())
}

fn composites(accs: &[f64], ppls: &[f64]) -> Vec<f64> {
    accs.iter().zip(ppls).map(|(&a, &p)| composite_score(p, a, 1.0, 1.0)).collect()
}

/// Fig. 7 — composite-score comparison across classifiers.
pub fn fig7(ctx: &mut ExpContext) -> Result<String> {
    let data = composite_inputs(ctx)?;
    let mut out = String::new();
    let mut t = Table::new(
        "Fig 7 — composite scores (w1*ln(ppl) - w2*acc) per flagship",
        &["Variant", "tl-llama", "tl-qwen", "tl-gemma", "tl-phi"],
    );
    for (v, accs, ppls) in &data {
        let cs = composites(accs, ppls);
        t.row(
            std::iter::once(v.label().to_string())
                .chain(cs.iter().map(|c| format!("{c:.4}")))
                .collect(),
        );
        let xs: Vec<f64> = (0..cs.len()).map(|i| i as f64).collect();
        out.push_str(&scatter(&format!("composite — {}", v.label()), &xs, &cs, 6, 40));
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Table 13 — paired t-test + Cohen's d between classifier variants.
pub fn table13(ctx: &mut ExpContext) -> Result<String> {
    let data = composite_inputs(ctx)?;
    let get = |v: Variant| -> Vec<f64> {
        let (_, accs, ppls) = data.iter().find(|(x, ..)| *x == v).unwrap();
        composites(accs, ppls)
    };
    let pairs = [
        ("fast: 8bit vs 4bit/8bit", Variant::Fast8, Variant::Fast48),
        ("fast train: 8bit vs 4bit/8bit", Variant::FastTrain8, Variant::FastTrain48),
        ("fast vs fast train (8bit)", Variant::Fast8, Variant::FastTrain8),
        ("fast vs fast train (4/8 mixed)", Variant::Fast48, Variant::FastTrain48),
    ];
    let mut t = Table::new(
        "Table 13 — statistical comparison of composite scores",
        &["Comparison", "Abs Diff", "t-statistic", "p-value / significance", "Cohen's d / effect"],
    );
    for (label, a, b) in pairs {
        let ca = get(a);
        let cb = get(b);
        let tt = paired_t_test(&ca, &cb);
        let d = cohens_d(&ca, &cb);
        let abs_diff = ca
            .iter()
            .zip(&cb)
            .map(|(x, y)| (x - y).abs())
            .sum::<f64>()
            / ca.len() as f64;
        t.row(vec![
            label.into(),
            format!("{abs_diff:.4}"),
            format!("{:.4}", tt.t),
            format!("{:.4} / {}", tt.p, tt.significance()),
            format!("{:.4} / {}", d, effect_size_label(d)),
        ]);
    }
    Ok(t.render())
}

/// Table 14 — summary: relative Δaccuracy/Δperplexity/Δsize + analysis
/// complexity, with measured EWQ-vs-FastEWQ analysis wallclock.
pub fn table14(ctx: &mut ExpContext) -> Result<String> {
    let mut t = Table::new(
        "Table 14 — MMLU performance vs model size across quantization methods",
        &["Model", "Variant", "Accuracy", "Perplexity", "Size / Total (MB)", "Complexity"],
    );
    for name in FLAGSHIPS {
        let raw = ctx.eval_variant(name, Variant::Raw)?;
        t.row(vec![
            name.into(),
            "raw".into(),
            format!("{:.4}", raw.accuracy),
            format!("{:.4}", raw.perplexity),
            format!("{:.2}", raw.total_mb()),
            "-".into(),
        ]);
        for v in Variant::ALL.into_iter().skip(1) {
            let r = ctx.eval_variant(name, v)?;
            t.row(vec![
                name.into(),
                v.label().into(),
                crate::report::pct((r.accuracy - raw.accuracy) / raw.accuracy),
                crate::report::pct((r.perplexity - raw.perplexity) / raw.perplexity),
                format!(
                    "{} / {:.2}",
                    crate::report::pct((r.total_mb() - raw.total_mb()) / raw.total_mb()),
                    r.total_mb()
                ),
                v.complexity().into(),
            ]);
        }
    }
    let mut out = t.render();

    // measured complexity: O(n) entropy scan vs O(1) classifier
    ctx.fast_full()?;
    let model = ctx.flagships.iter().find(|m| m.schema.name == "tl-llama").unwrap();
    let t0 = std::time::Instant::now();
    let _ = analyze_model(model, &EwqConfig::default());
    let ewq_time = t0.elapsed();
    let fe = ctx.fast_full.as_ref().unwrap();
    let t0 = std::time::Instant::now();
    let _ = fe.classify_model(&model.schema);
    let fast_time = t0.elapsed();
    let params = (model.schema.block_params() * model.schema.n_blocks) as f64;
    let scan_rate = params / ewq_time.as_secs_f64(); // params/s
    out.push_str(&format!(
        "\nMeasured analysis time (tl-llama): EWQ O(n) = {ewq_time:?}, FastEWQ O(1) = {fast_time:?} \
         (speedup {:.0}x; paper claims >=100x).\n\
         EWQ scan rate {:.0} Mparam/s -> extrapolated 8B-param model: {:.1} s scan vs \
         {fast_time:?} classify ({:.0}x).\n",
        ewq_time.as_secs_f64() / fast_time.as_secs_f64().max(1e-12),
        scan_rate / 1e6,
        8e9 / scan_rate,
        (8e9 / scan_rate) / fast_time.as_secs_f64().max(1e-12)
    ));
    Ok(out)
}

/// Algorithm-1 walkthrough over three cluster scenarios.
pub fn alg1(ctx: &mut ExpContext) -> Result<String> {
    let model = ctx.flagship("tl-llama")?;
    let schema = &model.schema;
    let a = analyze_model(model, &EwqConfig::default());
    let raw_total = schema.total_raw_bytes();

    let scenarios = [
        ("plentiful (2x raw)", Cluster::uniform(2, raw_total, raw_total)),
        (
            "tight (85% of raw across 3 machines)",
            Cluster::uniform(3, raw_total * 85 / 300, raw_total * 85 / 300),
        ),
        (
            "starved (30% of raw on 1 machine)",
            Cluster::uniform(1, raw_total * 30 / 100, raw_total * 30 / 100),
        ),
    ];

    let mut t = Table::new(
        "Algorithm 1 — optimized distribution (tl-llama)",
        &["Scenario", "R (MB)", "fits", "raw/8/4/3/1.58", "total (MB)", "hops", "net (us)"],
    );
    for (label, cluster) in scenarios {
        let d = optimize_distribution(&a, schema, &cluster, &EwqConfig::default());
        let (r, q8, q4, q3, t2) = d.plan.counts();
        t.row(vec![
            label.into(),
            format!("{:.2}", mb(cluster.total_resources())),
            d.fits.to_string(),
            format!("{r}/{q8}/{q4}/{q3}/{t2}"),
            format!("{:.2}", mb(d.total_bytes(schema))),
            d.hops.to_string(),
            d.network_latency_us(&cluster).to_string(),
        ]);
    }
    Ok(t.render())
}
