//! Deterministic RNG utilities (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` seeds `Xoshiro256pp`, the general-purpose generator used by
//! every stochastic component (dataset builder, classifiers, serving traces,
//! property tests). All experiments are reproducible from fixed seeds.

/// SplitMix64 — used for seeding and cheap stateless hashing.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (k <= n), in shuffled order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Bootstrap sample: n indices drawn with replacement from [0, n).
    pub fn bootstrap(&mut self, n: usize) -> Vec<usize> {
        (0..n).map(|_| self.below(n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256pp::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256pp::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256pp::new(15);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn bootstrap_len_and_range() {
        let mut r = Xoshiro256pp::new(17);
        let b = r.bootstrap(64);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&i| i < 64));
    }
}
