//! Quantization error metrics (used by the ablation benches and the
//! §2.5 "case for mixed quantization" analysis).

use crate::tensor::Tensor;

/// Mean squared error between two equally-shaped tensors.
pub fn mse(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape, b.shape);
    let n = a.numel() as f64;
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / n
}

/// Signal-to-quantization-noise ratio in dB: 10·log10(Σx² / Σ(x−x̂)²).
pub fn sqnr_db(orig: &Tensor, deq: &Tensor) -> f64 {
    assert_eq!(orig.shape, deq.shape);
    let sig: f64 = orig.data.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let noise: f64 = orig
        .data
        .iter()
        .zip(&deq.data)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    if noise == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (sig / noise).log10()
    }
}

/// Max absolute error.
pub fn max_abs_err(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape, b.shape);
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| ((x - y) as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, quantize, Precision};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn identical_tensors_zero_error() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.0, 0.5]);
        assert_eq!(mse(&t, &t), 0.0);
        assert_eq!(max_abs_err(&t, &t), 0.0);
        assert!(sqnr_db(&t, &t).is_infinite());
    }

    #[test]
    fn sqnr_improves_roughly_6db_per_bit() {
        let mut r = Xoshiro256pp::new(0);
        let w = Tensor::new(vec![128, 64], (0..128 * 64).map(|_| r.normal_f32(0.0, 1.0)).collect());
        let s8 = sqnr_db(&w, &dequantize(&quantize(&w, Precision::Q8)));
        let s4 = sqnr_db(&w, &dequantize(&quantize(&w, Precision::Q4)));
        // 4 extra bits should buy >= ~12 dB even with conservative clipping
        assert!(s8 - s4 > 12.0, "s8={s8} s4={s4}");
        assert!(s8 > 30.0);
    }

    #[test]
    fn mse_simple_value() {
        let a = Tensor::new(vec![2], vec![0.0, 0.0]);
        let b = Tensor::new(vec![2], vec![3.0, 4.0]);
        assert!((mse(&a, &b) - 12.5).abs() < 1e-12);
        assert_eq!(max_abs_err(&a, &b), 4.0);
    }
}
