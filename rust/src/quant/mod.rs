//! Weight-only quantization formats.
//!
//! Formats and packing layouts are bit-identical with the L1 Pallas kernels
//! (`python/compile/kernels/ref.py` / `quant.py`): the Rust side *quantizes
//! and packs*, the AOT-compiled graph *unpacks and dequantizes in-VMEM* right
//! before the matmul. All scales are per-output-column, symmetric.
//!
//! | format | bits/param | payload layout (k×n matrix)                      |
//! |--------|-----------|---------------------------------------------------|
//! | `Raw`  | 32        | f32 row-major                                     |
//! | `Q8`   | 8         | i8 row-major + f32 scale[n]                       |
//! | `Q4`   | 4         | u8[k/2,n]: rows 2i,2i+1 -> lo/hi nibble (+8 bias) |
//! | `Q3`   | 3         | u8[3k/8,n]: 8 rows -> 3 bytes (+4 bias), edge §3.4|
//! | `T2`   | 2 (1.58)  | u8[k/4,n]: 4 ternary codes/byte (+1 bias)         |
//!
//! Packing is organized in **row groups** (1 row for Q8, 2 for Q4, 8 for Q3,
//! 4 for T2): each group maps to a disjoint payload segment, so
//! `quantize_pooled`/`dequantize_pooled` fan contiguous group bands out over
//! a `par::Pool` and concatenate segments in band order — the bytes are
//! identical for every worker count.

pub mod error;

use std::ops::Range;

use crate::par::Pool;
use crate::tensor::Tensor;

/// Precision levels of the paper's quantization ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    /// Ternary "1.58-bit" (stored as 2 bits/param).
    T2,
    /// 3-bit — the §3.4 edge-deployment extension.
    Q3,
    /// 4-bit.
    Q4,
    /// 8-bit.
    Q8,
    /// Unquantized f32.
    Raw,
}

impl Precision {
    pub fn bits_per_param(self) -> f64 {
        match self {
            Precision::Raw => 32.0,
            Precision::Q8 => 8.0,
            Precision::Q4 => 4.0,
            Precision::Q3 => 3.0,
            Precision::T2 => 2.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Raw => "raw",
            Precision::Q8 => "8bit",
            Precision::Q4 => "4bit",
            Precision::Q3 => "3bit",
            Precision::T2 => "1.58bit",
        }
    }

    /// Payload bytes for a k×n matrix in this precision (scales included).
    pub fn matrix_bytes(self, k: usize, n: usize) -> usize {
        let scale_bytes = if self == Precision::Raw { 0 } else { 4 * n };
        let payload = match self {
            Precision::Raw => 4 * k * n,
            Precision::Q8 => k * n,
            Precision::Q4 => k.div_ceil(2) * n,
            Precision::Q3 => (3 * k.div_ceil(8)) * n,
            Precision::T2 => k.div_ceil(4) * n,
        };
        payload + scale_bytes
    }

    /// Rows per packing group (the parallel work unit).
    fn group_rows(self) -> usize {
        match self {
            Precision::Raw | Precision::Q8 => 1,
            Precision::Q4 => 2,
            Precision::T2 => 4,
            Precision::Q3 => 8,
        }
    }

    /// Stable wire tag — decoupled from the enum's declaration order so the
    /// frame format survives refactors of the precision ladder.
    pub fn tag(self) -> u8 {
        match self {
            Precision::Raw => 0,
            Precision::Q8 => 1,
            Precision::Q4 => 2,
            Precision::Q3 => 3,
            Precision::T2 => 4,
        }
    }

    pub fn from_tag(t: u8) -> Option<Precision> {
        match t {
            0 => Some(Precision::Raw),
            1 => Some(Precision::Q8),
            2 => Some(Precision::Q4),
            3 => Some(Precision::Q3),
            4 => Some(Precision::T2),
            _ => None,
        }
    }
}

/// Parse a precision from its `label()` (plus short aliases) — CLI/config
/// surface for e.g. `ewq serve --kv-precision 8bit`.
impl std::str::FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "raw" | "f32" => Ok(Precision::Raw),
            "8bit" | "q8" => Ok(Precision::Q8),
            "4bit" | "q4" => Ok(Precision::Q4),
            "3bit" | "q3" => Ok(Precision::Q3),
            "1.58bit" | "2bit" | "t2" => Ok(Precision::T2),
            other => anyhow::bail!(
                "unknown precision {other:?} (raw|8bit|4bit|3bit|1.58bit)"
            ),
        }
    }
}

/// A quantized (or raw) 2-D weight matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct QMat {
    pub prec: Precision,
    pub rows: usize,
    pub cols: usize,
    pub payload: Payload,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Raw(Vec<f32>),
    Q8 { q: Vec<i8>, s: Vec<f32> },
    Q4 { p: Vec<u8>, s: Vec<f32> },
    Q3 { p: Vec<u8>, s: Vec<f32> },
    T2 { p: Vec<u8>, s: Vec<f32> },
}

#[inline]
fn rte(x: f32) -> f32 {
    // round half to even — matches jnp.round / np.round in ref.py
    x.round_ties_even()
}

/// Split `n_groups` row groups into contiguous bands for the pool: a handful
/// of bands per worker so the atomic task counter load-balances, collapsing
/// to a single band on a serial pool.
fn bands(n_groups: usize, pool: &Pool) -> Vec<Range<usize>> {
    if pool.workers() <= 1 || n_groups <= 1 {
        return vec![0..n_groups];
    }
    let target = (pool.workers() * 4).min(n_groups);
    let size = n_groups.div_ceil(target);
    (0..n_groups.div_ceil(size)).map(|b| (b * size)..((b + 1) * size).min(n_groups)).collect()
}

// ---- per-band packers: each group maps to a disjoint payload segment ------------

fn pack_q8(w: &Tensor, r: &[f32], groups: Range<usize>) -> Vec<i8> {
    let (_, n) = w.dims2();
    let mut out = vec![0i8; groups.len() * n];
    for (gi, i) in groups.enumerate() {
        let row = &w.data[i * n..(i + 1) * n];
        let seg = &mut out[gi * n..(gi + 1) * n];
        for j in 0..n {
            seg[j] = rte(row[j] * r[j]).clamp(-127.0, 127.0) as i8;
        }
    }
    out
}

fn pack_q4(w: &Tensor, r: &[f32], groups: Range<usize>) -> Vec<u8> {
    let (_, n) = w.dims2();
    let mut out = vec![0u8; groups.len() * n];
    for (gi, i2) in groups.enumerate() {
        let row_lo = &w.data[(2 * i2) * n..(2 * i2 + 1) * n];
        let row_hi = &w.data[(2 * i2 + 1) * n..(2 * i2 + 2) * n];
        let seg = &mut out[gi * n..(gi + 1) * n];
        for j in 0..n {
            let lo = (rte(row_lo[j] * r[j]).clamp(-7.0, 7.0) as i32 + 8) as u8;
            let hi = (rte(row_hi[j] * r[j]).clamp(-7.0, 7.0) as i32 + 8) as u8;
            seg[j] = lo | (hi << 4);
        }
    }
    out
}

fn pack_q3(w: &Tensor, recip: &[f32], groups: Range<usize>) -> Vec<u8> {
    let (_, n) = w.dims2();
    // 8 rows -> 3 bytes per column: 24-bit little-endian bitstream of
    // eight 3-bit codes (q+4 in [1,7]).
    let mut out = vec![0u8; groups.len() * 3 * n];
    for (gi, g) in groups.enumerate() {
        for j in 0..n {
            let mut bits: u32 = 0;
            for r8 in 0..8 {
                let q = rte(w.data[(8 * g + r8) * n + j] * recip[j]).clamp(-3.0, 3.0) as i32 + 4;
                bits |= (q as u32) << (3 * r8);
            }
            out[(3 * gi) * n + j] = (bits & 0xFF) as u8;
            out[(3 * gi + 1) * n + j] = ((bits >> 8) & 0xFF) as u8;
            out[(3 * gi + 2) * n + j] = ((bits >> 16) & 0xFF) as u8;
        }
    }
    out
}

fn pack_t2(w: &Tensor, recip: &[f32], groups: Range<usize>) -> Vec<u8> {
    let (_, n) = w.dims2();
    let mut out = vec![0u8; groups.len() * n];
    for (gi, g) in groups.enumerate() {
        for j in 0..n {
            let mut byte = 0u8;
            for r4 in 0..4 {
                let q = rte(w.data[(4 * g + r4) * n + j] * recip[j]).clamp(-1.0, 1.0) as i32 + 1;
                byte |= (q as u8) << (2 * r4);
            }
            out[gi * n + j] = byte;
        }
    }
    out
}

/// Quantize a 2-D tensor to `prec` (serial reference path; identical bytes
/// to `quantize_pooled` on any pool).
pub fn quantize(w: &Tensor, prec: Precision) -> QMat {
    quantize_pooled(w, prec, &Pool::serial())
}

/// Quantize with row-group bands fanned out over `pool`. Packing layouts
/// match ref.py exactly.
pub fn quantize_pooled(w: &Tensor, prec: Precision, pool: &Pool) -> QMat {
    let (k, n) = w.dims2();
    let payload = match prec {
        Precision::Raw => Payload::Raw(w.data.clone()),
        Precision::Q8 => {
            let s: Vec<f32> = w.col_abs_max().iter().map(|m| m.max(1e-12) / 127.0).collect();
            // §Perf: reciprocal-multiply instead of per-element divide
            let r: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
            let q = concat(pool, bands(k, pool), |b| pack_q8(w, &r, b));
            Payload::Q8 { q, s }
        }
        Precision::Q4 => {
            assert_eq!(k % 2, 0, "Q4 needs even k");
            let s: Vec<f32> = w.col_abs_max().iter().map(|m| m.max(1e-12) / 7.0).collect();
            let r: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
            let p = concat(pool, bands(k / 2, pool), |b| pack_q4(w, &r, b));
            Payload::Q4 { p, s }
        }
        Precision::Q3 => {
            assert_eq!(k % 8, 0, "Q3 needs k % 8 == 0");
            let s: Vec<f32> = w.col_abs_max().iter().map(|m| m.max(1e-12) / 3.0).collect();
            let recip: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
            let p = concat(pool, bands(k / 8, pool), |b| pack_q3(w, &recip, b));
            Payload::Q3 { p, s }
        }
        Precision::T2 => {
            assert_eq!(k % 4, 0, "T2 needs k % 4 == 0");
            let s: Vec<f32> = w.col_abs_mean().iter().map(|m| m.max(1e-12)).collect();
            let recip: Vec<f32> = s.iter().map(|v| 1.0 / v).collect();
            let p = concat(pool, bands(k / 4, pool), |b| pack_t2(w, &recip, b));
            Payload::T2 { p, s }
        }
    };
    QMat { prec, rows: k, cols: n, payload }
}

/// Re-pack an already-quantized matrix at a different precision — the
/// building block of online requantization (`serving::requant`). The same
/// target is a cheap clone; otherwise the matrix is dequantized and
/// re-quantized on the target lattice. Note the information floor: a
/// demotion then promotion (Q8 → Q4 → Q8) re-packs from the *Q4* lattice,
/// so promoted payloads carry the coarsest precision the block passed
/// through — repack never recovers bits, it only changes storage. Repack at
/// the same precision is exact (`requantize_is_fixed_point`), so swap
/// round-trips that end where they started at the same rung are no-ops.
pub fn repack(m: &QMat, target: Precision) -> QMat {
    if m.prec == target {
        return m.clone();
    }
    quantize(&dequantize(m), target)
}

/// Map bands in parallel and concatenate the segments in band order.
fn concat<E: Send + Clone>(
    pool: &Pool,
    bands: Vec<Range<usize>>,
    f: impl Fn(Range<usize>) -> Vec<E> + Sync,
) -> Vec<E> {
    if bands.len() == 1 {
        return f(bands.into_iter().next().unwrap());
    }
    let segs = pool.par_map_indexed(&bands, |_, b| f(b.clone()));
    let mut out = Vec::with_capacity(segs.iter().map(Vec::len).sum());
    for s in segs {
        out.extend_from_slice(&s);
    }
    out
}

// ---- per-band unpackers ---------------------------------------------------------

fn unpack_rows(m: &QMat, groups: Range<usize>) -> Vec<f32> {
    let n = m.cols;
    let gr = m.prec.group_rows();
    let mut out = vec![0.0f32; groups.len() * gr * n];
    match &m.payload {
        Payload::Raw(d) => {
            out.copy_from_slice(&d[groups.start * n..groups.end * n]);
        }
        Payload::Q8 { q, s } => {
            for (gi, i) in groups.enumerate() {
                for j in 0..n {
                    out[gi * n + j] = q[i * n + j] as f32 * s[j];
                }
            }
        }
        Payload::Q4 { p, s } => {
            for (gi, i2) in groups.enumerate() {
                for j in 0..n {
                    let b = p[i2 * n + j];
                    out[(2 * gi) * n + j] = ((b & 0xF) as i32 - 8) as f32 * s[j];
                    out[(2 * gi + 1) * n + j] = (((b >> 4) & 0xF) as i32 - 8) as f32 * s[j];
                }
            }
        }
        Payload::Q3 { p, s } => {
            for (gi, g) in groups.enumerate() {
                for j in 0..n {
                    let bits = p[(3 * g) * n + j] as u32
                        | ((p[(3 * g + 1) * n + j] as u32) << 8)
                        | ((p[(3 * g + 2) * n + j] as u32) << 16);
                    for r in 0..8 {
                        let q = ((bits >> (3 * r)) & 0x7) as i32 - 4;
                        out[(8 * gi + r) * n + j] = q as f32 * s[j];
                    }
                }
            }
        }
        Payload::T2 { p, s } => {
            for (gi, g) in groups.enumerate() {
                for j in 0..n {
                    let b = p[g * n + j];
                    for r in 0..4 {
                        let q = ((b >> (2 * r)) & 0x3) as i32 - 1;
                        out[(4 * gi + r) * n + j] = q as f32 * s[j];
                    }
                }
            }
        }
    }
    out
}

/// Dequantize back to f32 (used for the Q3 edge path, the native reference
/// executor, and error metrics; the PJRT hot path dequantizes in-graph).
pub fn dequantize(m: &QMat) -> Tensor {
    dequantize_pooled(m, &Pool::serial())
}

/// Dequantize with row-group bands fanned out over `pool` (bit-identical to
/// the serial path).
pub fn dequantize_pooled(m: &QMat, pool: &Pool) -> Tensor {
    let (k, n) = (m.rows, m.cols);
    let n_groups = k / m.prec.group_rows();
    let out = concat(pool, bands(n_groups, pool), |b| unpack_rows(m, b));
    debug_assert_eq!(out.len(), k * n);
    Tensor::new(vec![k, n], out)
}

/// Dequantize the `rows` × `cols` sub-tile of `m` into `out` (row-major,
/// `rows.len() * cols.len()` elements), bit-identical to the same region of
/// `dequantize(m)`. `rows` must begin and end on packing-group boundaries
/// (the fused GEMM kernels tile `k` in multiples of 8, which covers every
/// format); `cols` is unconstrained. This is the kernel-side unpack: tiles
/// live in a per-worker scratch buffer, so serving never materializes a
/// full f32 copy of a packed matrix. Resolves the SIMD/scalar path itself;
/// the fused kernels hoist that choice and call `dequantize_tile_path`.
pub fn dequantize_tile(m: &QMat, rows: Range<usize>, cols: Range<usize>, out: &mut [f32]) {
    dequantize_tile_path(m, rows, cols, crate::simd::kernel_path(), out)
}

/// `dequantize_tile` with the inner-loop path chosen by the caller. The
/// unpack loops live in `crate::simd` (one row-group per call, vectorized
/// across the column dimension with the scalar code as fallback); both
/// paths produce identical bits, so callers may mix them freely.
pub fn dequantize_tile_path(
    m: &QMat,
    rows: Range<usize>,
    cols: Range<usize>,
    path: crate::simd::KernelPath,
    out: &mut [f32],
) {
    let n = m.cols;
    let (th, tw) = (rows.len(), cols.len());
    assert!(rows.end <= m.rows && cols.end <= n, "tile out of bounds");
    assert_eq!(out.len(), th * tw, "tile buffer size mismatch");
    let gr = m.prec.group_rows();
    assert_eq!(rows.start % gr, 0, "tile start must be group-aligned");
    assert_eq!(th % gr, 0, "tile height must be whole packing groups");
    if tw == 0 {
        return;
    }
    match &m.payload {
        Payload::Raw(d) => {
            for (ri, i) in rows.enumerate() {
                out[ri * tw..(ri + 1) * tw]
                    .copy_from_slice(&d[i * n + cols.start..i * n + cols.end]);
            }
        }
        Payload::Q8 { q, s } => {
            let sv = &s[cols.start..cols.end];
            for (ri, i) in rows.enumerate() {
                crate::simd::dequant_q8_row(
                    &q[i * n + cols.start..i * n + cols.end],
                    sv,
                    &mut out[ri * tw..(ri + 1) * tw],
                    path,
                );
            }
        }
        Payload::Q4 { p, s } => {
            let sv = &s[cols.start..cols.end];
            for (gi, g) in (rows.start / 2..rows.end / 2).enumerate() {
                crate::simd::dequant_q4_rows(
                    &p[g * n + cols.start..g * n + cols.end],
                    sv,
                    &mut out[(2 * gi) * tw..(2 * gi + 2) * tw],
                    path,
                );
            }
        }
        Payload::Q3 { p, s } => {
            let sv = &s[cols.start..cols.end];
            for (gi, g) in (rows.start / 8..rows.end / 8).enumerate() {
                let b0 = &p[(3 * g) * n + cols.start..(3 * g) * n + cols.end];
                let b1 = &p[(3 * g + 1) * n + cols.start..(3 * g + 1) * n + cols.end];
                let b2 = &p[(3 * g + 2) * n + cols.start..(3 * g + 2) * n + cols.end];
                crate::simd::dequant_q3_rows(
                    b0,
                    b1,
                    b2,
                    sv,
                    &mut out[(8 * gi) * tw..(8 * gi + 8) * tw],
                    path,
                );
            }
        }
        Payload::T2 { p, s } => {
            let sv = &s[cols.start..cols.end];
            for (gi, g) in (rows.start / 4..rows.end / 4).enumerate() {
                crate::simd::dequant_t2_rows(
                    &p[g * n + cols.start..g * n + cols.end],
                    sv,
                    &mut out[(4 * gi) * tw..(4 * gi + 4) * tw],
                    path,
                );
            }
        }
    }
}

/// Issue software-prefetch hints for the packed bytes and scale group that
/// `dequantize_tile_path(m, rows, cols, ..)` would read — the kernels call
/// this for the *next* tile while unpacking the current one (DESIGN.md §16).
/// Mirrors the payload indexing above exactly, but reads nothing and writes
/// nothing: prefetch is a pure hint, so this can never change a result bit.
/// Unlike the dequantizer it clamps instead of asserting — the "next tile"
/// computed at a band edge may run past the matrix, and a partially- or
/// fully-out-of-range tile must degrade to fewer (or zero) hints.
pub fn prefetch_tile(m: &QMat, rows: Range<usize>, cols: Range<usize>) {
    use crate::simd::prefetch_bytes;
    let n = m.cols;
    let rows = rows.start.min(m.rows)..rows.end.min(m.rows);
    let cols = cols.start.min(n)..cols.end.min(n);
    let tw = cols.len();
    if tw == 0 || rows.is_empty() {
        return;
    }
    match &m.payload {
        Payload::Raw(d) => {
            for i in rows {
                prefetch_bytes(d[i * n + cols.start..].as_ptr() as *const u8, 4 * tw);
            }
        }
        Payload::Q8 { q, s } => {
            prefetch_bytes(s[cols.start..].as_ptr() as *const u8, 4 * tw);
            for i in rows {
                prefetch_bytes(q[i * n + cols.start..].as_ptr() as *const u8, tw);
            }
        }
        Payload::Q4 { p, s } => {
            prefetch_bytes(s[cols.start..].as_ptr() as *const u8, 4 * tw);
            for g in rows.start / 2..rows.end / 2 {
                prefetch_bytes(p[g * n + cols.start..].as_ptr() as *const u8, tw);
            }
        }
        Payload::Q3 { p, s } => {
            prefetch_bytes(s[cols.start..].as_ptr() as *const u8, 4 * tw);
            for g in rows.start / 8..rows.end / 8 {
                for j in 0..3 {
                    prefetch_bytes(p[(3 * g + j) * n + cols.start..].as_ptr() as *const u8, tw);
                }
            }
        }
        Payload::T2 { p, s } => {
            prefetch_bytes(s[cols.start..].as_ptr() as *const u8, 4 * tw);
            for g in rows.start / 4..rows.end / 4 {
                prefetch_bytes(p[g * n + cols.start..].as_ptr() as *const u8, tw);
            }
        }
    }
}

impl QMat {
    /// Stored size in bytes (payload + scales).
    pub fn size_bytes(&self) -> usize {
        self.prec.matrix_bytes(self.rows, self.cols)
    }

    pub fn scales(&self) -> Option<&[f32]> {
        match &self.payload {
            Payload::Raw(_) => None,
            Payload::Q8 { s, .. }
            | Payload::Q4 { s, .. }
            | Payload::Q3 { s, .. }
            | Payload::T2 { s, .. } => Some(s),
        }
    }

    /// Raw packed payload bytes (for feeding the PJRT executable).
    pub fn packed_bytes(&self) -> Vec<u8> {
        match &self.payload {
            Payload::Raw(d) => d.iter().flat_map(|v| v.to_le_bytes()).collect(),
            Payload::Q8 { q, .. } => q.iter().map(|&v| v as u8).collect(),
            Payload::Q4 { p, .. } | Payload::Q3 { p, .. } | Payload::T2 { p, .. } => p.clone(),
        }
    }

    /// Serialize to the self-describing wire frame `from_packed_bytes`
    /// parses: header (magic, version, precision tag, shape, scale count),
    /// then the f32-LE scales, then the `packed_bytes` payload.
    pub fn wire_bytes(&self) -> Vec<u8> {
        let scales = self.scales().unwrap_or(&[]);
        let payload = self.packed_bytes();
        let mut out = Vec::with_capacity(WIRE_HEADER + 4 * scales.len() + payload.len());
        out.extend_from_slice(&WIRE_MAGIC);
        out.push(WIRE_VERSION);
        out.push(self.prec.tag());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        out.extend_from_slice(&(scales.len() as u32).to_le_bytes());
        for v in scales {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&payload);
        out
    }

    /// Validate an UNTRUSTED wire frame (a shard handoff, a cached plan
    /// artifact, a network peer) into a `QMat`. Every malformation —
    /// truncation, bad magic/version/tag, shape overflow, group-contract
    /// violation, scale-count lies, non-finite scales, trailing bytes —
    /// comes back as a typed `QuantError`; this function never panics on
    /// any input. Accepted frames re-encode byte-identically via
    /// `wire_bytes` (codes outside the quantizer's clamp range, e.g. a
    /// `-8` Q4 nibble, are representable and kept as-is).
    ///
    /// ```
    /// use ewq::quant::{quantize, Precision, QMat, QuantError};
    /// use ewq::tensor::Tensor;
    ///
    /// let w = Tensor::new(vec![4, 2], (0..8).map(|i| i as f32 - 3.5).collect());
    /// let frame = quantize(&w, Precision::Q8).wire_bytes();
    /// // accepted frames re-encode byte-identically
    /// assert_eq!(QMat::from_packed_bytes(&frame).unwrap().wire_bytes(), frame);
    /// // a truncated frame fails as typed data, never as a panic
    /// assert_eq!(
    ///     QMat::from_packed_bytes(&frame[..frame.len() - 1]),
    ///     Err(QuantError::Truncated { needed: frame.len(), got: frame.len() - 1 }),
    /// );
    /// ```
    pub fn from_packed_bytes(data: &[u8]) -> std::result::Result<QMat, QuantError> {
        if data.len() < WIRE_HEADER {
            return Err(QuantError::Truncated { needed: WIRE_HEADER, got: data.len() });
        }
        let magic: [u8; 4] = data[0..4].try_into().unwrap();
        if magic != WIRE_MAGIC {
            return Err(QuantError::BadMagic(magic));
        }
        if data[4] != WIRE_VERSION {
            return Err(QuantError::BadVersion(data[4]));
        }
        let prec = Precision::from_tag(data[5]).ok_or(QuantError::BadPrecision(data[5]))?;
        let le32 = |o: usize| u32::from_le_bytes(data[o..o + 4].try_into().unwrap()) as usize;
        let (rows, cols, nscales) = (le32(6), le32(10), le32(14));
        let want_scales = if prec == Precision::Raw { 0 } else { cols };
        if nscales != want_scales {
            return Err(QuantError::ScaleCountMismatch { want: want_scales, got: nscales });
        }
        let bad_shape = QuantError::BadShape { rows, cols };
        let gr = prec.group_rows();
        if rows % gr != 0 {
            return Err(bad_shape);
        }
        // bytes per packing group of `gr` rows (see the module's layout table)
        let per_group = match prec {
            Precision::Raw => 4,
            Precision::Q3 => 3,
            Precision::Q8 | Precision::Q4 | Precision::T2 => 1,
        };
        let payload_len = cols
            .checked_mul(per_group)
            .and_then(|g| g.checked_mul(rows / gr))
            .ok_or(bad_shape.clone())?;
        let total = WIRE_HEADER
            .checked_add(4 * nscales)
            .and_then(|t| t.checked_add(payload_len))
            .ok_or(bad_shape)?;
        if data.len() < total {
            return Err(QuantError::Truncated { needed: total, got: data.len() });
        }
        if data.len() > total {
            return Err(QuantError::TrailingBytes { extra: data.len() - total });
        }
        let mut s = Vec::with_capacity(nscales);
        for i in 0..nscales {
            let v = f32::from_le_bytes(
                data[WIRE_HEADER + 4 * i..WIRE_HEADER + 4 * (i + 1)].try_into().unwrap(),
            );
            if !v.is_finite() {
                return Err(QuantError::BadScale { index: i });
            }
            s.push(v);
        }
        let pb = &data[WIRE_HEADER + 4 * nscales..];
        let payload = match prec {
            Precision::Raw => Payload::Raw(
                pb.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            Precision::Q8 => Payload::Q8 { q: pb.iter().map(|&b| b as i8).collect(), s },
            Precision::Q4 => Payload::Q4 { p: pb.to_vec(), s },
            Precision::Q3 => Payload::Q3 { p: pb.to_vec(), s },
            Precision::T2 => Payload::T2 { p: pb.to_vec(), s },
        };
        Ok(QMat { prec, rows, cols, payload })
    }
}

// ---- self-describing wire frame -------------------------------------------------

/// Wire-frame magic (`b"EWQM"`).
pub const WIRE_MAGIC: [u8; 4] = *b"EWQM";
/// Wire-format version `from_packed_bytes` accepts.
pub const WIRE_VERSION: u8 = 1;
/// Header: magic 4 + version 1 + tag 1 + rows 4 + cols 4 + nscales 4.
const WIRE_HEADER: usize = 18;

/// Typed validation failures from `QMat::from_packed_bytes` — untrusted
/// bytes fail as data, never as a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QuantError {
    /// Fewer bytes than the header (or its declared frame length) needs.
    Truncated { needed: usize, got: usize },
    BadMagic([u8; 4]),
    BadVersion(u8),
    BadPrecision(u8),
    /// Shape that overflows addressing or breaks the packing-group contract.
    BadShape { rows: usize, cols: usize },
    /// Scale count inconsistent with the declared precision and shape.
    ScaleCountMismatch { want: usize, got: usize },
    /// Non-finite scale — would silently poison every dequantized value.
    BadScale { index: usize },
    /// Bytes left over past the declared payload.
    TrailingBytes { extra: usize },
}

impl std::fmt::Display for QuantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantError::Truncated { needed, got } => {
                write!(f, "truncated frame: need {needed} bytes, got {got}")
            }
            QuantError::BadMagic(m) => write!(f, "bad magic {m:?} (want {WIRE_MAGIC:?})"),
            QuantError::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (want {WIRE_VERSION})")
            }
            QuantError::BadPrecision(t) => write!(f, "unknown precision tag {t}"),
            QuantError::BadShape { rows, cols } => {
                write!(f, "invalid shape {rows}x{cols} for the declared precision")
            }
            QuantError::ScaleCountMismatch { want, got } => {
                write!(f, "scale count {got} != expected {want}")
            }
            QuantError::BadScale { index } => write!(f, "non-finite scale at column {index}"),
            QuantError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after the payload")
            }
        }
    }
}

impl std::error::Error for QuantError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn rand_tensor(k: usize, n: usize, seed: u64, std: f32) -> Tensor {
        let mut r = Xoshiro256pp::new(seed);
        Tensor::new(vec![k, n], (0..k * n).map(|_| r.normal_f32(0.0, std)).collect())
    }

    #[test]
    fn q8_roundtrip_error_bounded() {
        let w = rand_tensor(64, 48, 0, 0.5);
        let q = quantize(&w, Precision::Q8);
        let wd = dequantize(&q);
        let s = q.scales().unwrap();
        for i in 0..64 {
            for j in 0..48 {
                assert!((wd.at2(i, j) - w.at2(i, j)).abs() <= 0.5 * s[j] + 1e-7);
            }
        }
    }

    #[test]
    fn q4_roundtrip_error_bounded() {
        let w = rand_tensor(64, 48, 1, 0.5);
        let q = quantize(&w, Precision::Q4);
        let wd = dequantize(&q);
        let s = q.scales().unwrap();
        for i in 0..64 {
            for j in 0..48 {
                assert!((wd.at2(i, j) - w.at2(i, j)).abs() <= 0.5 * s[j] + 1e-7);
            }
        }
    }

    #[test]
    fn q3_roundtrip_error_bounded() {
        let w = rand_tensor(64, 16, 2, 0.5);
        let q = quantize(&w, Precision::Q3);
        let wd = dequantize(&q);
        let s = q.scales().unwrap();
        for i in 0..64 {
            for j in 0..16 {
                assert!((wd.at2(i, j) - w.at2(i, j)).abs() <= 0.5 * s[j] + 1e-7);
            }
        }
    }

    #[test]
    fn t2_values_are_ternary_multiples() {
        let w = rand_tensor(64, 16, 3, 1.0);
        let q = quantize(&w, Precision::T2);
        let wd = dequantize(&q);
        let s = q.scales().unwrap();
        for i in 0..64 {
            for j in 0..16 {
                let r = wd.at2(i, j) / s[j];
                assert!(
                    (r - r.round()).abs() < 1e-5 && (-1.0..=1.0).contains(&r.round()),
                    "ratio {r}"
                );
            }
        }
    }

    #[test]
    fn prefetch_tile_tolerates_every_edge_and_overrun() {
        // the next-tile lookahead may hand this any rectangle, including
        // ones past the matrix edge; it must never panic and (being a pure
        // hint) never perturb a later dequant
        let w = rand_tensor(64, 48, 77, 0.5);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let q = quantize(&w, prec);
            let expect = dequantize(&q);
            for (rows, cols) in [
                (0..32, 0..48),   // interior
                (32..64, 40..48), // ragged right edge
                (56..64, 0..13),  // ragged bottom edge
                (64..96, 0..48),  // fully past the rows
                (32..64, 48..64), // fully past the cols
                (48..80, 40..80), // straddles both edges
            ] {
                prefetch_tile(&q, rows.clone(), cols.clone());
                assert_eq!(
                    dequantize(&q),
                    expect,
                    "{} rows={rows:?} cols={cols:?}",
                    prec.label()
                );
            }
        }
    }

    #[test]
    fn requantize_is_fixed_point() {
        // quantize(dequantize(q)) == q for Q4 (idempotence of the lattice)
        let w = rand_tensor(32, 24, 4, 0.7);
        let q1 = quantize(&w, Precision::Q4);
        let q2 = quantize(&dequantize(&q1), Precision::Q4);
        assert_eq!(q1, q2);
    }

    #[test]
    fn pooled_quantize_is_byte_identical() {
        // row-group banding must not change a single byte, any worker count
        let w = rand_tensor(96, 56, 8, 0.6);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let serial = quantize(&w, prec);
            for workers in [2usize, 3, 5] {
                let pooled = quantize_pooled(&w, prec, &Pool::new(workers));
                assert_eq!(serial, pooled, "{} workers={workers}", prec.label());
            }
        }
    }

    #[test]
    fn pooled_dequantize_is_bit_identical() {
        let w = rand_tensor(96, 56, 9, 0.6);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let q = quantize(&w, prec);
            let serial = dequantize(&q);
            for workers in [2usize, 4] {
                let pooled = dequantize_pooled(&q, &Pool::new(workers));
                assert_eq!(serial, pooled, "{} workers={workers}", prec.label());
            }
        }
    }

    #[test]
    fn dequantize_tile_matches_full_dequantize() {
        // every format, group-aligned row tiles x arbitrary column tiles,
        // bit-identical to the corresponding region of the full dequantize
        let (k, n) = (40usize, 23usize); // k % 8 == 0, odd-ish n
        let w = rand_tensor(k, n, 11, 0.6);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let q = quantize(&w, prec);
            let full = dequantize(&q);
            for rows in [0..8usize, 8..24, 16..40, 0..40] {
                for cols in [0..1usize, 3..10, 5..23, 0..23] {
                    let (th, tw) = (rows.len(), cols.len());
                    let mut tile = vec![f32::NAN; th * tw];
                    dequantize_tile(&q, rows.clone(), cols.clone(), &mut tile);
                    for ri in 0..th {
                        for ci in 0..tw {
                            let expect = full.at2(rows.start + ri, cols.start + ci);
                            assert_eq!(
                                tile[ri * tw + ci].to_bits(),
                                expect.to_bits(),
                                "{} rows={rows:?} cols={cols:?} ({ri},{ci})",
                                prec.label()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dequantize_tile_paths_bit_identical() {
        // scalar vs SIMD unpack over every format and ragged column ranges
        // (partial 8-lane chunks + scalar tails) — same bits, always
        use crate::simd::KernelPath;
        let (k, n) = (32usize, 29usize);
        let w = rand_tensor(k, n, 13, 0.6);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let q = quantize(&w, prec);
            for rows in [0..8usize, 8..32, 0..32] {
                for cols in [0..29usize, 1..9, 3..20, 28..29] {
                    let (th, tw) = (rows.len(), cols.len());
                    let mut scalar = vec![f32::NAN; th * tw];
                    dequantize_tile_path(&q, rows.clone(), cols.clone(), KernelPath::Scalar, &mut scalar);
                    let mut simd = vec![f32::NAN; th * tw];
                    dequantize_tile_path(&q, rows.clone(), cols.clone(), KernelPath::Avx2, &mut simd);
                    for (i, (a, b)) in simd.iter().zip(&scalar).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} rows={rows:?} cols={cols:?} elem {i}: simd {a} vs scalar {b}",
                            prec.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "group-aligned")]
    fn dequantize_tile_rejects_unaligned_start() {
        let w = rand_tensor(16, 8, 12, 0.5);
        let q = quantize(&w, Precision::Q3);
        let mut out = vec![0.0f32; 8 * 8];
        dequantize_tile(&q, 4..12, 0..8, &mut out);
    }

    #[test]
    fn size_model_table9() {
        // bits/param ordering and exact byte counts
        let (k, n) = (96, 384);
        let raw = Precision::Raw.matrix_bytes(k, n);
        let q8 = Precision::Q8.matrix_bytes(k, n);
        let q4 = Precision::Q4.matrix_bytes(k, n);
        let q3 = Precision::Q3.matrix_bytes(k, n);
        let t2 = Precision::T2.matrix_bytes(k, n);
        assert_eq!(raw, 4 * k * n);
        assert_eq!(q8, k * n + 4 * n);
        assert_eq!(q4, k * n / 2 + 4 * n);
        assert_eq!(q3, 3 * k * n / 8 + 4 * n);
        assert_eq!(t2, k * n / 4 + 4 * n);
        assert!(raw > q8 && q8 > q4 && q4 > q3 && q3 > t2);
    }

    #[test]
    fn packed_bytes_lengths() {
        let w = rand_tensor(32, 16, 5, 0.5);
        assert_eq!(quantize(&w, Precision::Raw).packed_bytes().len(), 32 * 16 * 4);
        assert_eq!(quantize(&w, Precision::Q8).packed_bytes().len(), 32 * 16);
        assert_eq!(quantize(&w, Precision::Q4).packed_bytes().len(), 16 * 16);
        assert_eq!(quantize(&w, Precision::Q3).packed_bytes().len(), 12 * 16);
        assert_eq!(quantize(&w, Precision::T2).packed_bytes().len(), 8 * 16);
    }

    #[test]
    fn wire_roundtrip_every_precision() {
        let w = rand_tensor(32, 24, 21, 0.6);
        for prec in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
        {
            let q = quantize(&w, prec);
            let frame = q.wire_bytes();
            let parsed = QMat::from_packed_bytes(&frame).unwrap();
            assert_eq!(parsed, q, "{}", prec.label());
            assert_eq!(parsed.wire_bytes(), frame, "{}: re-encode byte-identical", prec.label());
            assert_eq!(Precision::from_tag(prec.tag()), Some(prec));
        }
    }

    #[test]
    fn wire_rejects_malformed_frames_with_typed_errors() {
        let q = quantize(&rand_tensor(16, 8, 22, 0.5), Precision::Q8);
        let frame = q.wire_bytes();
        assert_eq!(
            QMat::from_packed_bytes(&[]),
            Err(QuantError::Truncated { needed: 18, got: 0 })
        );
        let mut f = frame.clone();
        f[0] = b'X';
        assert_eq!(QMat::from_packed_bytes(&f), Err(QuantError::BadMagic(*b"XWQM")));
        let mut f = frame.clone();
        f[4] = 9;
        assert_eq!(QMat::from_packed_bytes(&f), Err(QuantError::BadVersion(9)));
        let mut f = frame.clone();
        f[5] = 250;
        assert_eq!(QMat::from_packed_bytes(&f), Err(QuantError::BadPrecision(250)));
        // scale count inconsistent with the declared shape
        let mut f = frame.clone();
        f[14..18].copy_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            QMat::from_packed_bytes(&f),
            Err(QuantError::ScaleCountMismatch { want: 8, got: 7 })
        );
        // payload shortfall and trailing junk
        let mut f = frame.clone();
        f.truncate(frame.len() - 1);
        assert_eq!(
            QMat::from_packed_bytes(&f),
            Err(QuantError::Truncated { needed: frame.len(), got: frame.len() - 1 })
        );
        let mut f = frame.clone();
        f.extend_from_slice(&[0, 0, 0]);
        assert_eq!(QMat::from_packed_bytes(&f), Err(QuantError::TrailingBytes { extra: 3 }));
        // non-finite scale (column 2 starts at header + 2*4)
        let mut f = frame.clone();
        f[18 + 8..18 + 12].copy_from_slice(&f32::NAN.to_le_bytes());
        assert_eq!(QMat::from_packed_bytes(&f), Err(QuantError::BadScale { index: 2 }));
        // odd row count under Q4's 2-row packing group
        let mut f = frame.clone();
        f[5] = Precision::Q4.tag();
        f[6..10].copy_from_slice(&15u32.to_le_bytes());
        assert_eq!(
            QMat::from_packed_bytes(&f),
            Err(QuantError::BadShape { rows: 15, cols: 8 })
        );
        // shape whose Raw payload size overflows usize
        let mut f = frame.clone();
        f[5] = Precision::Raw.tag();
        f[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        f[10..14].copy_from_slice(&u32::MAX.to_le_bytes());
        f[14..18].copy_from_slice(&0u32.to_le_bytes());
        assert_eq!(
            QMat::from_packed_bytes(&f),
            Err(QuantError::BadShape { rows: u32::MAX as usize, cols: u32::MAX as usize })
        );
    }

    const ALL_PRECISIONS: [Precision; 5] =
        [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2];

    #[test]
    fn precision_tag_roundtrip_is_exhaustive_and_stable() {
        // every variant survives tag() -> from_tag(), the tag values are the
        // documented wire constants, and they are pairwise distinct
        for p in ALL_PRECISIONS {
            assert_eq!(Precision::from_tag(p.tag()), Some(p), "{}", p.label());
        }
        assert_eq!(Precision::Raw.tag(), 0);
        assert_eq!(Precision::Q8.tag(), 1);
        assert_eq!(Precision::Q4.tag(), 2);
        assert_eq!(Precision::Q3.tag(), 3);
        assert_eq!(Precision::T2.tag(), 4);
        let mut tags: Vec<u8> = ALL_PRECISIONS.iter().map(|p| p.tag()).collect();
        tags.sort_unstable();
        tags.dedup();
        assert_eq!(tags.len(), ALL_PRECISIONS.len(), "tags must be distinct");
        // every byte outside the assigned range is rejected, not mis-mapped
        for t in 5..=u8::MAX {
            assert_eq!(Precision::from_tag(t), None, "tag {t}");
        }
    }

    #[test]
    fn bits_per_param_and_matrix_bytes_are_consistent() {
        // matrix_bytes must equal ceil-packed payload (bits_per_param) plus
        // the per-column f32 scales, for every variant and several
        // group-aligned shapes — the size model the requant controller's
        // byte accounting and the wire format both ride
        for (k, n) in [(8usize, 1usize), (16, 8), (32, 24), (96, 56), (64, 3)] {
            for p in ALL_PRECISIONS {
                let scale_bytes = if p == Precision::Raw { 0 } else { 4 * n };
                let payload_bits = p.bits_per_param() * (k * n) as f64;
                // k is a multiple of 8, so every format packs without edge
                // padding and the bit count is whole
                let expect = (payload_bits / 8.0) as usize + scale_bytes;
                assert_eq!(p.matrix_bytes(k, n), expect, "{} {k}x{n}", p.label());
            }
        }
        // packed QMats agree with the static size model
        let w = rand_tensor(32, 24, 31, 0.5);
        for p in ALL_PRECISIONS {
            let q = quantize(&w, p);
            assert_eq!(q.size_bytes(), p.matrix_bytes(32, 24), "{}", p.label());
            let scale_bytes = if p == Precision::Raw { 0 } else { 4 * 24 };
            assert_eq!(
                q.packed_bytes().len() + scale_bytes,
                q.size_bytes(),
                "{}: payload + scales == size_bytes",
                p.label()
            );
        }
    }

    #[test]
    fn wire_rejects_tag_payload_length_disagreement() {
        // flip ONLY the precision tag on an otherwise valid frame: the
        // declared payload length no longer matches the bytes present, and
        // the frame must fail typed (Truncated or TrailingBytes) rather
        // than parse into a mis-typed QMat. 16x8 is group-aligned for every
        // format, so the shape itself stays valid — only the length lies.
        let w = rand_tensor(16, 8, 24, 0.5);
        for from in ALL_PRECISIONS {
            let frame = quantize(&w, from).wire_bytes();
            for to in ALL_PRECISIONS {
                if to == from {
                    continue;
                }
                let mut f = frame.clone();
                f[5] = to.tag();
                let got = QMat::from_packed_bytes(&f);
                match (&got, from == Precision::Raw || to == Precision::Raw) {
                    // Raw frames carry no scales, quantized ones do: a
                    // Raw<->quantized tag flip also trips the scale count
                    (Err(QuantError::ScaleCountMismatch { .. }), true) => {}
                    (Err(QuantError::Truncated { .. }), false)
                    | (Err(QuantError::TrailingBytes { .. }), false) => {}
                    _ => panic!(
                        "{} frame retagged {} must fail on length: {got:?}",
                        from.label(),
                        to.label()
                    ),
                }
            }
        }
    }

    #[test]
    fn repack_changes_precision_and_is_identity_at_the_same_rung() {
        let w = rand_tensor(32, 24, 29, 0.6);
        let q8 = quantize(&w, Precision::Q8);
        // same precision: exact clone, payload bytes untouched
        assert_eq!(repack(&q8, Precision::Q8), q8);
        // demotion re-packs on the coarser lattice
        let q4 = repack(&q8, Precision::Q4);
        assert_eq!(q4.prec, Precision::Q4);
        assert_eq!((q4.rows, q4.cols), (32, 24));
        assert!(q4.size_bytes() < q8.size_bytes());
        // round-trip Q8 -> Q4 -> Q8: shape and size restored, but the
        // payload now carries the Q4 information floor (documented loss)
        let back = repack(&q4, Precision::Q8);
        assert_eq!(back.prec, Precision::Q8);
        assert_eq!(back.size_bytes(), q8.size_bytes());
        // the promoted payload stays on the Q4 lattice to within the Q8
        // rounding error — it must NOT recover the original Q8 detail
        let (q4d, backd) = (dequantize(&q4), dequantize(&back));
        let s8 = back.scales().unwrap();
        for i in 0..32 {
            for j in 0..24 {
                assert!(
                    (backd.at2(i, j) - q4d.at2(i, j)).abs() <= 0.5 * s8[j] + 1e-7,
                    "({i},{j}): promotion must re-encode the Q4 lattice"
                );
            }
        }
    }

    #[test]
    fn adversarial_wire_bytes_never_panic_and_only_exact_frames_parse() {
        // property: any truncation / extension / bit-flip of a valid frame
        // either fails with a typed error or parses into a QMat that
        // re-encodes to the EXACT mutated bytes — never a panic, never a
        // lossy accept
        let w = rand_tensor(16, 12, 23, 0.5);
        let frames: Vec<Vec<u8>> =
            [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
                .iter()
                .map(|&p| quantize(&w, p).wire_bytes())
                .collect();
        crate::proptest_lite::check(
            0xEB17,
            400,
            64,
            |g| {
                let mut f = frames[g.usize_in(0, frames.len())].clone();
                match g.usize_in(0, 3) {
                    0 => {
                        let keep = g.usize_in(0, f.len() + 1);
                        f.truncate(keep);
                    }
                    1 => {
                        for _ in 0..g.usize_in(1, 16) {
                            f.push(g.usize_in(0, 256) as u8);
                        }
                    }
                    _ => {
                        for _ in 0..g.usize_in(1, 6) {
                            let i = g.usize_in(0, f.len());
                            f[i] ^= 1 << g.usize_in(0, 8);
                        }
                    }
                }
                f
            },
            |bytes| match QMat::from_packed_bytes(bytes) {
                Err(_) => Ok(()), // typed rejection; the property is no-panic
                Ok(m) if m.wire_bytes() == *bytes => Ok(()),
                Ok(m) => Err(format!(
                    "accepted a {}x{} {} frame it cannot re-encode byte-identically",
                    m.rows,
                    m.cols,
                    m.prec.label()
                )),
            },
        );
    }

    #[test]
    fn precision_ordering() {
        assert!(Precision::T2 < Precision::Q3);
        assert!(Precision::Q3 < Precision::Q4);
        assert!(Precision::Q4 < Precision::Q8);
        assert!(Precision::Q8 < Precision::Raw);
    }

    #[test]
    fn error_decreases_with_precision() {
        let w = rand_tensor(96, 96, 6, 0.8);
        let mse = |p: Precision| error::mse(&w, &dequantize(&quantize(&w, p)));
        let e8 = mse(Precision::Q8);
        let e4 = mse(Precision::Q4);
        let e3 = mse(Precision::Q3);
        let e2 = mse(Precision::T2);
        assert!(e8 < e4 && e4 < e3 && e3 < e2, "{e8} {e4} {e3} {e2}");
        assert_eq!(mse(Precision::Raw), 0.0);
    }
}
