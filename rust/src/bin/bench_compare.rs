//! `bench_compare` — CI regression gate over the bench-smoke artifacts.
//!
//! Compares the fused-GEMM GFLOP/s and KV-decode tokens/s figures in the
//! freshly generated bench JSONs against a committed `BENCH_baseline.json`
//! and fails (exit 1) when any tracked metric regresses by more than the
//! tolerance.
//!
//! ```text
//! bench_compare <current.json>... <baseline.json>
//!   EWQ_BENCH_TOLERANCE     allowed fractional drop (default 0.20 = 20%)
//!   EWQ_BENCH_SIMD_MIN      required SIMD/scalar fused-GEMM GFLOP/s ratio
//!                           on Q8 and Q4 when the runner dispatched a
//!                           vector path (default 2.0; skipped when
//!                           kernel_path is "scalar")
//!   EWQ_BENCH_BATCHED_MIN   required continuous-batching throughput ratio
//!                           decode_tok_s_batched / decode_tok_s_raw_kv
//!                           (default 3.0; both keys come from the same
//!                           bench_decode run, so this is a hardware-
//!                           independent amortization gate, not an
//!                           absolute-throughput floor)
//!   EWQ_BENCH_COMPARE_MODE  "enforce" (default) exits 1 on regression;
//!                           "warn" reports but always exits 0 — the
//!                           first-run stance until a baseline measured on
//!                           the CI hardware itself is committed
//! ```
//!
//! Several current files may be given (bench-smoke emits one JSON per
//! bench target); tracked keys are looked up across all of them. A missing
//! baseline is not an error (first run: nothing to compare against yet); a
//! missing current file is — bench-smoke should have produced it. Keys
//! skipped because the baseline predates them are **listed explicitly in
//! the final verdict line**, so a truncated bench run can never masquerade
//! as a clean comparison. `OPTIONAL_KEYS` (the serving overload sweep) are
//! softer: compared when both sides carry them, listed as skipped when
//! either side doesn't. The parser is a deliberate 20-line scanner: the
//! files are emitted by our own benches as flat `"key": number` JSON, and
//! the crate builds fully offline, so no JSON dependency is warranted.

/// Tracked metrics: higher is better for all of them.
const KEYS: [&str; 8] = [
    "gflops_fused_serial",
    "gflops_fused_pooled",
    "gemm_gflops_q8_simd",
    "gemm_gflops_q4_simd",
    "gemv_gflops_8bit",
    "gemv_gflops_4bit",
    "decode_tok_s_raw_kv",
    "decode_tok_s_batched",
];

/// Optional tracked metrics (higher is better): compared only when present
/// in BOTH the current results and the baseline, listed as skipped in the
/// verdict line otherwise. The overload-sweep goodput, the prefix-share
/// decode sweep, the requant pressure sweep, and the hardware-gated
/// AVX-512 / pinned-worker cells land here because a missing row (quick
/// mode, older bench binary, a dims-incompatible bench model skipping the
/// requant sweep, a runner without avx512f or with a single core) is a
/// coverage gap to surface, not a hard gate failure like a vanished
/// kernel metric.
const OPTIONAL_KEYS: [&str; 9] = [
    "overload_goodput_rps_1x",
    "overload_goodput_rps_2x",
    "decode_tok_s_prefix_0",
    "decode_tok_s_prefix_0.5",
    "decode_tok_s_prefix_0.9",
    "requant_swaps",
    "requant_bytes_freed",
    "gemm_gflops_q8_avx512",
    "pinned_decode_tok_s",
];

/// Extract the number following `"key":` in a flat JSON document.
fn extract_number(json: &str, key: &str) -> Option<f64> {
    let needle = format!("\"{key}\"");
    let at = json.find(&needle)?;
    let rest = json[at + needle.len()..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract the string following `"key":` in a flat JSON document.
fn extract_string<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\"");
    let at = json.find(&needle)?;
    let rest = json[at + needle.len()..].trim_start().strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    rest.split('"').next()
}

/// The SIMD hard gate: on a runner whose kernels dispatched to a vector
/// path (`kernel_path != "scalar"`), the fused GEMM must be at least `min`
/// times the scalar GFLOP/s on Q8 and Q4 — vectorization that stops paying
/// is a regression even when the absolute numbers drift within tolerance.
/// Returns the number of violated ratios; reports each.
fn simd_gate(current: &str, min: f64) -> usize {
    let Some(path) = extract_string(current, "kernel_path") else {
        eprintln!("bench_compare: simd gate: kernel_path MISSING from current results");
        return 1;
    };
    if path == "scalar" {
        println!(
            "bench_compare: simd gate: SKIPPED (kernel_path = scalar: no vector unit \
             or EWQ_FORCE_SCALAR)"
        );
        return 0;
    }
    let mut violations = 0usize;
    for prec in ["q8", "q4"] {
        let scalar = extract_number(current, &format!("gemm_gflops_{prec}_scalar"));
        let simd = extract_number(current, &format!("gemm_gflops_{prec}_simd"));
        match (scalar, simd) {
            (Some(sc), Some(si)) if sc > 0.0 => {
                let ratio = si / sc;
                if ratio < min {
                    violations += 1;
                    eprintln!(
                        "bench_compare: simd gate: {prec} fused GEMM {path} is only \
                         {ratio:.2}x scalar ({si:.3} vs {sc:.3} GFLOP/s; need >= {min:.1}x)"
                    );
                } else {
                    println!(
                        "bench_compare: simd gate: {prec} fused GEMM {path} {ratio:.2}x \
                         scalar ({si:.3} vs {sc:.3} GFLOP/s) — ok"
                    );
                }
            }
            _ => {
                violations += 1;
                eprintln!(
                    "bench_compare: simd gate: gemm_gflops_{prec}_scalar/_simd MISSING \
                     from current results"
                );
            }
        }
    }
    violations
}

/// The continuous-batching hard gate: a fused `decode_step_batched` cohort
/// of 16 sequences must deliver at least `min` times the serial
/// per-sequence decode throughput. Both numbers come from the same
/// bench_decode run on the same machine, so the ratio gates the
/// amortization + shard-pool win itself, independent of runner speed —
/// batching that stops paying is a regression even when the absolute
/// numbers drift within tolerance. Returns the number of violations (0/1).
fn batched_gate(current: &str, min: f64) -> usize {
    let batched = extract_number(current, "decode_tok_s_batched");
    let per_seq = extract_number(current, "decode_tok_s_raw_kv");
    match (batched, per_seq) {
        (Some(b), Some(p)) if p > 0.0 => {
            let ratio = b / p;
            if ratio < min {
                eprintln!(
                    "bench_compare: batched gate: batch-16 decode is only {ratio:.2}x the \
                     per-sequence path ({b:.1} vs {p:.1} tok/s; need >= {min:.1}x)"
                );
                1
            } else {
                println!(
                    "bench_compare: batched gate: batch-16 decode {ratio:.2}x per-sequence \
                     ({b:.1} vs {p:.1} tok/s) — ok"
                );
                0
            }
        }
        _ => {
            eprintln!(
                "bench_compare: batched gate: decode_tok_s_batched/decode_tok_s_raw_kv \
                 MISSING from current results"
            );
            1
        }
    }
}

/// A higher-is-better metric regressed if it dropped by more than `tol`
/// (fractional) below the baseline.
fn regressed(current: f64, baseline: f64, tol: f64) -> bool {
    baseline > 0.0 && current < baseline * (1.0 - tol)
}

/// Print one metric's verdict line; returns whether it regressed.
fn report(key: &str, cur: f64, base: f64, tol: f64) -> bool {
    let ratio = if base > 0.0 { cur / base } else { f64::INFINITY };
    let verdict = if regressed(cur, base, tol) {
        "REGRESSED"
    } else if ratio >= 1.0 + tol {
        "improved (consider refreshing the baseline)"
    } else {
        "ok"
    };
    println!(
        "bench_compare: {key}: current {cur:.3} vs baseline {base:.3} ({ratio:.2}x) — {verdict}"
    );
    regressed(cur, base, tol)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (current_paths, baseline_path) = match args.as_slice() {
        [currents @ .., b] if !currents.is_empty() => (currents.to_vec(), b.clone()),
        _ => {
            eprintln!("usage: bench_compare <current.json>... <baseline.json>");
            std::process::exit(2);
        }
    };
    let tol: f64 = std::env::var("EWQ_BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.20);
    let enforce = !matches!(
        std::env::var("EWQ_BENCH_COMPARE_MODE").as_deref(),
        Ok("warn")
    );

    // tracked keys are looked up across the concatenation of every current
    // file (one JSON per bench target, all flat and disjoint)
    let mut current = String::new();
    for p in &current_paths {
        match std::fs::read_to_string(p) {
            Ok(c) => current.push_str(&c),
            Err(e) => {
                eprintln!("bench_compare: cannot read current results {p}: {e}");
                std::process::exit(1);
            }
        }
    }
    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(b) => b,
        Err(_) => {
            println!(
                "bench_compare: no baseline at {baseline_path} — first run, nothing to \
                 compare (commit one to arm the gate)"
            );
            return;
        }
    };

    let simd_min: f64 = std::env::var("EWQ_BENCH_SIMD_MIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.0);
    let batched_min: f64 = std::env::var("EWQ_BENCH_BATCHED_MIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);
    let mut regressions = simd_gate(&current, simd_min) + batched_gate(&current, batched_min);
    let mut skipped: Vec<&str> = Vec::new();
    for key in KEYS {
        let cur = match extract_number(&current, key) {
            Some(c) => c,
            None => {
                // a tracked metric vanishing from the bench output is itself
                // a gate failure — otherwise schema drift disarms the gate
                // silently and forever
                eprintln!(
                    "bench_compare: {key}: MISSING from current results ({})",
                    current_paths.join(", ")
                );
                regressions += 1;
                continue;
            }
        };
        let Some(base) = extract_number(&baseline, key) else {
            // baseline may predate a newly tracked key: skip the
            // comparison, but carry the skip into the final verdict line —
            // a truncated or partial run must stay visible
            println!("bench_compare: {key}: SKIPPED (not in baseline yet)");
            skipped.push(key);
            continue;
        };
        if report(key, cur, base, tol) {
            regressions += 1;
        }
    }
    for key in OPTIONAL_KEYS {
        match (extract_number(&current, key), extract_number(&baseline, key)) {
            (Some(cur), Some(base)) => {
                if report(key, cur, base, tol) {
                    regressions += 1;
                }
            }
            (cur, base) => {
                println!(
                    "bench_compare: {key}: SKIPPED (optional; in current: {}, in baseline: {})",
                    cur.is_some(),
                    base.is_some()
                );
                skipped.push(key);
            }
        }
    }

    let skip_note = if skipped.is_empty() {
        String::new()
    } else {
        format!(" — {} key(s) skipped, NOT compared: [{}]", skipped.len(), skipped.join(", "))
    };
    if regressions > 0 {
        let pct = tol * 100.0;
        if enforce {
            eprintln!(
                "bench_compare: {regressions} metric(s) regressed more than {pct:.0}%, went \
                 missing, or violated the simd/batched gates{skip_note} — failing (set \
                 EWQ_BENCH_COMPARE_MODE=warn to downgrade)"
            );
            std::process::exit(1);
        }
        println!(
            "bench_compare: {regressions} metric(s) regressed more than {pct:.0}%, went \
             missing, or violated the simd/batched gates{skip_note} — warn-only mode, not failing"
        );
    } else {
        println!("bench_compare: within {:.0}% of baseline{skip_note}", tol * 100.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "model": "syn-kernels",
  "workers": 4,
  "fused_serial_ms": 12.3456,
  "gflops_fused_serial": 1.234,
  "gflops_fused_pooled": 4.5,
  "resident_ratio_vs_f32": 0.2656
}"#;

    #[test]
    fn extracts_numbers_from_flat_json() {
        assert_eq!(extract_number(SAMPLE, "gflops_fused_serial"), Some(1.234));
        assert_eq!(extract_number(SAMPLE, "gflops_fused_pooled"), Some(4.5));
        assert_eq!(extract_number(SAMPLE, "workers"), Some(4.0));
        assert_eq!(extract_number(SAMPLE, "resident_ratio_vs_f32"), Some(0.2656));
        assert_eq!(extract_number(SAMPLE, "missing_key"), None);
        assert_eq!(extract_number("", "x"), None);
        // a string value is not a number
        assert_eq!(extract_number(SAMPLE, "model"), None);
    }

    #[test]
    fn scientific_notation_and_negatives_parse() {
        let doc = r#"{ "a": -3.5, "b": 1.2e-3 }"#;
        assert_eq!(extract_number(doc, "a"), Some(-3.5));
        assert_eq!(extract_number(doc, "b"), Some(1.2e-3));
    }

    #[test]
    fn extracts_strings_from_flat_json() {
        assert_eq!(extract_string(SAMPLE, "model"), Some("syn-kernels"));
        assert_eq!(extract_string(SAMPLE, "missing"), None);
        // a number value is not a string
        assert_eq!(extract_string(SAMPLE, "workers"), None);
        let doc = r#"{ "kernel_path": "avx2", "gemm_banding": "rows" }"#;
        assert_eq!(extract_string(doc, "kernel_path"), Some("avx2"));
        assert_eq!(extract_string(doc, "gemm_banding"), Some("rows"));
    }

    #[test]
    fn simd_gate_passes_skips_and_fails() {
        let pass = r#"{ "kernel_path": "avx2",
            "gemm_gflops_q8_scalar": 1.0, "gemm_gflops_q8_simd": 2.5,
            "gemm_gflops_q4_scalar": 1.0, "gemm_gflops_q4_simd": 2.0 }"#;
        assert_eq!(simd_gate(pass, 2.0), 0, "at or above the ratio passes");
        let fail = r#"{ "kernel_path": "avx2",
            "gemm_gflops_q8_scalar": 1.0, "gemm_gflops_q8_simd": 1.5,
            "gemm_gflops_q4_scalar": 1.0, "gemm_gflops_q4_simd": 2.5 }"#;
        assert_eq!(simd_gate(fail, 2.0), 1, "one ratio below the bar");
        let scalar = r#"{ "kernel_path": "scalar" }"#;
        assert_eq!(simd_gate(scalar, 2.0), 0, "scalar runners skip the gate");
        assert_eq!(simd_gate("{}", 2.0), 1, "missing kernel_path is a failure");
        let partial = r#"{ "kernel_path": "avx2", "gemm_gflops_q8_scalar": 1.0 }"#;
        assert_eq!(simd_gate(partial, 2.0), 2, "missing ratio inputs fail both");
    }

    #[test]
    fn batched_gate_ratio_and_missing_keys() {
        let pass = r#"{ "decode_tok_s_raw_kv": 100.0, "decode_tok_s_batched": 350.0 }"#;
        assert_eq!(batched_gate(pass, 3.0), 0, "at or above the ratio passes");
        let fail = r#"{ "decode_tok_s_raw_kv": 100.0, "decode_tok_s_batched": 250.0 }"#;
        assert_eq!(batched_gate(fail, 3.0), 1, "below the ratio fails");
        assert_eq!(batched_gate(fail, 2.0), 0, "EWQ_BENCH_BATCHED_MIN lowers the bar");
        assert_eq!(
            batched_gate(r#"{ "decode_tok_s_batched": 250.0 }"#, 3.0),
            1,
            "a vanished per-sequence key must not disarm the gate"
        );
        assert_eq!(batched_gate("{}", 3.0), 1, "missing keys are a failure");
    }

    #[test]
    fn regression_threshold_is_fractional_drop() {
        assert!(!regressed(1.0, 1.0, 0.20), "equal is fine");
        assert!(!regressed(0.81, 1.0, 0.20), "within tolerance");
        assert!(regressed(0.79, 1.0, 0.20), "past tolerance");
        assert!(!regressed(2.0, 1.0, 0.20), "improvement is fine");
        assert!(!regressed(0.0, 0.0, 0.20), "degenerate baseline never fails");
    }

    #[test]
    fn prefix_sweep_keys_do_not_alias() {
        // "decode_tok_s_prefix_0" must never read "decode_tok_s_prefix_0.5"'s
        // value: the needle includes both quotes, so the shorter key only
        // matches its own entry regardless of emission order
        let doc = r#"{ "decode_tok_s_prefix_0.5": 150.0, "decode_tok_s_prefix_0.9": 200.0,
            "decode_tok_s_prefix_0": 100.0 }"#;
        assert_eq!(extract_number(doc, "decode_tok_s_prefix_0"), Some(100.0));
        assert_eq!(extract_number(doc, "decode_tok_s_prefix_0.5"), Some(150.0));
        assert_eq!(extract_number(doc, "decode_tok_s_prefix_0.9"), Some(200.0));
    }

    #[test]
    fn optional_keys_are_disjoint_from_required() {
        // an optional key shadowing a required one would silently soften
        // the hard gate for it
        for k in OPTIONAL_KEYS {
            assert!(!KEYS.contains(&k), "{k} is both required and optional");
        }
    }

    #[test]
    fn report_flags_only_regressions() {
        assert!(report("k", 0.5, 1.0, 0.20));
        assert!(!report("k", 0.9, 1.0, 0.20), "within tolerance");
        assert!(!report("k", 5.0, 1.0, 0.20), "improvement never fails");
        assert!(!report("k", 1.0, 0.0, 0.20), "degenerate baseline never fails");
    }
}
