//! Report rendering: fixed-width tables and ASCII figures (histograms, bar
//! charts, scatter/line plots) used by `ewq exp <id>` to regenerate every
//! paper table and figure in the terminal, plus CSV emission for plotting.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Horizontal ASCII bar chart (used for Fig. 2 histograms / Fig. 5 importances).
pub fn bar_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let maxv = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = format!("-- {title} --\n");
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / maxv) * width as f64).round().max(0.0) as usize;
        out.push_str(&format!("{l:>lw$} | {} {v:.4}\n", "#".repeat(n)));
    }
    out
}

/// Histogram of values into `bins` equal-width buckets, rendered as bars.
pub fn histogram(title: &str, values: &[f64], bins: usize, width: usize) -> String {
    assert!(bins > 0 && !values.is_empty());
    let lo = values.iter().cloned().fold(f64::MAX, f64::min);
    let hi = values.iter().cloned().fold(f64::MIN, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &v in values {
        let mut b = ((v - lo) / span * bins as f64) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    let labels: Vec<String> = (0..bins)
        .map(|b| format!("[{:.3},{:.3})", lo + span * b as f64 / bins as f64, lo + span * (b + 1) as f64 / bins as f64))
        .collect();
    bar_chart(title, &labels, &counts.iter().map(|&c| c as f64).collect::<Vec<_>>(), width)
}

/// Simple y-vs-x ASCII line/scatter plot (Fig. 1 entropy-vs-block, Fig. 6 ROC).
pub fn scatter(title: &str, xs: &[f64], ys: &[f64], rows: usize, cols: usize) -> String {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let (x0, x1) = (
        xs.iter().cloned().fold(f64::MAX, f64::min),
        xs.iter().cloned().fold(f64::MIN, f64::max),
    );
    let (y0, y1) = (
        ys.iter().cloned().fold(f64::MAX, f64::min),
        ys.iter().cloned().fold(f64::MIN, f64::max),
    );
    let xs_span = (x1 - x0).max(1e-12);
    let ys_span = (y1 - y0).max(1e-12);
    let mut grid = vec![vec![b' '; cols]; rows];
    for (&x, &y) in xs.iter().zip(ys) {
        let c = (((x - x0) / xs_span) * (cols - 1) as f64).round() as usize;
        let r = (((y - y0) / ys_span) * (rows - 1) as f64).round() as usize;
        grid[rows - 1 - r][c] = b'*';
    }
    let mut out = format!("-- {title} --  y:[{y0:.4},{y1:.4}] x:[{x0:.2},{x1:.2}]\n");
    for row in grid {
        out.push('|');
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(cols));
    out.push('\n');
    out
}

/// Format a fraction as a percentage string with sign, e.g. -18.98%.
pub fn pct(x: f64) -> String {
    format!("{:+.2}%", 100.0 * x)
}

/// Human-readable byte count (B / KiB / MiB / GiB).
pub fn bytes_human(b: usize) -> String {
    const K: f64 = 1024.0;
    let x = b as f64;
    if x < K {
        format!("{b} B")
    } else if x < K * K {
        format!("{:.1} KiB", x / K)
    } else if x < K * K * K {
        format!("{:.1} MiB", x / (K * K))
    } else {
        format!("{:.1} GiB", x / (K * K * K))
    }
}

/// Compact rendering of a block-precision residency histogram (indexed by
/// `Precision::tag()`): non-empty buckets as `label:count`, e.g.
/// `8bit:20 4bit:10 3bit:2`. `empty` when no blocks are booked at all.
pub fn residency_compact(counts: &[usize; 5]) -> String {
    let parts: Vec<String> = counts
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(tag, &c)| {
            let label = crate::quant::Precision::from_tag(tag as u8)
                .map(|p| p.label())
                .unwrap_or("?");
            format!("{label}:{c}")
        })
        .collect();
    if parts.is_empty() {
        "empty".to_string()
    } else {
        parts.join(" ")
    }
}

/// Resident-weight accounting table: one row per `(label, resident_bytes,
/// f32_baseline_bytes)` triple — what a replica actually pins when serving
/// from packed payloads vs the same weights held fully in f32
/// (`QuantizedModel::f32_equivalent_bytes`). The memory-reduction claim,
/// rendered.
pub fn resident_table(rows: &[(String, usize, usize)]) -> Table {
    let mut t = Table::new(
        "resident weight bytes (packed vs fully-f32 baseline)",
        &["plan", "resident", "f32-baseline", "ratio", "reduction"],
    );
    for (label, resident, baseline) in rows {
        let ratio = *resident as f64 / (*baseline).max(1) as f64;
        t.row(vec![
            label.clone(),
            bytes_human(*resident),
            bytes_human(*baseline),
            format!("{ratio:.3}"),
            pct(ratio - 1.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "acc"]);
        t.row(vec!["tl-llama".into(), "0.68".into()]);
        t.row(vec!["x".into(), "0.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("tl-llama"));
        let lines: Vec<&str> = s.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new("t", &["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn histogram_counts_everything() {
        let vals = vec![0.0, 0.1, 0.5, 0.9, 1.0];
        let h = histogram("h", &vals, 2, 10);
        assert!(h.contains("#"));
    }

    #[test]
    fn scatter_contains_points() {
        let s = scatter("s", &[0.0, 1.0, 2.0], &[0.0, 1.0, 4.0], 5, 20);
        assert_eq!(s.matches('*').count(), 3);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(-0.1898), "-18.98%");
        assert_eq!(pct(0.0032), "+0.32%");
    }

    #[test]
    fn bytes_human_units() {
        assert_eq!(bytes_human(0), "0 B");
        assert_eq!(bytes_human(512), "512 B");
        assert_eq!(bytes_human(2048), "2.0 KiB");
        assert_eq!(bytes_human(5 * 1024 * 1024 + 512 * 1024), "5.5 MiB");
        assert_eq!(bytes_human(3 * 1024 * 1024 * 1024), "3.0 GiB");
    }

    #[test]
    fn residency_compact_skips_empty_buckets() {
        assert_eq!(residency_compact(&[0, 0, 0, 0, 0]), "empty");
        assert_eq!(residency_compact(&[0, 20, 10, 2, 0]), "8bit:20 4bit:10 3bit:2");
        assert_eq!(residency_compact(&[1, 0, 0, 0, 3]), "raw:1 1.58bit:3");
    }

    #[test]
    fn resident_table_rows_and_ratio() {
        let t = resident_table(&[
            ("mixed".into(), 250, 1000),
            ("raw".into(), 1000, 1000),
        ]);
        let s = t.render();
        assert!(s.contains("mixed"));
        assert!(s.contains("0.250"));
        assert!(s.contains("-75.00%"));
        assert!(s.contains("1.000"));
        let csv = t.to_csv();
        assert!(csv.starts_with("plan,resident,f32-baseline,ratio,reduction"));
    }
}
