//! Network-topology-aware block placement (paper §3.4: "the block
//! distribution algorithm dynamically adjusts to network topology,
//! prioritizing block placement that minimizes cross-machine communication
//! during inference").
//!
//! Topologies assign a per-pair latency; placement cost is the summed
//! latency along the sequential inference path embed → block₀ → … → head.

use crate::ewq::QuantPlan;
use crate::quant::Precision;
use crate::zoo::Schema;

use super::{Cluster, Distribution};

/// Pairwise latency model between machines.
#[derive(Clone, Debug)]
pub enum Topology {
    /// every pair at the same latency
    FullMesh { latency_us: u64 },
    /// machines on a ring; latency = hop-distance * per_hop
    Ring { per_hop_us: u64 },
    /// leaf-spine: intra-rack cheap, cross-rack expensive
    TwoTier { rack_size: usize, intra_us: u64, cross_us: u64 },
}

impl Topology {
    pub fn latency_us(&self, a: usize, b: usize, n_machines: usize) -> u64 {
        if a == b {
            return 0;
        }
        match *self {
            Topology::FullMesh { latency_us } => latency_us,
            Topology::Ring { per_hop_us } => {
                let d = a.abs_diff(b);
                let d = d.min(n_machines - d);
                d as u64 * per_hop_us
            }
            Topology::TwoTier { rack_size, intra_us, cross_us } => {
                if a / rack_size == b / rack_size {
                    intra_us
                } else {
                    cross_us
                }
            }
        }
    }
}

/// Total network latency of one forward pass under a placement
/// (outer machine hosts embed + head, so the path returns to it).
pub fn path_latency_us(
    placement: &[usize],
    outer_machine: usize,
    topo: &Topology,
    n_machines: usize,
) -> u64 {
    let mut total = 0u64;
    let mut prev = outer_machine;
    for &m in placement {
        total += topo.latency_us(prev, m, n_machines);
        prev = m;
    }
    total + topo.latency_us(prev, outer_machine, n_machines)
}

/// Per-machine byte loads of a placement.
pub fn machine_loads(
    plan: &QuantPlan,
    placement: &[usize],
    outer_machine: usize,
    schema: &Schema,
    n_machines: usize,
) -> Vec<usize> {
    let mut load = vec![0usize; n_machines];
    load[outer_machine] += schema.total_raw_bytes() - schema.blocks_raw_bytes();
    for (b, &m) in placement.iter().enumerate() {
        let p = plan.assignments[b];
        let mats: usize = schema.mat_shapes().iter().map(|&(k, n)| p.matrix_bytes(k, n)).sum();
        load[m] += mats + 4 * 2 * schema.d_model;
    }
    load
}

/// Greedy topology-aware refinement: starting from a distribution, move
/// single blocks between machines whenever the move reduces path latency
/// and respects capacity. Deterministic, terminates (latency strictly
/// decreases each accepted move).
pub fn refine_placement(
    dist: &Distribution,
    schema: &Schema,
    cluster: &Cluster,
    topo: &Topology,
) -> Distribution {
    let n_machines = cluster.machines.len();
    let mut placement = dist.placement.clone();
    let mut loads =
        machine_loads(&dist.plan, &placement, dist.outer_machine, schema, n_machines);

    let block_bytes = |p: Precision| -> usize {
        schema.mat_shapes().iter().map(|&(k, n)| p.matrix_bytes(k, n)).sum::<usize>()
            + 4 * 2 * schema.d_model
    };

    let mut improved = true;
    while improved {
        improved = false;
        for b in 0..placement.len() {
            let cur = placement[b];
            let bytes = block_bytes(dist.plan.assignments[b]);
            let base = path_latency_us(&placement, dist.outer_machine, topo, n_machines);
            let mut best: Option<(u64, usize)> = None;
            for m in 0..n_machines {
                if m == cur || loads[m] + bytes > cluster.machines[m].capacity() {
                    continue;
                }
                placement[b] = m;
                let lat = path_latency_us(&placement, dist.outer_machine, topo, n_machines);
                if lat < base && best.map(|(l, _)| lat < l).unwrap_or(true) {
                    best = Some((lat, m));
                }
            }
            placement[b] = cur;
            if let Some((_, m)) = best {
                loads[cur] -= bytes;
                loads[m] += bytes;
                placement[b] = m;
                improved = true;
            }
        }
    }

    let hops = {
        let mut h = 0usize;
        let mut prev = dist.outer_machine;
        for &m in &placement {
            if m != prev {
                h += 1;
            }
            prev = m;
        }
        if prev != dist.outer_machine {
            h += 1;
        }
        h
    };
    Distribution { plan: dist.plan.clone(), placement, outer_machine: dist.outer_machine, fits: dist.fits, hops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{optimize_distribution, Cluster};
    use crate::entropy::EntropyStats;
    use crate::ewq::{BlockAnalysis, EwqConfig, ModelAnalysis};
    use crate::proptest_lite::check;

    fn schema(n_blocks: usize) -> Schema {
        Schema {
            name: "t".into(),
            n_blocks,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            vocab: 512,
            seq_len: 32,
            eval_batch: 8,
        }
    }

    fn analysis(n: usize) -> ModelAnalysis {
        let s = schema(n);
        let hs: Vec<f64> = (0..n).map(|i| 4.0 + 0.3 * i as f64).collect();
        ModelAnalysis {
            model: "t".into(),
            blocks: hs
                .iter()
                .enumerate()
                .map(|(i, &h)| BlockAnalysis {
                    block: i,
                    exec_index: s.exec_index(i),
                    entropy: h,
                    params: s.block_params(),
                })
                .collect(),
            stats: EntropyStats::from_values(&hs),
        }
    }

    #[test]
    fn ring_latency_is_symmetric_shortest_path() {
        let t = Topology::Ring { per_hop_us: 10 };
        assert_eq!(t.latency_us(0, 1, 6), 10);
        assert_eq!(t.latency_us(0, 5, 6), 10); // wraps around
        assert_eq!(t.latency_us(0, 3, 6), 30);
        assert_eq!(t.latency_us(2, 2, 6), 0);
        assert_eq!(t.latency_us(1, 4, 6), t.latency_us(4, 1, 6));
    }

    #[test]
    fn two_tier_rack_locality() {
        let t = Topology::TwoTier { rack_size: 2, intra_us: 5, cross_us: 100 };
        assert_eq!(t.latency_us(0, 1, 4), 5);
        assert_eq!(t.latency_us(0, 2, 4), 100);
        assert_eq!(t.latency_us(2, 3, 4), 5);
    }

    #[test]
    fn path_latency_counts_return_hop() {
        let t = Topology::FullMesh { latency_us: 7 };
        // outer=0, blocks on [0,1,1,0]: hops 0->0(0) 0->1(7) 1->1(0) 1->0(7) 0->0(0)
        assert_eq!(path_latency_us(&[0, 1, 1, 0], 0, &t, 2), 14);
        // all on outer machine: zero
        assert_eq!(path_latency_us(&[0, 0, 0], 0, &t, 2), 0);
    }

    #[test]
    fn refinement_never_increases_latency_and_respects_capacity() {
        check(
            11,
            30,
            12,
            |g| (g.usize_in(4, 12), g.usize_in(2, 5), g.usize_in(0, 3)),
            |&(n_blocks, n_machines, topo_kind)| {
                let s = schema(n_blocks);
                let a = analysis(n_blocks);
                let per = s.total_raw_bytes() * 2 / n_machines.max(1) + 100_000;
                let cluster = Cluster::uniform(n_machines, per, per);
                let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
                let topo = match topo_kind {
                    0 => Topology::FullMesh { latency_us: 50 },
                    1 => Topology::Ring { per_hop_us: 20 },
                    _ => Topology::TwoTier { rack_size: 2, intra_us: 5, cross_us: 80 },
                };
                let before =
                    path_latency_us(&d.placement, d.outer_machine, &topo, n_machines);
                let r = refine_placement(&d, &s, &cluster, &topo);
                let after = path_latency_us(&r.placement, r.outer_machine, &topo, n_machines);
                if after > before {
                    return Err(format!("refinement worsened latency {before} -> {after}"));
                }
                let loads = machine_loads(&r.plan, &r.placement, r.outer_machine, &s, n_machines);
                for (m, l) in loads.iter().enumerate() {
                    if *l > cluster.machines[m].capacity() {
                        return Err(format!("machine {m} over capacity"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn refinement_consolidates_under_full_mesh() {
        // with one machine big enough for everything, refinement should pull
        // every block onto the outer machine (zero network latency)
        let s = schema(6);
        let a = analysis(6);
        let big = s.total_raw_bytes() * 2;
        let cluster = Cluster::new(vec![
            super::super::Machine::new("big", big, big),
            super::super::Machine::new("small", big, big),
        ]);
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        let topo = Topology::FullMesh { latency_us: 100 };
        let r = refine_placement(&d, &s, &cluster, &topo);
        assert_eq!(
            path_latency_us(&r.placement, r.outer_machine, &topo, 2),
            0,
            "placement {:?} outer {}",
            r.placement,
            r.outer_machine
        );
    }
}
