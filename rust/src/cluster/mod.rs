//! Deployment-cluster modelling + the paper's distribution optimizers:
//! **Algorithm 1** (EWQ-driven promote/demote under resource limit R) and
//! **Algorithm 2** (FastEWQ classifier-driven, exec_index-ordered).
//!
//! The cluster is simulated (DESIGN.md §2): machines expose memory/disk
//! budgets and a per-hop link latency used by the serving coordinator.
//! `topology` adds pairwise-latency models + placement refinement.

pub mod topology;

use crate::ewq::{EwqConfig, ModelAnalysis, QuantPlan};
use crate::quant::Precision;
use crate::zoo::Schema;

/// One inference machine: Z = min(memory, disk) is its usable capacity.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: String,
    pub mem_bytes: usize,
    pub disk_bytes: usize,
}

impl Machine {
    pub fn new(name: &str, mem_bytes: usize, disk_bytes: usize) -> Self {
        Self { name: name.into(), mem_bytes, disk_bytes }
    }

    /// Z_i = min(X_i, Y_i) (paper §3.4).
    pub fn capacity(&self) -> usize {
        self.mem_bytes.min(self.disk_bytes)
    }
}

#[derive(Clone, Debug)]
pub struct Cluster {
    pub machines: Vec<Machine>,
    /// Simulated one-way latency charged per cross-machine hop at inference.
    pub link_latency_us: u64,
}

impl Cluster {
    pub fn new(machines: Vec<Machine>) -> Self {
        Self { machines, link_latency_us: 200 }
    }

    /// Uniform cluster of n identical machines.
    pub fn uniform(n: usize, mem: usize, disk: usize) -> Self {
        Self::new((0..n).map(|i| Machine::new(&format!("m{i}"), mem, disk)).collect())
    }

    /// R = Σ Z_i — aggregate resources (paper §3.4).
    pub fn total_resources(&self) -> usize {
        self.machines.iter().map(|m| m.capacity()).sum()
    }
}

/// Outcome of a distribution optimization.
#[derive(Clone, Debug)]
pub struct Distribution {
    pub plan: QuantPlan,
    /// machine index for each block (same order as plan.assignments).
    pub placement: Vec<usize>,
    /// machine hosting embedding/head ("block 1" in the paper's numbering).
    pub outer_machine: usize,
    /// whether the final size fits in the cluster's R.
    pub fits: bool,
    /// cross-machine boundaries on the sequential inference path.
    pub hops: usize,
}

impl Distribution {
    pub fn total_bytes(&self, schema: &Schema) -> usize {
        self.plan.total_bytes(schema)
    }

    /// Simulated added network latency for one forward pass.
    pub fn network_latency_us(&self, cluster: &Cluster) -> u64 {
        self.hops as u64 * cluster.link_latency_us
    }
}

fn block_bytes(schema: &Schema, p: Precision) -> usize {
    schema.mat_shapes().iter().map(|&(k, n)| p.matrix_bytes(k, n)).sum::<usize>()
        + 4 * 2 * schema.d_model
}

fn outer_bytes(schema: &Schema) -> usize {
    schema.total_raw_bytes() - schema.blocks_raw_bytes()
}

fn plan_total(plan: &QuantPlan, schema: &Schema) -> usize {
    plan.total_bytes(schema)
}

/// **Algorithm 1** — Optimized distribution of transformer blocks.
///
/// 1. R = Σ Z_i; deploy raw if it fits.
/// 2. Start from the EWQ quantization decision.
/// 3. If S < R: promote blocks in DESCENDING entropy (8bit→raw, 4bit→8bit→raw)
///    while resources allow.
/// 4. If S > R: demote blocks in ASCENDING entropy to 1.58-bit until it fits.
/// 5. Place blocks across machines (largest capacity first, contiguous runs
///    to minimize cross-machine hops).
pub fn optimize_distribution(
    analysis: &ModelAnalysis,
    schema: &Schema,
    cluster: &Cluster,
    cfg: &EwqConfig,
) -> Distribution {
    let r = cluster.total_resources();
    let n = analysis.blocks.len();

    // Step 1: unquantized deployment if possible.
    let raw_plan = QuantPlan::uniform(&analysis.model, n, Precision::Raw);
    if plan_total(&raw_plan, schema) <= r {
        return place(raw_plan, schema, cluster);
    }

    // Step 2: EWQ decision as the starting point.
    let mut plan = crate::ewq::decide(analysis, cfg);
    let ascending = plan.priority.clone(); // ascending entropy
    let mut s = plan_total(&plan, schema);

    // Step 3: promotion loop — highest entropy first.
    if s <= r {
        for &b in ascending.iter().rev() {
            loop {
                let cur = plan.assignments[b];
                let next = match cur {
                    Precision::Raw => break,
                    Precision::Q8 => Precision::Raw,
                    Precision::Q4 | Precision::Q3 => Precision::Q8,
                    Precision::T2 => Precision::Q4,
                };
                let delta = block_bytes(schema, next) - block_bytes(schema, cur);
                if s + delta <= r {
                    plan.assignments[b] = next;
                    s += delta;
                } else {
                    break;
                }
            }
        }
    }

    // Step 4: demotion loop — lowest entropy first, down to 1.58-bit.
    if s > r {
        for &b in &ascending {
            if s <= r {
                break;
            }
            let cur = plan.assignments[b];
            if cur == Precision::T2 {
                continue;
            }
            let delta = block_bytes(schema, cur) - block_bytes(schema, Precision::T2);
            plan.assignments[b] = Precision::T2;
            s -= delta;
        }
    }

    place(plan, schema, cluster)
}

/// **Algorithm 2** — FastEWQ distribution: `selected` marks blocks the O(1)
/// classifier flagged for quantization. Selected blocks start at 8-bit;
/// spare resources promote LOW exec_index blocks back to raw; deficits
/// demote HIGH exec_index blocks to 4-bit then 1.58-bit.
pub fn fastewq_distribution(
    model: &str,
    selected: &[bool],
    schema: &Schema,
    cluster: &Cluster,
) -> Distribution {
    let r = cluster.total_resources();
    let n = selected.len();
    let mut plan = QuantPlan {
        model: model.into(),
        assignments: selected
            .iter()
            .map(|&q| if q { Precision::Q8 } else { Precision::Raw })
            .collect(),
        // priority = descending exec_index (later blocks quantize first)
        priority: (0..n).rev().collect(),
    };
    let mut s = plan_total(&plan, schema);

    if s <= r {
        // promote selected blocks with LOWEST exec_index first
        for b in 0..n {
            if !selected[b] || plan.assignments[b] == Precision::Raw {
                continue;
            }
            let delta = block_bytes(schema, Precision::Raw) - block_bytes(schema, Precision::Q8);
            if s + delta <= r {
                plan.assignments[b] = Precision::Raw;
                s += delta;
            } else {
                break;
            }
        }
    } else {
        // demote selected blocks with HIGHEST exec_index first: Q8→Q4→T2
        for step in [Precision::Q4, Precision::T2] {
            for b in (0..n).rev() {
                if s <= r {
                    break;
                }
                if !selected[b] {
                    continue;
                }
                let cur = plan.assignments[b];
                if cur <= step {
                    continue;
                }
                let delta = block_bytes(schema, cur) - block_bytes(schema, step);
                plan.assignments[b] = step;
                s -= delta;
            }
        }
    }

    place(plan, schema, cluster)
}

/// §3.4 edge mode: a 4-bit/3-bit combination for severely constrained
/// devices — high-entropy blocks keep 4-bit, the rest drop to 3-bit.
pub fn edge_plan(analysis: &ModelAnalysis, _schema: &Schema) -> QuantPlan {
    let mu = analysis.stats.mean;
    QuantPlan {
        model: analysis.model.clone(),
        assignments: analysis
            .blocks
            .iter()
            .map(|b| if b.entropy > mu { Precision::Q4 } else { Precision::Q3 })
            .collect(),
        priority: analysis.ascending(),
    }
}

/// Greedy placement: machines sorted by descending capacity; the outer
/// (embedding/head) payload goes first, then blocks in execution order so
/// contiguous runs share a machine and hops are minimized.
fn place(plan: QuantPlan, schema: &Schema, cluster: &Cluster) -> Distribution {
    let mut order: Vec<usize> = (0..cluster.machines.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(cluster.machines[i].capacity()));

    let fits = plan_total(&plan, schema) <= cluster.total_resources();
    let mut remaining: Vec<usize> = order.iter().map(|&i| cluster.machines[i].capacity()).collect();

    let mut cursor = 0usize;
    let mut take = |bytes: usize, remaining: &mut Vec<usize>| -> usize {
        while cursor < remaining.len() && remaining[cursor] < bytes {
            cursor += 1;
        }
        let m = cursor.min(remaining.len() - 1);
        remaining[m] = remaining[m].saturating_sub(bytes);
        m
    };

    let outer_machine = order[take(outer_bytes(schema), &mut remaining)];
    let mut placement = Vec::with_capacity(plan.assignments.len());
    let mut hops = 0usize;
    let mut prev = outer_machine;
    for &p in &plan.assignments {
        let m = order[take(block_bytes(schema, p), &mut remaining)];
        if m != prev {
            hops += 1;
        }
        prev = m;
        placement.push(m);
    }
    // final head hop back to the outer machine if the last block is elsewhere
    if prev != outer_machine {
        hops += 1;
    }

    Distribution { plan, placement, outer_machine, fits, hops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::EntropyStats;
    use crate::ewq::BlockAnalysis;
    use crate::proptest_lite::check;

    fn schema(n_blocks: usize) -> Schema {
        Schema {
            name: "t".into(),
            n_blocks,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            vocab: 512,
            seq_len: 32,
            eval_batch: 8,
        }
    }

    fn analysis(hs: &[f64]) -> ModelAnalysis {
        let s = schema(hs.len());
        ModelAnalysis {
            model: "t".into(),
            blocks: hs
                .iter()
                .enumerate()
                .map(|(i, &h)| BlockAnalysis {
                    block: i,
                    exec_index: s.exec_index(i),
                    entropy: h,
                    params: s.block_params(),
                })
                .collect(),
            stats: EntropyStats::from_values(hs),
        }
    }

    #[test]
    fn plentiful_cluster_deploys_raw() {
        let hs: Vec<f64> = (0..8).map(|i| 4.0 + i as f64 * 0.2).collect();
        let a = analysis(&hs);
        let s = schema(8);
        let cluster = Cluster::uniform(2, 1 << 30, 1 << 30);
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        assert!(d.fits);
        assert_eq!(d.plan.counts().0, 8, "all raw");
    }

    #[test]
    fn starved_cluster_demotes_to_ternary() {
        let hs: Vec<f64> = (0..8).map(|i| 4.0 + i as f64 * 0.2).collect();
        let a = analysis(&hs);
        let s = schema(8);
        // capacity barely above the all-T2 floor
        let t2_plan = QuantPlan::uniform("t", 8, Precision::T2);
        let floor = t2_plan.total_bytes(&s);
        let cluster = Cluster::uniform(1, floor + 2048, floor + 2048);
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        assert!(d.fits, "should fit by demoting");
        assert!(d.plan.counts().4 > 0, "uses 1.58-bit blocks: {:?}", d.plan.counts());
        assert!(d.total_bytes(&s) <= cluster.total_resources());
    }

    #[test]
    fn infeasible_cluster_reports_not_fitting() {
        let hs: Vec<f64> = (0..8).map(|i| 4.0 + i as f64 * 0.2).collect();
        let a = analysis(&hs);
        let s = schema(8);
        let cluster = Cluster::uniform(1, 1024, 1024); // absurdly small
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        assert!(!d.fits);
    }

    #[test]
    fn promotion_prefers_high_entropy_blocks() {
        let hs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let a = analysis(&hs);
        let s = schema(8);
        // budget: EWQ plan + room to promote roughly two blocks to raw
        let base = crate::ewq::decide(&a, &EwqConfig::default()).total_bytes(&s);
        let room = 2 * (s.block_raw_bytes() - 50_000);
        let cluster = Cluster::uniform(1, base + room, base + room);
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        assert!(d.fits);
        // any promoted-to-raw block must have entropy >= every still-quantized block
        let worst_raw = d
            .plan
            .assignments
            .iter()
            .zip(&hs)
            .filter(|(&p, _)| p == Precision::Raw)
            .map(|(_, &h)| h)
            .fold(f64::MAX, f64::min);
        let best_quant = d
            .plan
            .assignments
            .iter()
            .zip(&hs)
            .filter(|(&p, _)| p != Precision::Raw)
            .map(|(_, &h)| h)
            .fold(f64::MIN, f64::max);
        assert!(worst_raw >= best_quant, "raw floor {worst_raw} < quant ceil {best_quant}");
    }

    #[test]
    fn fastewq_promotes_low_exec_index_first() {
        let s = schema(6);
        let selected = vec![true; 6];
        // room for everything raw except ~2 blocks
        let raw_total = QuantPlan::uniform("t", 6, Precision::Raw).total_bytes(&s);
        let budget = raw_total - 2 * (s.block_raw_bytes() * 7 / 8);
        let cluster = Cluster::uniform(1, budget, budget);
        let d = fastewq_distribution("t", &selected, &s, &cluster);
        assert!(d.fits);
        // raw blocks must be a prefix (low exec_index promoted first)
        let first_quant =
            d.plan.assignments.iter().position(|&p| p != Precision::Raw).unwrap_or(6);
        assert!(
            d.plan.assignments[first_quant..].iter().all(|&p| p != Precision::Raw),
            "promotions not prefix-ordered: {:?}",
            d.plan.assignments
        );
    }

    #[test]
    fn fastewq_demotes_high_exec_index_first() {
        let s = schema(6);
        let selected = vec![true; 6];
        let q8_total = QuantPlan::uniform("t", 6, Precision::Q8).total_bytes(&s);
        let budget = q8_total - s.block_raw_bytes() / 8; // force some demotion
        let cluster = Cluster::uniform(1, budget, budget);
        let d = fastewq_distribution("t", &selected, &s, &cluster);
        assert!(d.fits);
        let demoted: Vec<usize> = d
            .plan
            .assignments
            .iter()
            .enumerate()
            .filter(|(_, &p)| p < Precision::Q8)
            .map(|(i, _)| i)
            .collect();
        assert!(!demoted.is_empty());
        // demotions concentrate at the tail
        assert!(demoted.iter().all(|&i| i >= 6 - demoted.len() - 1));
    }

    #[test]
    fn edge_plan_uses_only_q4_q3() {
        let a = analysis(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let s = schema(8);
        let p = edge_plan(&a, &s);
        assert!(p.assignments.iter().all(|&x| x == Precision::Q4 || x == Precision::Q3));
        let (_, _, q4, q3, _) = p.counts();
        assert!(q4 > 0 && q3 > 0);
        // §3.4 claim: 18-25% below uniform 4-bit
        let uni4 = QuantPlan::uniform("t", 8, Precision::Q4);
        let saving =
            1.0 - p.blocks_bytes(&s) as f64 / uni4.blocks_bytes(&s) as f64;
        assert!(saving > 0.05, "edge saving {saving}");
    }

    #[test]
    fn placement_respects_capacity_and_counts_hops() {
        let hs: Vec<f64> = (0..10).map(|i| 3.0 + 0.3 * i as f64).collect();
        let a = analysis(&hs);
        let s = schema(10);
        let per_machine = s.total_raw_bytes() / 3 + 200_000;
        let cluster = Cluster::uniform(4, per_machine, per_machine);
        let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
        assert!(d.fits);
        // per-machine load <= capacity
        let mut load = vec![0usize; 4];
        load[d.outer_machine] += s.total_raw_bytes() - s.blocks_raw_bytes();
        for (b, &m) in d.placement.iter().enumerate() {
            load[m] += block_bytes(&s, d.plan.assignments[b]);
        }
        for (m, l) in load.iter().enumerate() {
            assert!(*l <= cluster.machines[m].capacity(), "machine {m} overloaded");
        }
        assert!(d.hops >= 1, "multi-machine placement must hop");
        assert!(d.network_latency_us(&cluster) == d.hops as u64 * 200);
    }

    #[test]
    fn property_algorithm1_never_exceeds_r_when_feasible() {
        check(
            7,
            40,
            24,
            |g| {
                let n = g.usize_in(2, 16.max(3));
                let hs = g.vec_f64(n, 1.0, 10.0);
                let machines = g.usize_in(1, 5);
                // budget between T2 floor and raw total
                let frac = g.f64_in(0.28, 1.3);
                (hs, machines, frac)
            },
            |(hs, machines, frac)| {
                let a = analysis(hs);
                let s = schema(hs.len());
                let raw = s.total_raw_bytes();
                let budget = ((raw as f64 * frac) as usize / machines).max(1);
                let cluster = Cluster::uniform(*machines, budget, budget);
                let d = optimize_distribution(&a, &s, &cluster, &EwqConfig::default());
                let total = d.total_bytes(&s);
                let r = cluster.total_resources();
                if d.fits && total > r {
                    return Err(format!("claims fit but {total} > {r}"));
                }
                if !d.fits {
                    // only allowed when even all-T2 exceeds R
                    let floor =
                        QuantPlan::uniform("t", hs.len(), Precision::T2).total_bytes(&s);
                    if floor <= r {
                        return Err(format!("gave up although floor {floor} <= {r}"));
                    }
                }
                if d.placement.len() != hs.len() {
                    return Err("placement arity".into());
                }
                Ok(())
            },
        );
    }
}
