//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, retries the failing case with progressively simpler
//! inputs by re-generating at decreasing size hints — a lightweight stand-in
//! for shrinking. Every coordinator invariant test (cluster, batching, plan
//! state) goes through this.

use crate::rng::Xoshiro256pp;

/// Size hint passed to generators; starts at `max_size` and shrinks on failure.
pub struct Gen<'a> {
    pub rng: &'a mut Xoshiro256pp,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo).max(1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run a property over `cases` random inputs. Panics with the seed and case
/// index on failure so the case is replayable.
pub fn check<T, G, P>(seed: u64, cases: usize, max_size: usize, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Xoshiro256pp::new(seed);
    for case in 0..cases {
        let size = 1 + (max_size * (case + 1)) / cases; // grow sizes over the run
        let input = generate(&mut Gen { rng: &mut rng, size });
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}, size={size}): {msg}\ninput: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            1,
            50,
            100,
            |g| g.usize_in(0, g.size),
            |&x| if x < 101 { Ok(()) } else { Err(format!("{x} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_loudly() {
        check(2, 50, 10, |g| g.usize_in(0, 10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err("x >= 5".into())
            }
        });
    }

    #[test]
    fn generator_helpers_in_range() {
        check(
            3,
            100,
            64,
            |g| {
                let n = g.usize_in(1, 8);
                let v = g.vec_f64(n, -1.0, 1.0);
                (v, g.f64_in(2.0, 3.0), g.bool())
            },
            |(v, f, _b)| {
                if v.iter().all(|x| (-1.0..1.0).contains(x)) && (2.0..3.0).contains(f) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }
}
