//! Entropy analysis — the paper's Section 3 core.
//!
//! `softmax_entropy` implements H = -Σ p_i·log(p_i + ε) with p = softmax of
//! the flattened weights, numerically stable via max-shift and streamed in
//! chunks (the L3 mirror of the L1 Pallas kernel; the two are cross-checked
//! through the AOT `entropy.hlo` module in the runtime integration tests).
//!
//! Every reduction here is **chunked and deterministic**: the input is split
//! into fixed `CHUNK`-sized pieces (a function of length only), per-chunk
//! partials are computed — in parallel when a multi-worker `par::Pool` is
//! passed — and folded in chunk order. The result is bit-identical for any
//! worker count; the plain (non-`_pooled`) entry points are the same code on
//! a serial pool.
//!
//! `block_entropy` is the size-weighted mean over a block's matrices
//! (paper eq. 3.2); `EntropyStats` carries μ_H, σ_H and the threshold
//! T = μ_H − X·σ_H (eq. 3.3.3).

use crate::par::Pool;

/// Paper's stability constant ε. Defaults tiny: for n ≥ 1e4 parameters the
/// illustrative 0.01 saturates log(p+ε) ≈ log ε and washes out inter-block
/// differences (see DESIGN.md). Configurable on every entry point.
pub const EPS_DEFAULT: f64 = 1e-12;

/// Fixed reduction chunk: large enough to amortize task dispatch, small
/// enough that multi-megabyte tensors split across every worker.
const CHUNK: usize = 1 << 15;

fn max_shift(w: &[f32], pool: &Pool) -> f64 {
    let m = pool.par_chunk_fold(
        w,
        CHUNK,
        |c| {
            let mut m = f32::NEG_INFINITY;
            for &x in c {
                if x > m {
                    m = x;
                }
            }
            m
        },
        f32::NEG_INFINITY,
        |a, b| if b > a { b } else { a },
    );
    m as f64
}

/// Streaming softmax entropy of a weight slice. Two passes after the global
/// max: partition function, then the fused -Σ p log(p+ε) accumulation, each
/// a chunked parallel reduction in f64.
pub fn softmax_entropy(w: &[f32], eps: f64) -> f64 {
    softmax_entropy_pooled(w, eps, &Pool::serial())
}

/// `softmax_entropy` with an explicit worker pool (bit-identical to the
/// serial path for any worker count).
pub fn softmax_entropy_pooled(w: &[f32], eps: f64, pool: &Pool) -> f64 {
    assert!(!w.is_empty(), "entropy of empty tensor");
    let m = max_shift(w, pool);
    // pass 2a: partition function
    let z = pool.par_chunk_fold(
        w,
        CHUNK,
        |c| c.iter().map(|&x| (x as f64 - m).exp()).sum::<f64>(),
        0.0f64,
        |a, b| a + b,
    );
    // pass 2b: -Σ p log(p+ε)
    pool.par_chunk_fold(
        w,
        CHUNK,
        |c| {
            let mut h = 0.0f64;
            for &x in c {
                let p = (x as f64 - m).exp() / z;
                h -= p * (p + eps).ln();
            }
            h
        },
        0.0f64,
        |a, b| a + b,
    )
}

/// Single-matrix entropy with the default ε.
pub fn entropy(w: &[f32]) -> f64 {
    softmax_entropy(w, EPS_DEFAULT)
}

/// Fused fast path (§Perf): for ε → 0 the entropy has the closed form
///   H = ln Z − Σ e^{x−m}·(x−m) / Z,
/// computable in ONE exp per element (two data passes instead of three,
/// no ln per element). The deviation from the exact ε-formula is
/// Σ p·[ln(p+ε) − ln p] ≤ n·ε — for ε = 1e-12 and n ≤ 1e7 that is < 1e-5,
/// orders of magnitude below any block-selection threshold gap.
pub fn softmax_entropy_fast(w: &[f32]) -> f64 {
    entropy_fused_pooled(w, &Pool::serial())
}

/// `softmax_entropy_fast` under its pipeline name (the fused estimator the
/// analyzers dispatch to).
pub fn entropy_fused(w: &[f32]) -> f64 {
    entropy_fused_pooled(w, &Pool::serial())
}

/// Fused closed-form entropy with an explicit worker pool: per-chunk
/// (Σe^{x−m}, Σe^{x−m}·(x−m)) partials in f64, folded in chunk order —
/// bit-identical for any worker count.
///
/// Deliberate change from the earlier fast path: exp is computed in f64,
/// not f32. The f32 exp bought ~1.6x per element but capped fused-vs-exact
/// agreement at ~1e-6; f64 keeps the fused estimator within 1e-9 of the
/// exact ε→0 formula (property-tested below), which is what lets the
/// analyzers treat the two as interchangeable. The chunked parallel fold is
/// the intended way to recover (and exceed) the lost per-element speed.
pub fn entropy_fused_pooled(w: &[f32], pool: &Pool) -> f64 {
    assert!(!w.is_empty(), "entropy of empty tensor");
    let m = max_shift(w, pool);
    let (z, zx) = pool.par_chunk_fold(
        w,
        CHUNK,
        |c| {
            let mut z = 0.0f64;
            let mut zx = 0.0f64;
            for &x in c {
                let d = x as f64 - m;
                let e = d.exp();
                z += e;
                zx += e * d;
            }
            (z, zx)
        },
        (0.0f64, 0.0f64),
        |(za, xa), (zb, xb)| (za + zb, xa + xb),
    );
    z.ln() - zx / z
}

/// Entropy dispatch used by the EWQ analyzers: the fused fast path when ε is
/// effectively zero, the exact three-pass formula otherwise.
pub fn entropy_for_selection(w: &[f32], eps: f64) -> f64 {
    entropy_for_selection_pooled(w, eps, &Pool::serial())
}

/// `entropy_for_selection` with an explicit worker pool.
pub fn entropy_for_selection_pooled(w: &[f32], eps: f64, pool: &Pool) -> f64 {
    if eps <= 1e-9 {
        entropy_fused_pooled(w, pool)
    } else {
        softmax_entropy_pooled(w, eps, pool)
    }
}

/// Size-weighted block entropy (paper eq. 3.2):
/// H_block = Σ_i |W_i|·H(W_i) / Σ_i |W_i|.
pub fn block_entropy<'a, I>(mats: I, eps: f64) -> f64
where
    I: IntoIterator<Item = &'a [f32]>,
{
    block_entropy_pooled(mats, eps, &Pool::serial())
}

/// `block_entropy` with an explicit worker pool (parallelism inside each
/// matrix reduction; the per-matrix weighting itself is a fixed-order fold).
pub fn block_entropy_pooled<'a, I>(mats: I, eps: f64, pool: &Pool) -> f64
where
    I: IntoIterator<Item = &'a [f32]>,
{
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for w in mats {
        let n = w.len() as f64;
        num += n * entropy_for_selection_pooled(w, eps, pool);
        den += n;
    }
    assert!(den > 0.0, "block with no parameters");
    num / den
}

/// Distribution statistics over per-block entropies (paper §3.3.2–3.3.3).
#[derive(Clone, Debug, PartialEq)]
pub struct EntropyStats {
    pub mean: f64,
    pub std: f64,
}

impl EntropyStats {
    pub fn from_values(hs: &[f64]) -> Self {
        assert!(!hs.is_empty());
        let n = hs.len() as f64;
        let mean = hs.iter().sum::<f64>() / n;
        let var = hs.iter().map(|h| (h - mean) * (h - mean)).sum::<f64>() / n;
        Self { mean, std: var.sqrt() }
    }

    /// T = μ_H − X·σ_H (X ≥ 0; paper default X = 1).
    pub fn threshold(&self, x: f64) -> f64 {
        assert!(x >= 0.0, "X must be non-negative");
        self.mean - x * self.std
    }
}

/// Rank of each block when sorted ascending by entropy (paper §3.3.1).
/// Returns indices into `hs` ordered lowest-entropy-first; ties broken by
/// block index for determinism.
pub fn ascending_order(hs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..hs.len()).collect();
    idx.sort_by(|&a, &b| hs[a].partial_cmp(&hs[b]).unwrap().then(a.cmp(&b)));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::rng::Xoshiro256pp;

    fn numpy_like_entropy(w: &[f32], eps: f64) -> f64 {
        // naive reference in f64
        let m = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let exps: Vec<f64> = w.iter().map(|&x| ((x as f64) - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.iter().map(|e| -(e / z) * ((e / z) + eps).ln()).sum()
    }

    #[test]
    fn uniform_is_log_n() {
        let w = vec![0.5f32; 4096];
        let h = entropy(&w);
        assert!((h - (4096f64).ln()).abs() < 1e-6, "h={h}");
    }

    #[test]
    fn one_hot_is_zero() {
        let mut w = vec![0.0f32; 2048];
        w[3] = 200.0;
        assert!(entropy(&w) < 1e-3);
    }

    #[test]
    fn shift_invariant() {
        let mut r = Xoshiro256pp::new(1);
        let w: Vec<f32> = (0..1000).map(|_| r.normal_f32(0.0, 0.7)).collect();
        let w2: Vec<f32> = w.iter().map(|x| x + 5.0).collect();
        assert!((entropy(&w) - entropy(&w2)).abs() < 1e-8);
    }

    #[test]
    fn matches_naive_reference() {
        let mut r = Xoshiro256pp::new(2);
        for n in [2usize, 17, 1000, 5000] {
            let w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.3, 1.2)).collect();
            let h = softmax_entropy(&w, 1e-12);
            let href = numpy_like_entropy(&w, 1e-12);
            assert!((h - href).abs() < 1e-9 * (1.0 + href.abs()), "{h} vs {href}");
        }
    }

    #[test]
    fn entropy_bounded_by_log_n() {
        let mut r = Xoshiro256pp::new(3);
        for _ in 0..20 {
            let n = 64 + r.below(4000);
            let w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 2.0)).collect();
            let h = entropy(&w);
            assert!(h >= 0.0 && h <= (n as f64).ln() + 1e-9);
        }
    }

    #[test]
    fn larger_spread_means_lower_entropy() {
        // wider weight distribution => more peaked softmax => lower entropy
        let mut r = Xoshiro256pp::new(4);
        let tight: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 0.05)).collect();
        let wide: Vec<f32> = (0..4096).map(|_| r.normal_f32(0.0, 3.0)).collect();
        assert!(entropy(&tight) > entropy(&wide));
    }

    #[test]
    fn eps_lowers_entropy() {
        let mut r = Xoshiro256pp::new(5);
        let w: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 0.5)).collect();
        assert!(softmax_entropy(&w, 1e-2) < softmax_entropy(&w, 1e-12));
    }

    #[test]
    fn block_entropy_is_weighted_mean() {
        let mut r = Xoshiro256pp::new(6);
        let a: Vec<f32> = (0..1024).map(|_| r.normal_f32(0.0, 0.1)).collect();
        let b: Vec<f32> = (0..3072).map(|_| r.normal_f32(0.0, 1.5)).collect();
        let ha = entropy_for_selection(&a, EPS_DEFAULT);
        let hb = entropy_for_selection(&b, EPS_DEFAULT);
        let h = block_entropy([a.as_slice(), b.as_slice()], EPS_DEFAULT);
        let expect = (1024.0 * ha + 3072.0 * hb) / 4096.0;
        assert!((h - expect).abs() < 1e-9);
        assert!(h >= ha.min(hb) && h <= ha.max(hb));
    }

    #[test]
    fn fast_path_matches_exact_formula() {
        // §Perf: the fused closed form deviates from the exact ε-formula by
        // at most ~n·ε — far below any selection threshold gap.
        let mut r = Xoshiro256pp::new(21);
        for n in [64usize, 4096, 100_000] {
            let w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.1, 0.8)).collect();
            let exact = softmax_entropy(&w, 1e-12);
            let fast = softmax_entropy_fast(&w);
            assert!(
                (exact - fast).abs() < 1e-6 * (1.0 + exact.abs()),
                "n={n}: exact {exact} vs fast {fast}"
            );
        }
    }

    #[test]
    fn selection_dispatch_picks_paths() {
        let mut r = Xoshiro256pp::new(22);
        let w: Vec<f32> = (0..2048).map(|_| r.normal_f32(0.0, 0.5)).collect();
        // tiny eps -> fast path; must still match exact closely
        let a = entropy_for_selection(&w, 1e-12);
        assert!((a - softmax_entropy(&w, 1e-12)).abs() < 1e-6);
        // large eps -> exact path verbatim
        let b = entropy_for_selection(&w, 1e-2);
        assert_eq!(b, softmax_entropy(&w, 1e-2));
    }

    #[test]
    fn property_fused_matches_exact_within_1e9() {
        // satellite: entropy_fused ≡ softmax_entropy(·, ε→0) within 1e-9 on
        // random tensors (n kept ≤ 2048 so the n·ε analytic gap stays below
        // the tolerance).
        check(
            1234,
            40,
            2048,
            |g| {
                // σ ≤ 1 keeps H well above 2 nats at these sizes, so the
                // analytic n·ε fused-vs-exact gap stays far below tolerance
                let n = g.usize_in(2, g.size.max(3));
                let std = g.f64_in(0.05, 1.0);
                (0..n).map(|_| (g.rng.normal() * std) as f32).collect::<Vec<f32>>()
            },
            |w| {
                let exact = softmax_entropy(w, 1e-12);
                let fused = entropy_fused(w);
                let tol = 1e-9 * (1.0 + exact.abs());
                if (exact - fused).abs() <= tol {
                    Ok(())
                } else {
                    Err(format!("n={}: exact {exact} vs fused {fused}", w.len()))
                }
            },
        );
    }

    #[test]
    fn property_parallel_reduction_is_bit_stable() {
        // satellite: the chunked parallel reduction is deterministic w.r.t.
        // worker count — identical BITS, not just close values.
        check(
            777,
            12,
            150_000,
            |g| {
                let n = g.usize_in(1, g.size.max(2));
                (0..n).map(|_| (g.rng.normal() * 0.7) as f32).collect::<Vec<f32>>()
            },
            |w| {
                let serial_exact = softmax_entropy_pooled(w, 1e-12, &Pool::serial());
                let serial_fused = entropy_fused_pooled(w, &Pool::serial());
                for workers in [2usize, 5] {
                    let pool = Pool::new(workers);
                    let pe = softmax_entropy_pooled(w, 1e-12, &pool);
                    let pf = entropy_fused_pooled(w, &pool);
                    if pe.to_bits() != serial_exact.to_bits() {
                        return Err(format!("exact path drifted at workers={workers}"));
                    }
                    if pf.to_bits() != serial_fused.to_bits() {
                        return Err(format!("fused path drifted at workers={workers}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pooled_block_entropy_matches_serial() {
        let mut r = Xoshiro256pp::new(33);
        let a: Vec<f32> = (0..40_000).map(|_| r.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> = (0..70_000).map(|_| r.normal_f32(0.0, 1.1)).collect();
        let serial = block_entropy([a.as_slice(), b.as_slice()], EPS_DEFAULT);
        let pooled =
            block_entropy_pooled([a.as_slice(), b.as_slice()], EPS_DEFAULT, &Pool::new(4));
        assert_eq!(serial.to_bits(), pooled.to_bits());
    }

    #[test]
    fn stats_and_threshold() {
        let hs = [4.0, 6.0, 8.0];
        let s = EntropyStats::from_values(&hs);
        assert!((s.mean - 6.0).abs() < 1e-12);
        assert!((s.std - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((s.threshold(1.0) - (6.0 - s.std)).abs() < 1e-12);
        assert_eq!(s.threshold(0.0), s.mean);
    }

    #[test]
    fn ascending_order_sorts() {
        let hs = [5.0, 1.0, 3.0, 1.0];
        assert_eq!(ascending_order(&hs), vec![1, 3, 2, 0]);
    }
}
