//! Statistical toolkit: paired t-test (exact Student-t CDF via the
//! regularized incomplete beta function), Cohen's d, Pearson correlation,
//! and the paper's composite score (§6.3.1).

/// ln Γ(x) — Lanczos approximation (g=7, n=9), |err| < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized incomplete beta I_x(a, b) via continued fractions (Lentz).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x), "x out of range: {x}");
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    // symmetry for faster convergence
    if x > (a + 1.0) / (a + b + 2.0) {
        return 1.0 - betainc(b, a, 1.0 - x);
    }
    // continued fraction
    let tiny = 1e-300;
    let mut c = 1.0f64;
    let mut d = 1.0 - (a + b) * x / (a + 1.0);
    if d.abs() < tiny {
        d = tiny;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..200 {
        let m = m as f64;
        // even step
        let num = m * (b - m) * x / ((a + 2.0 * m - 1.0) * (a + 2.0 * m));
        d = 1.0 + num * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = 1.0 + num / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let num = -(a + m) * (a + b + m) * x / ((a + 2.0 * m) * (a + 2.0 * m + 1.0));
        d = 1.0 + num * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = 1.0 + num / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = d * c;
        h *= delta;
        if (delta - 1.0).abs() < 1e-14 {
            break;
        }
    }
    (ln_front.exp() * h / a).clamp(0.0, 1.0)
}

/// CDF of Student's t with `df` degrees of freedom.
pub fn student_t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    let p = 0.5 * betainc(df / 2.0, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Result of a paired t-test.
#[derive(Clone, Copy, Debug)]
pub struct TTest {
    pub t: f64,
    pub df: f64,
    /// two-sided p-value
    pub p: f64,
}

impl TTest {
    pub fn significance(&self) -> &'static str {
        // paper Table 11
        if self.p < 0.05 {
            "significant"
        } else if self.p < 0.10 {
            "marginally significant"
        } else {
            "not significant"
        }
    }
}

/// Paired t-test over two equally-sized samples (paper §6.3.1).
pub fn paired_t_test(a: &[f64], b: &[f64]) -> TTest {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    assert!(n >= 2, "need >= 2 pairs");
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let mean = diffs.iter().sum::<f64>() / n as f64;
    let var = diffs.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / (n as f64 - 1.0);
    let sd = var.sqrt();
    let df = (n - 1) as f64;
    if sd == 0.0 {
        // identical samples: t = 0 by convention, p = 1
        return TTest { t: 0.0, df, p: 1.0 };
    }
    let t = mean / (sd / (n as f64).sqrt());
    let p = 2.0 * (1.0 - student_t_cdf(t.abs(), df));
    TTest { t, df, p }
}

/// Cohen's d with pooled standard deviation (paper §6.3.1, Table 12).
pub fn cohens_d(a: &[f64], b: &[f64]) -> f64 {
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let ma = a.iter().sum::<f64>() / na;
    let mb = b.iter().sum::<f64>() / nb;
    let va = a.iter().map(|x| (x - ma) * (x - ma)).sum::<f64>() / (na - 1.0);
    let vb = b.iter().map(|x| (x - mb) * (x - mb)).sum::<f64>() / (nb - 1.0);
    let sp = (((na - 1.0) * va + (nb - 1.0) * vb) / (na + nb - 2.0)).sqrt();
    if sp == 0.0 {
        return 0.0;
    }
    (ma - mb) / sp
}

/// Effect-size interpretation (paper Table 12).
pub fn effect_size_label(d: f64) -> &'static str {
    let d = d.abs();
    if d < 0.2 {
        "negligible"
    } else if d < 0.5 {
        "small"
    } else if d < 0.8 {
        "medium"
    } else {
        "large"
    }
}

/// Composite score (paper §6.3.1): w1·ln(perplexity) − w2·accuracy.
pub fn composite_score(perplexity: f64, accuracy: f64, w1: f64, w2: f64) -> f64 {
    w1 * perplexity.ln() - w2 * accuracy
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_pop(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(5) = 24
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        // Γ(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
        // Γ(1) = 1
        assert!(ln_gamma(1.0).abs() < 1e-12);
    }

    #[test]
    fn betainc_boundaries_and_symmetry() {
        assert_eq!(betainc(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betainc(2.0, 3.0, 1.0), 1.0);
        // I_x(1,1) = x
        for x in [0.1, 0.35, 0.8] {
            assert!((betainc(1.0, 1.0, x) - x).abs() < 1e-10);
        }
        // symmetry: I_x(a,b) = 1 - I_{1-x}(b,a)
        let v = betainc(2.5, 4.0, 0.3);
        assert!((v - (1.0 - betainc(4.0, 2.5, 0.7))).abs() < 1e-10);
    }

    #[test]
    fn t_cdf_scipy_values() {
        // scipy.stats.t.cdf(1.0, 10) = 0.82955343...
        assert!((student_t_cdf(1.0, 10.0) - 0.8295534338489701).abs() < 1e-9);
        // t.cdf(0, df) = 0.5
        assert!((student_t_cdf(0.0, 5.0) - 0.5).abs() < 1e-12);
        // t.cdf(-2.0, 3) = 0.069662...
        assert!((student_t_cdf(-2.0, 3.0) - 0.06966298427942702).abs() < 1e-9);
    }

    #[test]
    fn paired_t_identical_is_not_significant() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let t = paired_t_test(&a, &a);
        assert_eq!(t.t, 0.0);
        assert_eq!(t.p, 1.0);
        assert_eq!(t.significance(), "not significant");
    }

    #[test]
    fn paired_t_matches_scipy() {
        // scipy.stats.ttest_rel([1,2,3,4,5],[2,2,4,4,6]) -> t=-2.4494897, p=0.0705173
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 2.0, 4.0, 4.0, 6.0];
        let t = paired_t_test(&a, &b);
        assert!((t.t - (-2.449489742783178)).abs() < 1e-9, "t={}", t.t);
        assert!((t.p - 0.0705).abs() < 5e-4, "p={}", t.p);
        assert_eq!(t.significance(), "marginally significant");
    }

    #[test]
    fn cohens_d_known() {
        // two groups shifted by exactly one pooled sd
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 3.0, 4.0];
        let d = cohens_d(&a, &b);
        assert!((d - (-1.0)).abs() < 1e-12, "d={d}");
        assert_eq!(effect_size_label(d), "large");
        assert_eq!(effect_size_label(0.1), "negligible");
        assert_eq!(effect_size_label(0.3), "small");
        assert_eq!(effect_size_label(0.6), "medium");
    }

    #[test]
    fn composite_score_formula() {
        let s = composite_score(std::f64::consts::E, 0.5, 1.0, 1.0);
        assert!((s - 0.5).abs() < 1e-12); // ln(e) - 0.5
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0, 2.0, 3.0];
        assert!((pearson(&a, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&a, &[5.0, 5.0, 5.0]), 0.0);
    }
}
