//! ETS — the EWQ Tensor Store binary format (reader/writer).
//!
//! Mirror of `python/compile/ets.py`; keep the two in lockstep.
//!
//! Layout (little-endian):
//! ```text
//! magic  b"ETS1"
//! u32    n_tensors
//! per tensor:
//!     u16  name_len, name utf-8 bytes
//!     u8   dtype     (0=f32, 1=i8, 2=u8, 3=i32)
//!     u8   ndim
//!     u32  dims[ndim]
//!     u64  data_len (bytes)
//!     data
//!     u32  crc32(data)
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"ETS1";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32 = 0,
    I8 = 1,
    U8 = 2,
    I32 = 3,
}

impl Dtype {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Dtype::F32,
            1 => Dtype::I8,
            2 => Dtype::U8,
            3 => Dtype::I32,
            _ => bail!("unknown dtype tag {v}"),
        })
    }

    pub fn elem_size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I8 | Dtype::U8 => 1,
        }
    }
}

/// A raw tensor as stored: dtype tag + dims + little-endian bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct EtsTensor {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
    pub data: Vec<u8>,
}

impl EtsTensor {
    pub fn from_f32(dims: Vec<usize>, vals: &[f32]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { dtype: Dtype::F32, dims, data }
    }

    pub fn from_i8(dims: Vec<usize>, vals: &[i8]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        Self { dtype: Dtype::I8, dims, data: vals.iter().map(|&v| v as u8).collect() }
    }

    pub fn from_u8(dims: Vec<usize>, vals: Vec<u8>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), vals.len());
        Self { dtype: Dtype::U8, dims, data: vals }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is {:?}, not F32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_i8(&self) -> Result<Vec<i8>> {
        if self.dtype != Dtype::I8 {
            bail!("tensor is {:?}, not I8", self.dtype);
        }
        Ok(self.data.iter().map(|&b| b as i8).collect())
    }
}

/// CRC-32 (IEEE, zlib-compatible) — table-driven; matches python `zlib.crc32`.
pub fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

pub fn write_ets<P: AsRef<Path>>(path: P, tensors: &BTreeMap<String, EtsTensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype as u8, t.dims.len() as u8])?;
        for &d in &t.dims {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        f.write_all(&t.data)?;
        f.write_all(&crc32(&t.data).to_le_bytes())?;
    }
    Ok(())
}

pub fn read_ets<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, EtsTensor>> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );

    fn take<const N: usize>(f: &mut impl Read) -> Result<[u8; N]> {
        let mut buf = [0u8; N];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    let magic = take::<4>(&mut f)?;
    if &magic != MAGIC {
        bail!("{}: bad magic {:?}", path.display(), magic);
    }
    let n = u32::from_le_bytes(take::<4>(&mut f)?) as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = u16::from_le_bytes(take::<2>(&mut f)?) as usize;
        let mut nb = vec![0u8; name_len];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name not utf-8")?;
        let [dt, nd] = take::<2>(&mut f)?;
        let dtype = Dtype::from_u8(dt)?;
        let mut dims = Vec::with_capacity(nd as usize);
        for _ in 0..nd {
            dims.push(u32::from_le_bytes(take::<4>(&mut f)?) as usize);
        }
        let dl = u64::from_le_bytes(take::<8>(&mut f)?) as usize;
        let expect = dims.iter().product::<usize>() * dtype.elem_size();
        if dl != expect {
            bail!("{name}: data_len {dl} != dims*esize {expect}");
        }
        let mut data = vec![0u8; dl];
        f.read_exact(&mut data)?;
        let crc = u32::from_le_bytes(take::<4>(&mut f)?);
        if crc != crc32(&data) {
            bail!("{name}: crc mismatch (stored {crc:#x})");
        }
        out.insert(name, EtsTensor { dtype, dims, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_zlib_vectors() {
        // zlib.crc32(b"123456789") == 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
        // zlib.crc32(b"hello world") == 0x0D4A1185
        assert_eq!(crc32(b"hello world"), 0x0D4A1185);
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let dir = std::env::temp_dir().join("ewq_ets_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ets");
        let mut m = BTreeMap::new();
        m.insert("a".into(), EtsTensor::from_f32(vec![2, 3], &[1., 2., 3., 4., 5., 6.]));
        m.insert("b".into(), EtsTensor::from_i8(vec![4], &[-4, -1, 0, 7]));
        m.insert("c".into(), EtsTensor::from_u8(vec![2, 2], vec![0, 128, 255, 7]));
        write_ets(&p, &m).unwrap();
        let back = read_ets(&p).unwrap();
        assert_eq!(back, m);
        assert_eq!(back["a"].to_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back["b"].to_i8().unwrap(), vec![-4, -1, 0, 7]);
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("ewq_ets_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ets");
        let mut m = BTreeMap::new();
        m.insert("w".into(), EtsTensor::from_f32(vec![4], &[1., 2., 3., 4.]));
        write_ets(&p, &m).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        let n = raw.len();
        raw[n - 8] ^= 0xFF; // flip a data byte (data precedes 4-byte crc)
        std::fs::write(&p, raw).unwrap();
        assert!(read_ets(&p).is_err());
    }

    #[test]
    fn dtype_roundtrip_tags() {
        for d in [Dtype::F32, Dtype::I8, Dtype::U8, Dtype::I32] {
            assert_eq!(Dtype::from_u8(d as u8).unwrap(), d);
        }
        assert!(Dtype::from_u8(9).is_err());
    }

    #[test]
    fn wrong_dtype_access_fails() {
        let t = EtsTensor::from_f32(vec![1], &[1.0]);
        assert!(t.to_i8().is_err());
    }
}
