//! Dense tensors + the ETS on-disk tensor store.
//!
//! `Tensor` is deliberately minimal: the heavy math runs inside AOT-compiled
//! XLA executables; the Rust side only needs shape-aware containers for
//! weights, quantized payloads and activations.

pub mod store;

pub use store::{read_ets, write_ets, Dtype, EtsTensor};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of rows/cols for a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D tensor, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    /// Column-wise max(|w|) for a 2-D tensor — the quantization scale base.
    pub fn col_abs_max(&self) -> Vec<f32> {
        let (r, c) = self.dims2();
        let mut m = vec![0.0f32; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for (mj, &x) in m.iter_mut().zip(row) {
                let a = x.abs();
                if a > *mj {
                    *mj = a;
                }
            }
        }
        m
    }

    /// Column-wise mean(|w|) — the ternary scale base.
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let (r, c) = self.dims2();
        let mut m = vec![0.0f64; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for (mj, &x) in m.iter_mut().zip(row) {
                *mj += x.abs() as f64;
            }
        }
        m.into_iter().map(|s| (s / r as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.at2(1, 2), 6.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn col_abs_max_and_mean() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -4.0, -3.0, 2.0]);
        assert_eq!(t.col_abs_max(), vec![3.0, 4.0]);
        assert_eq!(t.col_abs_mean(), vec![2.0, 3.0]);
    }

    #[test]
    fn zeros() {
        let t = Tensor::zeros(vec![3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }
}
