//! Token sampling: greedy / temperature / top-k over a logit slice.
//! Used by the serving path and the consistency metric (§Table 1).

use crate::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SamplingMode {
    Greedy,
    /// softmax sampling at a temperature
    Temperature(f32),
    /// top-k restricted temperature sampling
    TopK { k: usize, temperature: f32 },
}

/// Sample one token id from `logits`.
pub fn sample(logits: &[f32], mode: SamplingMode, rng: &mut Xoshiro256pp) -> usize {
    match mode {
        SamplingMode::Greedy => argmax(logits),
        SamplingMode::Temperature(t) => {
            let idx: Vec<usize> = (0..logits.len()).collect();
            categorical(logits, &idx, t, rng)
        }
        SamplingMode::TopK { k, temperature } => {
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(k.max(1));
            categorical(logits, &idx, temperature, rng)
        }
    }
}

/// NaN-safe greedy argmax (`total_cmp`): the serving and decode hot paths
/// call this on model output, where a NaN logit must select deterministically
/// rather than panic the shard thread.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

fn categorical(logits: &[f32], idx: &[usize], temperature: f32, rng: &mut Xoshiro256pp) -> usize {
    let t = temperature.max(1e-4) as f64;
    let m = idx.iter().map(|&i| logits[i] as f64).fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = idx.iter().map(|&i| ((logits[i] as f64 - m) / t).exp()).collect();
    let z: f64 = exps.iter().sum();
    let mut u = rng.next_f64() * z;
    for (j, e) in exps.iter().enumerate() {
        if u < *e {
            return idx[j];
        }
        u -= e;
    }
    idx[idx.len() - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let l = [0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(sample(&l, SamplingMode::Greedy, &mut Xoshiro256pp::new(1)), 1);
    }

    #[test]
    fn low_temperature_converges_to_greedy() {
        let l = [0.0f32, 2.0, 1.0];
        let mut rng = Xoshiro256pp::new(2);
        for _ in 0..50 {
            assert_eq!(sample(&l, SamplingMode::Temperature(0.01), &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let l = [0.0f32, 2.0, 1.0];
        let mut rng = Xoshiro256pp::new(3);
        let mut seen = [0usize; 3];
        for _ in 0..600 {
            seen[sample(&l, SamplingMode::Temperature(10.0), &mut rng)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 100), "counts {seen:?}");
    }

    #[test]
    fn topk_never_leaves_the_top_set() {
        let l = [5.0f32, 4.0, -10.0, -20.0];
        let mut rng = Xoshiro256pp::new(4);
        for _ in 0..200 {
            let s = sample(&l, SamplingMode::TopK { k: 2, temperature: 1.0 }, &mut rng);
            assert!(s == 0 || s == 1);
        }
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let l: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut a = Xoshiro256pp::new(9);
        let mut b = Xoshiro256pp::new(9);
        for _ in 0..20 {
            assert_eq!(
                sample(&l, SamplingMode::TopK { k: 8, temperature: 0.7 }, &mut a),
                sample(&l, SamplingMode::TopK { k: 8, temperature: 0.7 }, &mut b)
            );
        }
    }
}
