//! Model assembly: quantize a flagship model under a `QuantPlan`, hold the
//! packed weights in memory, and run full-sequence forward passes through
//! the per-precision AOT block executables.
//!
//! One compiled executable per (arch, precision-variant) serves every block
//! and every plan — weights are runtime arguments, so switching plans never
//! recompiles. Q3 (edge mode) has no dedicated artifact: its blocks are
//! dequantized to f32 at load time and dispatched through `block_raw`
//! (quantization *noise* is preserved; only the storage path differs —
//! documented in DESIGN.md).

pub mod sampler;

use anyhow::{bail, Result};

use crate::ewq::QuantPlan;
use crate::quant::{dequantize, quantize, Payload, Precision, QMat};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use crate::tensor::Tensor;
use crate::zoo::{ModelDir, Schema};

/// One block's runtime payload: norm gains + the six matrices, pre-encoded
/// as XLA literals in the artifact's argument order.
pub struct QuantBlock {
    pub prec: Precision,
    /// literals after the leading activation argument
    args: Vec<xla::Literal>,
    /// stored bytes under the plan (for memory accounting)
    pub bytes: usize,
}

/// A fully quantized, runtime-ready model instance.
pub struct QuantizedModel {
    pub schema: Schema,
    pub plan: QuantPlan,
    pub blocks: Vec<QuantBlock>,
    embed_args: Vec<xla::Literal>, // embed, pos
    head_args: Vec<xla::Literal>,  // gf, head
}

fn qmat_literals(m: &QMat) -> Result<Vec<xla::Literal>> {
    let (k, n) = (m.rows, m.cols);
    Ok(match &m.payload {
        Payload::Raw(d) => vec![lit_f32(&[k, n], d)?],
        Payload::Q8 { q, s } => vec![crate::runtime::lit_i8(&[k, n], q)?, lit_f32(&[n], s)?],
        Payload::Q4 { p, s } => vec![crate::runtime::lit_u8(&[k / 2, n], p)?, lit_f32(&[n], s)?],
        Payload::T2 { p, s } => vec![crate::runtime::lit_u8(&[k / 4, n], p)?, lit_f32(&[n], s)?],
        Payload::Q3 { .. } => bail!("Q3 must be dequantized before literal encoding"),
    })
}

impl QuantizedModel {
    /// Quantize `model` under `plan` and pre-encode every literal.
    pub fn build(model: &ModelDir, plan: &QuantPlan) -> Result<Self> {
        let schema = model.schema.clone();
        assert_eq!(plan.assignments.len(), schema.n_blocks);
        let mut blocks = Vec::with_capacity(schema.n_blocks);
        for (b, &prec) in plan.assignments.iter().enumerate() {
            let w = &model.weights.blocks[b];
            let d = schema.d_model;
            let mut bytes = 4 * 2 * d;
            let mut args: Vec<xla::Literal> = Vec::with_capacity(14);

            let qmats: Vec<QMat> = w.mats.iter().map(|t| quantize(t, prec)).collect();
            bytes += qmats.iter().map(|m| m.size_bytes()).sum::<usize>();

            match prec {
                Precision::Raw | Precision::Q3 => {
                    // block_raw argument order: g1, wq, wk, wv, wo, g2, w1, w2
                    args.push(lit_f32(&[d], &w.g1.data)?);
                    let mats: Vec<Tensor> = if prec == Precision::Q3 {
                        qmats.iter().map(dequantize).collect()
                    } else {
                        w.mats.to_vec()
                    };
                    for t in &mats[..4] {
                        args.push(lit_f32(&t.shape, &t.data)?);
                    }
                    args.push(lit_f32(&[d], &w.g2.data)?);
                    for t in &mats[4..] {
                        args.push(lit_f32(&t.shape, &t.data)?);
                    }
                }
                Precision::Q8 | Precision::Q4 | Precision::T2 => {
                    // block_q* argument order: g1, g2, then (q, s) x 6
                    args.push(lit_f32(&[d], &w.g1.data)?);
                    args.push(lit_f32(&[d], &w.g2.data)?);
                    for m in &qmats {
                        args.extend(qmat_literals(m)?);
                    }
                }
            }
            blocks.push(QuantBlock { prec, args, bytes });
        }

        let w = &model.weights;
        Ok(Self {
            embed_args: vec![
                lit_f32(&w.embed.shape, &w.embed.data)?,
                lit_f32(&w.pos.shape, &w.pos.data)?,
            ],
            head_args: vec![
                lit_f32(&w.gf.shape, &w.gf.data)?,
                lit_f32(&w.head.shape, &w.head.data)?,
            ],
            schema,
            plan: plan.clone(),
            blocks,
        })
    }

    /// Stored bytes of all blocks under this plan.
    pub fn blocks_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes).sum()
    }
}

impl Runtime {
    /// Execute with reference arguments (no literal copies).
    pub fn run_refs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

/// Executes a model's forward pass through the cached PJRT executables.
pub struct ModelExecutor<'rt> {
    rt: &'rt Runtime,
    model_dir: std::path::PathBuf,
    pub schema: Schema,
}

impl<'rt> ModelExecutor<'rt> {
    pub fn new(rt: &'rt Runtime, model: &ModelDir) -> Self {
        Self { rt, model_dir: model.dir.clone(), schema: model.schema.clone() }
    }

    fn artifact(&self, name: &str) -> std::path::PathBuf {
        self.model_dir.join(format!("{name}.hlo.txt"))
    }

    fn block_artifact(&self, p: Precision) -> &'static str {
        match p {
            Precision::Raw | Precision::Q3 => "block_raw",
            Precision::Q8 => "block_q8",
            Precision::Q4 => "block_q4",
            Precision::T2 => "block_t2",
        }
    }

    /// Pre-compile every artifact this model's plans may touch.
    pub fn warmup(&self) -> Result<()> {
        for name in ["embed", "head", "block_raw", "block_q8", "block_q4", "block_t2"] {
            self.rt.load(&self.artifact(name))?;
        }
        Ok(())
    }

    /// Full-sequence forward: `tokens` is a (B, S) batch (B = eval_batch,
    /// S = seq_len; caller pads). Returns logits (B, S, V) flattened.
    pub fn forward(&self, qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.schema.eval_batch, self.schema.seq_len);
        assert_eq!(tokens.len(), b * s, "token batch must be ({b},{s})");

        let embed = self.rt.load(&self.artifact("embed"))?;
        let tok_lit = lit_i32(&[b, s], tokens)?;
        let mut args: Vec<&xla::Literal> = vec![&tok_lit];
        args.extend(qm.embed_args.iter());
        let mut h = self.rt.run_refs(&embed, &args)?;

        for blk in &qm.blocks {
            let exe = self.rt.load(&self.artifact(self.block_artifact(blk.prec)))?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + blk.args.len());
            args.push(&h);
            args.extend(blk.args.iter());
            h = self.rt.run_refs(&exe, &args)?;
        }

        let head = self.rt.load(&self.artifact("head"))?;
        let out = self.rt.run_refs(&head, &[&h, &qm.head_args[0], &qm.head_args[1]])?;
        to_vec_f32(&out)
    }

    /// Greedy next-token prediction at `pos` for each row of the batch.
    pub fn next_tokens(&self, qm: &QuantizedModel, tokens: &[i32], pos: usize) -> Result<Vec<i32>> {
        let logits = self.forward(qm, tokens)?;
        let (b, s, v) = (self.schema.eval_batch, self.schema.seq_len, self.schema.vocab);
        Ok((0..b)
            .map(|row| {
                let base = (row * s + pos) * v;
                let slice = &logits[base..base + v];
                slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;

    fn setup() -> Option<(Runtime, ModelDir)> {
        let art = crate::artifacts_dir();
        if !art.join("models/tl-phi/weights.ets").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some((Runtime::cpu().unwrap(), ModelDir::load(art.join("models/tl-phi")).unwrap()))
    }

    fn tokens_for(schema: &Schema) -> Vec<i32> {
        // deterministic fact-shaped contexts
        let (b, s) = (schema.eval_batch, schema.seq_len);
        let mut toks = vec![0i32; b * s];
        for row in 0..b {
            toks[row * s] = 1; // Q
            toks[row * s + 1] = 160 + row as i32; // subject entity
            toks[row * s + 2] = 100 + row as i32; // relation
            toks[row * s + 3] = 2; // A
        }
        toks
    }

    #[test]
    fn raw_forward_produces_finite_logits() {
        let Some((rt, model)) = setup() else { return };
        let plan = QuantPlan::uniform("tl-phi", model.schema.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert_eq!(
            logits.len(),
            model.schema.eval_batch * model.schema.seq_len * model.schema.vocab
        );
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_variants_track_raw() {
        // The paper's premise end-to-end: logits drift grows as precision drops.
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let ex = ModelExecutor::new(&rt, &model);
        let toks = tokens_for(&model.schema);

        let raw =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw)).unwrap();
        let l_raw = ex.forward(&raw, &toks).unwrap();

        let mut errs = std::collections::BTreeMap::new();
        for p in [Precision::Q8, Precision::Q4, Precision::T2] {
            let qm = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, p)).unwrap();
            let l = ex.forward(&qm, &toks).unwrap();
            let err =
                l.iter().zip(&l_raw).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max);
            errs.insert(p, err);
        }
        assert!(errs[&Precision::Q8] < errs[&Precision::Q4]);
        assert!(errs[&Precision::Q4] < errs[&Precision::T2]);
        assert!(errs[&Precision::Q8] < 2.0, "q8 drift too large: {errs:?}");
    }

    #[test]
    fn q3_dispatches_through_raw_artifact() {
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let qm =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q3)).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        // Q3 accounting is smaller than Q4
        let q4 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q4)).unwrap();
        assert!(qm.blocks_bytes() < q4.blocks_bytes());
    }

    #[test]
    fn mixed_plan_uses_multiple_artifacts() {
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let mut plan = QuantPlan::uniform("m", n, Precision::Raw);
        plan.assignments[0] = Precision::Q8;
        plan.assignments[n - 1] = Precision::Q4;
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(rt.cached_modules() >= 4, "embed+head+raw+q8(+q4)");
    }

    #[test]
    fn memorized_fact_is_retrieved_greedily() {
        // tl-phi reached ~84% QA accuracy; most batch rows must decode
        // entity tokens at the answer position.
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let qm =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw)).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let next = ex.next_tokens(&qm, &tokens_for(&model.schema), 3).unwrap();
        let ent_hits = next.iter().filter(|&&t| (160..160 + 16).contains(&t)).count();
        assert!(ent_hits >= 6, "answer-position predictions {next:?}");
    }
}
