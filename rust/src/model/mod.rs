//! Model assembly: quantize a flagship model under a `QuantPlan`, hold the
//! packed weights in memory, and run full-sequence forward passes.
//!
//! `QuantizedModel` is backend-agnostic: it stores the packed `QMat`s (the
//! bytes that would ship to a device) plus the fp32 outer weights. Execution
//! goes through `ModelExecutor`, which dispatches per build configuration:
//!
//! - **`--features xla`**: the PJRT path — one compiled executable per
//!   (arch, precision-variant) serves every block and every plan; weights are
//!   runtime arguments (pre-encoded XLA literals), so switching plans never
//!   recompiles. Q3 (edge mode) has no dedicated artifact: its blocks are
//!   dequantized to f32 at load time and dispatched through `block_raw`
//!   (quantization *noise* is preserved; only the storage path differs).
//! - **default**: the native executor (`refexec`) — the same block math in
//!   pure Rust, served **directly from the packed payloads** through the
//!   fused quantized-GEMM kernels (`crate::kernels`). No artifacts or
//!   external crates required, so analysis/serving run offline, and a
//!   replica's resident weight bytes are the packed size — there is no f32
//!   shadow copy of quantized weights (see `QuantizedModel::resident_bytes`
//!   vs `shadow_copy_bytes`).
//!
//! `QuantizedModel::build_pooled` quantizes blocks concurrently on a
//! `par::Pool`; the packed bytes are identical for every worker count.

pub mod refexec;
pub mod sampler;

pub use refexec::{DecodeState, ForwardPass};

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::ewq::QuantPlan;
use crate::par::Pool;
use crate::quant::{quantize, Precision, QMat};
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::zoo::{ModelDir, Schema};

/// One block's swappable payload generation: the six packed matrices at a
/// single precision, plus the byte accounting for that packing. Published
/// behind an `Arc` so online requantization (`serving::requant`) can swap a
/// block's payloads without tearing readers: a forward/decode step
/// snapshots the `Arc` once per block (`QuantBlock::mats`) and keeps that
/// generation alive for the whole step, while the swap only replaces which
/// generation the *next* snapshot sees.
pub struct BlockMats {
    pub prec: Precision,
    /// wq, wk, wv, wo, w1, w2 — packed under `prec`.
    pub qmats: Vec<QMat>,
    /// stored bytes under `prec`: fp32 norm gains + packed payloads (for
    /// memory accounting)
    pub bytes: usize,
}

/// One block's runtime payload: norm gains + the current packed-matrix
/// generation, plus (under `xla`) the pre-encoded literals in artifact
/// argument order. The packed `qmats` are the only weight representation
/// kept resident; the native executor's kernels dequantize group tiles on
/// the fly. The matrices live behind `Mutex<Arc<..>>` (a std-only atomic
/// slot): readers clone the `Arc` out (`mats`, no allocation), writers
/// `publish` a freshly packed generation — the lock is held only for the
/// pointer copy, never across a repack or a kernel call.
pub struct QuantBlock {
    pub g1: Tensor,
    pub g2: Tensor,
    /// current payload generation (see `BlockMats`)
    mats: Mutex<Arc<BlockMats>>,
    /// literals after the leading activation argument
    #[cfg(feature = "xla")]
    args: Vec<xla::Literal>,
}

impl QuantBlock {
    pub fn new(prec: Precision, g1: Tensor, g2: Tensor, qmats: Vec<QMat>, bytes: usize) -> Self {
        Self {
            g1,
            g2,
            mats: Mutex::new(Arc::new(BlockMats { prec, qmats, bytes })),
            #[cfg(feature = "xla")]
            args: Vec::new(),
        }
    }

    /// Snapshot the current payload generation. Callers hold the returned
    /// `Arc` for at most one step, so a concurrent `publish` never tears a
    /// step and old generations free as soon as the last in-flight step
    /// drops its snapshot. Lock + refcount bump only — no allocation, so
    /// the zero-allocation guarantee of the steady-state decode path holds.
    pub fn mats(&self) -> Arc<BlockMats> {
        self.mats.lock().expect("block payload lock poisoned").clone()
    }

    /// Atomically replace the payload generation (requant swap commit).
    pub fn publish(&self, mats: Arc<BlockMats>) {
        *self.mats.lock().expect("block payload lock poisoned") = mats;
    }

    /// Current precision rung (snapshot; may change at the next step).
    pub fn prec(&self) -> Precision {
        self.mats().prec
    }

    /// Current stored bytes (snapshot).
    pub fn bytes(&self) -> usize {
        self.mats().bytes
    }
}

/// A fully quantized, runtime-ready model instance.
pub struct QuantizedModel {
    pub schema: Schema,
    pub plan: QuantPlan,
    pub blocks: Vec<QuantBlock>,
    pub embed: Tensor,
    pub pos: Tensor,
    pub gf: Tensor,
    pub head: Tensor,
    #[cfg(feature = "xla")]
    embed_args: Vec<xla::Literal>, // embed, pos
    #[cfg(feature = "xla")]
    head_args: Vec<xla::Literal>, // gf, head
}

#[cfg(feature = "xla")]
fn qmat_literals(m: &QMat) -> Result<Vec<xla::Literal>> {
    use crate::quant::Payload;
    use crate::runtime::lit_f32;
    let (k, n) = (m.rows, m.cols);
    Ok(match &m.payload {
        Payload::Raw(d) => vec![lit_f32(&[k, n], d)?],
        Payload::Q8 { q, s } => vec![crate::runtime::lit_i8(&[k, n], q)?, lit_f32(&[n], s)?],
        Payload::Q4 { p, s } => vec![crate::runtime::lit_u8(&[k / 2, n], p)?, lit_f32(&[n], s)?],
        Payload::T2 { p, s } => vec![crate::runtime::lit_u8(&[k / 4, n], p)?, lit_f32(&[n], s)?],
        Payload::Q3 { .. } => anyhow::bail!("Q3 must be dequantized before literal encoding"),
    })
}

/// Encode one block's executor arguments in artifact order (PJRT path only).
#[cfg(feature = "xla")]
fn encode_block_args(blk: &QuantBlock) -> Result<Vec<xla::Literal>> {
    use crate::runtime::lit_f32;
    let d = blk.g1.numel();
    // Encode-time snapshot: the PJRT literals are baked from the build-time
    // payload generation and are NOT refreshed by requant swaps — online
    // requantization drives the native path only (see `serving::requant`).
    let mats = blk.mats();
    let mut args: Vec<xla::Literal> = Vec::with_capacity(14);
    match mats.prec {
        Precision::Raw | Precision::Q3 => {
            // block_raw argument order: g1, wq, wk, wv, wo, g2, w1, w2.
            // Dequantized once here at encode time (literals are the
            // resident representation on this path), not cached on the block.
            args.push(lit_f32(&[d], &blk.g1.data)?);
            let t_mats: Vec<Tensor> = mats.qmats.iter().map(crate::quant::dequantize).collect();
            for t in &t_mats[..4] {
                args.push(lit_f32(&t.shape, &t.data)?);
            }
            args.push(lit_f32(&[d], &blk.g2.data)?);
            for t in &t_mats[4..] {
                args.push(lit_f32(&t.shape, &t.data)?);
            }
        }
        Precision::Q8 | Precision::Q4 | Precision::T2 => {
            // block_q* argument order: g1, g2, then (q, s) x 6
            args.push(lit_f32(&[d], &blk.g1.data)?);
            args.push(lit_f32(&[d], &blk.g2.data)?);
            for m in &mats.qmats {
                args.extend(qmat_literals(m)?);
            }
        }
    }
    Ok(args)
}

impl QuantizedModel {
    /// Quantize `model` under `plan` (serial reference path).
    pub fn build(model: &ModelDir, plan: &QuantPlan) -> Result<Self> {
        Self::build_pooled(model, plan, &Pool::serial())
    }

    /// Quantize `model` under `plan`, packing blocks concurrently on `pool`.
    /// The packed bytes — and under `xla` the encoded literals — are
    /// identical for every worker count (XLA literal encoding itself stays
    /// on the calling thread: literals are not `Send`).
    pub fn build_pooled(model: &ModelDir, plan: &QuantPlan, pool: &Pool) -> Result<Self> {
        let schema = model.schema.clone();
        assert_eq!(plan.assignments.len(), schema.n_blocks);
        let d = schema.d_model;

        // phase 1 (parallel): pack every block — plain `Send` data only, so
        // this fans out regardless of backend
        let packed: Vec<(Precision, Vec<QMat>, usize)> =
            pool.par_map_range(schema.n_blocks, |b| {
                let prec = plan.assignments[b];
                let w = &model.weights.blocks[b];
                let qmats: Vec<QMat> = w.mats.iter().map(|t| quantize(t, prec)).collect();
                let bytes = 4 * 2 * d + qmats.iter().map(|m| m.size_bytes()).sum::<usize>();
                (prec, qmats, bytes)
            });

        // phase 2 (serial): assemble blocks; under `xla` also pre-encode the
        // PJRT argument literals (literals are not `Send`)
        #[allow(unused_mut)]
        let mut blocks: Vec<QuantBlock> = packed
            .into_iter()
            .enumerate()
            .map(|(b, (prec, qmats, bytes))| {
                QuantBlock::new(
                    prec,
                    model.weights.blocks[b].g1.clone(),
                    model.weights.blocks[b].g2.clone(),
                    qmats,
                    bytes,
                )
            })
            .collect();
        #[cfg(feature = "xla")]
        for blk in &mut blocks {
            blk.args = encode_block_args(blk)?;
        }

        let w = &model.weights;
        Ok(Self {
            #[cfg(feature = "xla")]
            embed_args: vec![
                crate::runtime::lit_f32(&w.embed.shape, &w.embed.data)?,
                crate::runtime::lit_f32(&w.pos.shape, &w.pos.data)?,
            ],
            #[cfg(feature = "xla")]
            head_args: vec![
                crate::runtime::lit_f32(&w.gf.shape, &w.gf.data)?,
                crate::runtime::lit_f32(&w.head.shape, &w.head.data)?,
            ],
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            gf: w.gf.clone(),
            head: w.head.clone(),
            schema,
            plan: plan.clone(),
            blocks,
        })
    }

    /// Stored bytes of all blocks as currently packed (tracks requant swaps).
    pub fn blocks_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }

    /// fp32 bytes of the non-block weights (embed + pos + final norm + head).
    fn outer_bytes(&self) -> usize {
        4 * (self.embed.numel() + self.pos.numel() + self.gf.numel() + self.head.numel())
    }

    /// f32 bytes of all block matrices if they were held dequantized.
    fn blocks_f32_bytes(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                let mats = b.mats();
                mats.qmats.iter().map(|m| 4 * m.rows * m.cols).sum::<usize>()
            })
            .sum()
    }

    /// **Resident** weight bytes of this replica as served: packed block
    /// payloads + fp32 norm gains + fp32 outer weights. The fused kernels
    /// consume the packed payloads directly, so this is all the weight
    /// memory a native replica keeps — the paper's memory-reduction claim,
    /// measurable per plan (equals `QuantPlan::total_bytes`).
    pub fn resident_bytes(&self) -> usize {
        self.outer_bytes() + self.blocks_bytes()
    }

    /// The same weights with every block matrix held in f32 (an
    /// unquantized model's resident footprint).
    pub fn f32_equivalent_bytes(&self) -> usize {
        self.outer_bytes()
            + self.blocks.iter().map(|b| 4 * (b.g1.numel() + b.g2.numel())).sum::<usize>()
            + self.blocks_f32_bytes()
    }

    /// What the pre-kernel serving path kept resident: the packed payloads
    /// PLUS a cached f32 dequantized copy of every block matrix (the
    /// deleted `effective_mats` shadow copies). Kept as the baseline the
    /// memory-reduction claim is measured against.
    pub fn shadow_copy_bytes(&self) -> usize {
        self.resident_bytes() + self.blocks_f32_bytes()
    }

    /// Re-pack block `b`'s payloads at `target` precision and publish the
    /// new generation atomically (Arc swap; see `QuantBlock::publish`). The
    /// repack runs on the caller's thread against a snapshot, so it is safe
    /// to call while other threads hold older snapshots mid-step — they
    /// finish on their generation and pick up the new one at their next
    /// `mats()` call. Same-precision calls are no-ops. Returns
    /// `(old_bytes, new_bytes)` for residency accounting.
    ///
    /// Note the information floor: promoting a block (e.g. Q4 → Q8) re-packs
    /// from the current lattice, so quantization noise already incurred is
    /// kept, not undone — the promoted block costs Q8 bytes but carries Q4
    /// fidelity until a fresh build (`quant::repack` documents this).
    pub fn requantize_block(&self, b: usize, target: Precision) -> (usize, usize) {
        let blk = &self.blocks[b];
        let old = blk.mats();
        if old.prec == target {
            return (old.bytes, old.bytes);
        }
        let qmats: Vec<QMat> =
            old.qmats.iter().map(|m| crate::quant::repack(m, target)).collect();
        let bytes = 4 * (blk.g1.numel() + blk.g2.numel())
            + qmats.iter().map(|m| m.size_bytes()).sum::<usize>();
        blk.publish(Arc::new(BlockMats { prec: target, qmats, bytes }));
        (old.bytes, bytes)
    }

    /// Blocks per precision rung, indexed by `Precision::tag()` — the
    /// residency histogram `ServingMetrics::block_residency` reports.
    pub fn block_residency(&self) -> [usize; 5] {
        let mut out = [0usize; 5];
        for b in &self.blocks {
            out[b.prec().tag() as usize] += 1;
        }
        out
    }
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Execute with reference arguments (no literal copies).
    pub fn run_refs(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe.execute::<&xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

/// Executes a model's forward pass: PJRT executables when built with the
/// `xla` feature and the model directory has artifacts, the native fused-
/// kernel path (`refexec::ForwardPass`) otherwise. The native pass owns a
/// per-executor scratch arena (reused across calls, zero steady-state
/// allocation in the block loop) behind a `RefCell` — executors are
/// single-threaded by construction (each serving shard builds its own).
pub struct ModelExecutor<'rt> {
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    rt: &'rt Runtime,
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    model_dir: std::path::PathBuf,
    pub schema: Schema,
    native: std::cell::RefCell<refexec::ForwardPass>,
    #[cfg(feature = "xla")]
    use_pjrt: bool,
}

impl<'rt> ModelExecutor<'rt> {
    /// Serial-pool executor (the default: shard workers parallelize across
    /// replicas, not inside one forward).
    pub fn new(rt: &'rt Runtime, model: &ModelDir) -> Self {
        Self::with_pool(rt, model, Pool::serial())
    }

    /// Executor whose native forward fans matmul row bands and per-request
    /// attention rows out on `pool`. Results are bit-identical to the
    /// serial executor for any worker count.
    pub fn with_pool(rt: &'rt Runtime, model: &ModelDir, pool: Pool) -> Self {
        Self {
            rt,
            model_dir: model.dir.clone(),
            schema: model.schema.clone(),
            native: std::cell::RefCell::new(refexec::ForwardPass::new(&model.schema, pool)),
            #[cfg(feature = "xla")]
            use_pjrt: model.dir.join("block_raw.hlo.txt").exists(),
        }
    }

    /// Which execution backend forward passes use.
    pub fn backend(&self) -> &'static str {
        #[cfg(feature = "xla")]
        if self.use_pjrt {
            return "pjrt";
        }
        "native-ref"
    }

    #[cfg(feature = "xla")]
    fn artifact(&self, name: &str) -> std::path::PathBuf {
        self.model_dir.join(format!("{name}.hlo.txt"))
    }

    #[cfg(feature = "xla")]
    fn block_artifact(&self, p: Precision) -> &'static str {
        match p {
            Precision::Raw | Precision::Q3 => "block_raw",
            Precision::Q8 => "block_q8",
            Precision::Q4 => "block_q4",
            Precision::T2 => "block_t2",
        }
    }

    /// Pre-compile every artifact this model's plans may touch (no-op on the
    /// native path).
    pub fn warmup(&self) -> Result<()> {
        #[cfg(feature = "xla")]
        if self.use_pjrt {
            for name in ["embed", "head", "block_raw", "block_q8", "block_q4", "block_t2"] {
                self.rt.load(&self.artifact(name))?;
            }
        }
        Ok(())
    }

    /// Full-sequence forward: `tokens` is a (B, S) batch (B = eval_batch,
    /// S = seq_len; caller pads). Returns logits (B, S, V) flattened.
    pub fn forward(&self, qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.schema.eval_batch, self.schema.seq_len);
        assert_eq!(tokens.len(), b * s, "token batch must be ({b},{s})");
        #[cfg(feature = "xla")]
        if self.use_pjrt {
            return self.forward_pjrt(qm, tokens);
        }
        self.native.borrow_mut().forward(qm, tokens)
    }

    #[cfg(feature = "xla")]
    fn forward_pjrt(&self, qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
        use crate::runtime::{lit_i32, to_vec_f32};
        let (b, s) = (self.schema.eval_batch, self.schema.seq_len);

        let embed = self.rt.load(&self.artifact("embed"))?;
        let tok_lit = lit_i32(&[b, s], tokens)?;
        let mut args: Vec<&xla::Literal> = vec![&tok_lit];
        args.extend(qm.embed_args.iter());
        let mut h = self.rt.run_refs(&embed, &args)?;

        for blk in &qm.blocks {
            let exe = self.rt.load(&self.artifact(self.block_artifact(blk.prec())))?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + blk.args.len());
            args.push(&h);
            args.extend(blk.args.iter());
            h = self.rt.run_refs(&exe, &args)?;
        }

        let head = self.rt.load(&self.artifact("head"))?;
        let out = self.rt.run_refs(&head, &[&h, &qm.head_args[0], &qm.head_args[1]])?;
        to_vec_f32(&out)
    }

    /// One incremental decode step against the sequence's cached K/V — see
    /// `refexec::ForwardPass::decode_step_into`. Decode always runs on the
    /// native fused path: there are no PJRT decode artifacts, and the
    /// native pass is bit-identical to the full-sequence forward at Raw KV
    /// precision, so generation semantics are backend-independent.
    pub fn decode_step_into(
        &self,
        qm: &QuantizedModel,
        token: i32,
        st: &mut refexec::DecodeState,
        cache: &mut crate::serving::kvcache::KvCache,
        logits: &mut [f32],
    ) -> Result<()> {
        self.native.borrow_mut().decode_step_into(qm, token, st, cache, logits)
    }

    /// One **batched** decode step over every live sequence — see
    /// `refexec::ForwardPass::decode_step_batched`. Row `i` of `logits`
    /// (`states.len() * vocab` floats) is sequence `i`'s next-token logits;
    /// bit-identical to `states.len()` separate `decode_step_into` calls,
    /// which the serving layer keeps alive as the equivalence oracle.
    pub fn decode_step_batched(
        &self,
        qm: &QuantizedModel,
        tokens: &[i32],
        states: &mut [refexec::DecodeState],
        cache: &mut crate::serving::kvcache::KvCache,
        logits: &mut [f32],
    ) -> Result<()> {
        self.native.borrow_mut().decode_step_batched(qm, tokens, states, cache, logits)
    }

    /// Greedy next-token prediction at `pos` for each row of the batch.
    pub fn next_tokens(&self, qm: &QuantizedModel, tokens: &[i32], pos: usize) -> Result<Vec<i32>> {
        let logits = self.forward(qm, tokens)?;
        let (b, s, v) = (self.schema.eval_batch, self.schema.seq_len, self.schema.vocab);
        Ok((0..b)
            .map(|row| {
                let base = (row * s + pos) * v;
                let slice = &logits[base..base + v];
                slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;

    fn setup() -> Option<(Runtime, ModelDir)> {
        let art = crate::artifacts_dir();
        if !art.join("models/tl-phi/weights.ets").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some((Runtime::cpu().unwrap(), ModelDir::load(art.join("models/tl-phi")).unwrap()))
    }

    fn tokens_for(schema: &Schema) -> Vec<i32> {
        // deterministic fact-shaped contexts
        let (b, s) = (schema.eval_batch, schema.seq_len);
        let mut toks = vec![0i32; b * s];
        for row in 0..b {
            toks[row * s] = 1; // Q
            toks[row * s + 1] = 160 + row as i32; // subject entity
            toks[row * s + 2] = 100 + row as i32; // relation
            toks[row * s + 3] = 2; // A
        }
        toks
    }

    #[test]
    fn pooled_build_matches_serial() {
        // no artifacts needed: synthetic in-memory model
        use crate::zoo::gen::{synthetic_archs, synthetic_model_dir};
        let model = synthetic_model_dir(&synthetic_archs(1, 5)[0]);
        let n = model.schema.n_blocks;
        let mut plan = QuantPlan::uniform("syn", n, Precision::Q8);
        plan.assignments[0] = Precision::Raw;
        plan.assignments[n - 1] = Precision::Q4;
        plan.assignments[n / 2] = Precision::T2;
        let serial = QuantizedModel::build(&model, &plan).unwrap();
        for workers in [2usize, 4] {
            let pooled =
                QuantizedModel::build_pooled(&model, &plan, &Pool::new(workers)).unwrap();
            assert_eq!(pooled.blocks.len(), serial.blocks.len());
            for (a, b) in serial.blocks.iter().zip(&pooled.blocks) {
                let (am, bm) = (a.mats(), b.mats());
                assert_eq!(am.prec, bm.prec);
                assert_eq!(am.bytes, bm.bytes);
                assert_eq!(am.qmats, bm.qmats, "workers={workers}");
            }
            assert_eq!(pooled.blocks_bytes(), serial.blocks_bytes());
        }
    }

    #[test]
    fn bytes_accounting_tracks_plan() {
        use crate::zoo::gen::{synthetic_archs, synthetic_model_dir};
        let model = synthetic_model_dir(&synthetic_archs(1, 6)[0]);
        let n = model.schema.n_blocks;
        let raw = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw))
            .unwrap();
        let q8 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q8)).unwrap();
        let q4 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q4)).unwrap();
        assert!(raw.blocks_bytes() > q8.blocks_bytes());
        assert!(q8.blocks_bytes() > q4.blocks_bytes());
        assert_eq!(
            raw.blocks_bytes(),
            QuantPlan::uniform("m", n, Precision::Raw).blocks_bytes(&model.schema)
        );
    }

    #[test]
    fn resident_bytes_shrink_to_packed_size() {
        use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
        // a block-dominant geometry (the regime the paper's 18% claim lives
        // in): blocks outweigh the fp32 embed/pos/head
        let model = synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "resident".into(),
                n_blocks: 6,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 512,
                seq_len: 32,
                eval_batch: 8,
            },
            profile: Profile::UShape,
            seed: 5150,
        });
        let n = model.schema.n_blocks;
        let mut mixed = QuantPlan::uniform("m", n, Precision::Q4);
        for b in (0..n).step_by(2) {
            mixed.assignments[b] = Precision::Q8;
        }
        let qm = QuantizedModel::build(&model, &mixed).unwrap();
        // accounting identities
        assert_eq!(qm.resident_bytes(), mixed.total_bytes(&model.schema));
        assert_eq!(
            qm.shadow_copy_bytes(),
            qm.resident_bytes()
                + 4 * model.schema.n_blocks * model.schema.block_params()
        );
        // the acceptance bound: serving from packed weights keeps less than
        // half of what the shadow-copy path pinned
        assert!(
            2 * qm.resident_bytes() <= qm.shadow_copy_bytes(),
            "resident {} !<= 0.5 * shadow {}",
            qm.resident_bytes(),
            qm.shadow_copy_bytes()
        );
        // raw plan: resident == f32 equivalent (nothing is packed smaller)
        let raw = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw))
            .unwrap();
        assert_eq!(raw.resident_bytes(), raw.f32_equivalent_bytes());
        assert_eq!(qm.f32_equivalent_bytes(), raw.f32_equivalent_bytes());
        // precision ladder orders resident footprints
        let q8 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q8)).unwrap();
        let t2 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::T2)).unwrap();
        assert!(raw.resident_bytes() > q8.resident_bytes());
        assert!(q8.resident_bytes() > qm.resident_bytes());
        assert!(qm.resident_bytes() > t2.resident_bytes());
    }

    #[test]
    fn requantize_block_swaps_payloads_and_accounting() {
        use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
        let model = synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "requant".into(),
                n_blocks: 4,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 256,
                seq_len: 16,
                eval_batch: 4,
            },
            profile: Profile::RampUp,
            seed: 4242,
        });
        let n = model.schema.n_blocks;
        let qm =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q8)).unwrap();
        let before = qm.resident_bytes();
        assert_eq!(qm.block_residency()[Precision::Q8.tag() as usize], n);

        // same-precision swap is a no-op
        let (old, new) = qm.requantize_block(0, Precision::Q8);
        assert_eq!(old, new);
        assert_eq!(qm.resident_bytes(), before);

        // demote block 0 to Q4: residency books shrink by exactly old - new
        let (old, new) = qm.requantize_block(0, Precision::Q4);
        assert!(new < old);
        assert_eq!(qm.resident_bytes(), before - (old - new));
        assert_eq!(qm.blocks[0].prec(), Precision::Q4);
        let res = qm.block_residency();
        assert_eq!(res[Precision::Q8.tag() as usize], n - 1);
        assert_eq!(res[Precision::Q4.tag() as usize], 1);

        // a snapshot taken before a swap keeps the old generation alive and
        // untouched — this is the no-torn-reads guarantee decode rides
        let pre = qm.blocks[1].mats();
        qm.requantize_block(1, Precision::Q3);
        assert_eq!(pre.prec, Precision::Q8);
        assert_eq!(qm.blocks[1].prec(), Precision::Q3);

        // promotion re-packs from the current (demoted) lattice
        let demoted = qm.blocks[0].mats();
        let (_, back) = qm.requantize_block(0, Precision::Q8);
        let direct: Vec<QMat> = demoted
            .qmats
            .iter()
            .map(|m| crate::quant::repack(m, Precision::Q8))
            .collect();
        assert_eq!(qm.blocks[0].mats().qmats, direct);
        assert_eq!(back, qm.blocks[0].bytes());
    }

    #[test]
    fn raw_forward_produces_finite_logits() {
        let Some((rt, model)) = setup() else { return };
        let plan = QuantPlan::uniform("tl-phi", model.schema.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert_eq!(
            logits.len(),
            model.schema.eval_batch * model.schema.seq_len * model.schema.vocab
        );
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_variants_track_raw() {
        // The paper's premise end-to-end: logits drift grows as precision drops.
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let ex = ModelExecutor::new(&rt, &model);
        let toks = tokens_for(&model.schema);

        let raw =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw)).unwrap();
        let l_raw = ex.forward(&raw, &toks).unwrap();

        let mut errs = std::collections::BTreeMap::new();
        for p in [Precision::Q8, Precision::Q4, Precision::T2] {
            let qm = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, p)).unwrap();
            let l = ex.forward(&qm, &toks).unwrap();
            let err =
                l.iter().zip(&l_raw).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max);
            errs.insert(p, err);
        }
        assert!(errs[&Precision::Q8] < errs[&Precision::Q4]);
        assert!(errs[&Precision::Q4] < errs[&Precision::T2]);
        assert!(errs[&Precision::Q8] < 2.0, "q8 drift too large: {errs:?}");
    }

    #[test]
    fn q3_dispatches_through_raw_artifact() {
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let qm =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q3)).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        // Q3 accounting is smaller than Q4
        let q4 =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q4)).unwrap();
        assert!(qm.blocks_bytes() < q4.blocks_bytes());
    }

    #[test]
    fn mixed_plan_uses_multiple_artifacts() {
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let mut plan = QuantPlan::uniform("m", n, Precision::Raw);
        plan.assignments[0] = Precision::Q8;
        plan.assignments[n - 1] = Precision::Q4;
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let logits = ex.forward(&qm, &tokens_for(&model.schema)).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        if cfg!(feature = "xla") {
            assert!(rt.cached_modules() >= 4, "embed+head+raw+q8(+q4)");
        }
    }

    #[test]
    fn memorized_fact_is_retrieved_greedily() {
        // tl-phi reached ~84% QA accuracy; most batch rows must decode
        // entity tokens at the answer position.
        let Some((rt, model)) = setup() else { return };
        let n = model.schema.n_blocks;
        let qm =
            QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Raw)).unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        let next = ex.next_tokens(&qm, &tokens_for(&model.schema), 3).unwrap();
        let ent_hits = next.iter().filter(|&&t| (160..160 + 16).contains(&t)).count();
        assert!(ent_hits >= 6, "answer-position predictions {next:?}");
    }
}
