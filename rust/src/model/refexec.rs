//! Native reference executor: the L2 transformer forward pass
//! (`python/compile/model.py`) in pure Rust, running over a
//! `QuantizedModel`'s dequantized effective weights.
//!
//! This is the default executor when the crate is built without the `xla`
//! feature (and the fallback when artifacts are absent): pre-RMSNorm decoder
//! blocks, causal multi-head attention, tanh-GELU MLP, fp32 embed/head.
//! Quantization *noise* is preserved exactly — each block's matrices are the
//! dequantized `QMat` payloads, the same effective weights the AOT graph
//! reconstructs in-VMEM — so precision-ladder experiments (drift, accuracy,
//! perplexity ordering) behave the same way as on the PJRT path.

use anyhow::{ensure, Result};

use crate::model::QuantizedModel;
use crate::tensor::Tensor;

/// Full-sequence forward: `tokens` is a flattened (B, S) batch; returns
/// logits (B, S, V) flattened, matching `ModelExecutor::forward`.
pub fn forward(qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
    let s = &qm.schema;
    let (b, sl, d, v) = (s.eval_batch, s.seq_len, s.d_model, s.vocab);
    ensure!(tokens.len() == b * sl, "token batch must be ({b},{sl})");

    // embed + positional: x[r,t] = embed[token] + pos[t]
    let rows = b * sl;
    let mut x = vec![0.0f32; rows * d];
    for row in 0..b {
        for t in 0..sl {
            let tok = tokens[row * sl + t];
            ensure!(tok >= 0 && (tok as usize) < v, "token {tok} outside vocab {v}");
            let e = &qm.embed.data[tok as usize * d..(tok as usize + 1) * d];
            let p = &qm.pos.data[t * d..(t + 1) * d];
            let o = &mut x[(row * sl + t) * d..(row * sl + t + 1) * d];
            for j in 0..d {
                o[j] = e[j] + p[j];
            }
        }
    }

    for blk in &qm.blocks {
        block_forward(&mut x, b, sl, s.n_heads, &blk.g1.data, &blk.g2.data, blk.effective_mats());
    }

    // head: rms(x, gf) @ head -> (B*S, V)
    let xn = rms_rows(&x, &qm.gf.data);
    Ok(matmul(&xn, &qm.head.data, rows, d, v))
}

/// One pre-RMSNorm decoder block, in place over the (B*S, d) activations:
///   h = x + Attn(rms(x, g1); Wq, Wk, Wv, Wo)
///   y = h + W2 @ gelu(W1 @ rms(h, g2))
fn block_forward(
    x: &mut [f32],
    b: usize,
    sl: usize,
    n_heads: usize,
    g1: &[f32],
    g2: &[f32],
    mats: &[Tensor],
) {
    let d = g1.len();
    let rows = b * sl;
    let ff = mats[4].dims2().1;

    let xn = rms_rows(x, g1);
    let q = matmul(&xn, &mats[0].data, rows, d, d);
    let k = matmul(&xn, &mats[1].data, rows, d, d);
    let v = matmul(&xn, &mats[2].data, rows, d, d);
    let a = attention(&q, &k, &v, b, sl, d, n_heads);
    let ao = matmul(&a, &mats[3].data, rows, d, d);
    for (xi, oi) in x.iter_mut().zip(&ao) {
        *xi += oi;
    }

    let hn = rms_rows(x, g2);
    let mut h1 = matmul(&hn, &mats[4].data, rows, d, ff);
    for h in h1.iter_mut() {
        *h = gelu(*h);
    }
    let h2 = matmul(&h1, &mats[5].data, rows, ff, d);
    for (xi, oi) in x.iter_mut().zip(&h2) {
        *xi += oi;
    }
}

/// Row-wise RMSNorm with gain: x * g / sqrt(mean(x^2) + 1e-6).
fn rms_rows(x: &[f32], g: &[f32]) -> Vec<f32> {
    let d = g.len();
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for i in 0..rows {
        let r = &x[i * d..(i + 1) * d];
        let mut ss = 0.0f32;
        for &val in r {
            ss += val * val;
        }
        let inv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
        let o = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            o[j] = r[j] * g[j] * inv;
        }
    }
    out
}

/// (m,k) @ (k,n) row-major matmul, ikj loop order for stride-1 inner loops.
fn matmul(a: &[f32], bmat: &[f32], m: usize, kdim: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * kdim);
    debug_assert_eq!(bmat.len(), kdim * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * kdim..(i + 1) * kdim];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &bmat[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Causal multi-head attention over per-row (B,S,d) activations: softmax of
/// q·k / sqrt(hd) over positions <= t (rows never mix across the batch dim,
/// which is what makes per-request responses batching-invariant).
fn attention(q: &[f32], k: &[f32], v: &[f32], b: usize, sl: usize, d: usize, n_heads: usize) -> Vec<f32> {
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; b * sl * d];
    let mut scores = vec![0.0f32; sl];
    for bi in 0..b {
        for h in 0..n_heads {
            let off = h * hd;
            for t in 0..sl {
                let qrow = &q[(bi * sl + t) * d + off..(bi * sl + t) * d + off + hd];
                let mut m = f32::NEG_INFINITY;
                for u in 0..=t {
                    let krow = &k[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += qrow[j] * krow[j];
                    }
                    scores[u] = dot * scale;
                    if scores[u] > m {
                        m = scores[u];
                    }
                }
                let mut z = 0.0f32;
                for u in 0..=t {
                    scores[u] = (scores[u] - m).exp();
                    z += scores[u];
                }
                let orow = &mut out[(bi * sl + t) * d + off..(bi * sl + t) * d + off + hd];
                for u in 0..=t {
                    let w = scores[u] / z;
                    let vrow = &v[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    for j in 0..hd {
                        orow[j] += w * vrow[j];
                    }
                }
            }
        }
    }
    out
}

/// tanh-approximate GELU (`jax.nn.gelu` default).
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ewq::QuantPlan;
    use crate::model::{ModelExecutor, QuantizedModel};
    use crate::quant::Precision;
    use crate::runtime::Runtime;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
    use crate::zoo::{ModelDir, Schema};

    fn tiny_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "tiny".into(),
                n_blocks: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                vocab: 64,
                seq_len: 8,
                eval_batch: 4,
            },
            profile: Profile::UShape,
            seed: 77,
        })
    }

    fn tokens(schema: &Schema) -> Vec<i32> {
        let (b, s) = (schema.eval_batch, schema.seq_len);
        let mut toks = vec![0i32; b * s];
        for row in 0..b {
            for t in 0..4 {
                toks[row * s + t] = ((row * 7 + t * 3) % schema.vocab) as i32;
            }
        }
        toks
    }

    #[test]
    fn raw_forward_shapes_and_finiteness() {
        let model = tiny_model();
        let s = &model.schema;
        let plan = QuantPlan::uniform("tiny", s.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let logits = forward(&qm, &tokens(s)).unwrap();
        assert_eq!(logits.len(), s.eval_batch * s.seq_len * s.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        // not degenerate: logits vary across vocab
        let (mn, mx) = logits.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &x| {
            (a.min(x), b.max(x))
        });
        assert!(mx > mn);
    }

    #[test]
    fn forward_is_deterministic() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let a = forward(&qm, &tokens(&model.schema)).unwrap();
        let b = forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn quantization_drift_orders_with_precision() {
        let model = tiny_model();
        let n = model.schema.n_blocks;
        let toks = tokens(&model.schema);
        let run = |p: Precision| {
            let qm = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, p)).unwrap();
            forward(&qm, &toks).unwrap()
        };
        let raw = run(Precision::Raw);
        let max_err = |l: &[f32]| {
            l.iter().zip(&raw).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max)
        };
        let e8 = max_err(&run(Precision::Q8));
        let e4 = max_err(&run(Precision::Q4));
        let e2 = max_err(&run(Precision::T2));
        assert!(e8 < e4, "q8 {e8} !< q4 {e4}");
        assert!(e4 < e2, "q4 {e4} !< t2 {e2}");
    }

    #[test]
    fn q3_and_mixed_plans_execute() {
        let model = tiny_model();
        let n = model.schema.n_blocks;
        let q3 = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q3))
            .unwrap();
        assert!(forward(&q3, &tokens(&model.schema)).unwrap().iter().all(|x| x.is_finite()));
        let mut plan = QuantPlan::uniform("m", n, Precision::Raw);
        plan.assignments[n - 1] = Precision::Q4;
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        assert!(forward(&qm, &tokens(&model.schema)).unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn out_of_vocab_token_is_rejected() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut toks = tokens(&model.schema);
        toks[0] = model.schema.vocab as i32; // one past the end
        assert!(forward(&qm, &toks).is_err());
        toks[0] = -1;
        assert!(forward(&qm, &toks).is_err());
    }

    #[test]
    fn executor_dispatches_to_native_for_synthetic_models() {
        // a synthetic ModelDir has no artifacts, so the executor must take
        // the native path in every build configuration
        let model = tiny_model();
        let rt = Runtime::cpu().unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        assert_eq!(ex.backend(), "native-ref");
        ex.warmup().unwrap();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let via_executor = ex.forward(&qm, &tokens(&model.schema)).unwrap();
        let direct = forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(via_executor, direct);
        let next = ex.next_tokens(&qm, &tokens(&model.schema), 3).unwrap();
        assert_eq!(next.len(), model.schema.eval_batch);
        assert!(next.iter().all(|&t| (0..model.schema.vocab as i32).contains(&t)));
    }

    #[test]
    fn rms_normalizes_magnitude() {
        let g = vec![1.0f32; 8];
        let x: Vec<f32> = (0..8).map(|i| (i as f32 + 1.0) * 10.0).collect();
        let out = rms_rows(&x, &g);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 8.0;
        assert!((ms - 1.0).abs() < 1e-3, "mean square {ms}");
    }

    #[test]
    fn matmul_matches_hand_computed() {
        // (2x3) @ (3x2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn attention_is_causal_and_row_normalized() {
        // with q=k=0 scores are uniform over the visible prefix, so the
        // output at position t is the mean of v[0..=t]
        let (b, sl, d, h) = (1usize, 4usize, 8usize, 2usize);
        let q = vec![0.0f32; b * sl * d];
        let k = vec![0.0f32; b * sl * d];
        let mut v = vec![0.0f32; b * sl * d];
        for t in 0..sl {
            for j in 0..d {
                v[t * d + j] = t as f32;
            }
        }
        let out = attention(&q, &k, &v, b, sl, d, h);
        for t in 0..sl {
            let expect = (0..=t).sum::<usize>() as f32 / (t + 1) as f32;
            for j in 0..d {
                assert!((out[t * d + j] - expect).abs() < 1e-5, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn gelu_reference_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        // large |x|: approaches identity / zero
        assert!((gelu(6.0) - 6.0).abs() < 1e-3);
        assert!(gelu(-6.0).abs() < 1e-3);
    }
}
