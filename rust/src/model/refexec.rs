//! Native executor: the L2 transformer forward pass
//! (`python/compile/model.py`) in pure Rust, serving **directly from the
//! packed `QMat` payloads** through the fused quantized-GEMM kernels
//! (`crate::kernels`).
//!
//! This is the default executor when the crate is built without the `xla`
//! feature (and the fallback when artifacts are absent): pre-RMSNorm decoder
//! blocks, causal multi-head attention, tanh-GELU MLP, fp32 embed/head.
//! Quantization *noise* is preserved exactly — the kernels' group-wise tile
//! dequantization produces the same effective weights `dequantize` would,
//! accumulated in the same `k` order — so the fused path is bit-identical
//! to the dequantize-then-matmul reference (`forward_reference`, kept for
//! tests/benches) while keeping only packed bytes resident.
//!
//! `ForwardPass` owns the per-executor scratch arena (`Scratch`): activation
//! buffers, per-worker attention score rows, and the kernel `TilePool` are
//! allocated once from the schema, so `block_forward` does zero heap
//! allocation in steady state (`Scratch::grow_events` is the test hook that
//! proves it). Matmul row bands and per-request attention rows fan out on
//! the `par::Pool` the pass was built with — whose helper threads are
//! spawned once and parked between kernel scopes, so a steady-state pooled
//! forward also performs zero thread spawns (`Pool::spawn_events` is the
//! matching hook); results are bit-identical for any worker count.
//!
//! Both prefill (`forward`, GEMMs) and decode (`decode_step_into`, GEMVs)
//! run on the kernels' SIMD inner loops when the CPU supports them
//! (`simd::kernel_path()`; `EWQ_FORCE_SCALAR` pins the portable fallback)
//! and on the shape-chosen row/column banding (`kernels::gemm_banding`) —
//! all of which are bit-identical by construction (DESIGN.md §11), so
//! logits are invariant to path, banding, and worker count alike.
//!
//! `DecodeState` carries the sequence's KV cursor across steps, including
//! the prefix-caching seam (DESIGN.md §14): `DecodeState::attach_prefix`
//! seats a fresh sequence on already-resident shared-prefix pages (the
//! cursor starts past them, so the first turns ingest only the unshared
//! suffix) and `DecodeState::register_prefix` publishes a fully ingested
//! context for later attaches. Because cached page bytes are a
//! deterministic function of the token prefix, an attached sequence's
//! logits are bit-identical to a fresh full ingest — the invariant the
//! `decode_equivalence` prefix properties pin.

use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::kernels::{matmul_f32, matmul_qmat, matvec_f32, matvec_qmat, TilePool};
use crate::model::QuantizedModel;
use crate::par::Pool;
use crate::quant::{dequantize, QMat};
use crate::serving::kvcache::{KvCache, PrefixAttach};
use crate::tensor::Tensor;
use crate::zoo::Schema;

/// Batch geometry threaded through the block kernels.
#[derive(Clone, Copy)]
struct Dims {
    b: usize,
    sl: usize,
    n_heads: usize,
}

/// Per-executor scratch arena: every buffer the fused forward pass writes
/// between the token batch and the logits, pre-sized from the schema so the
/// steady-state hot path never touches the allocator. Per-worker buffers
/// (kernel tiles, attention score rows) sit behind uncontended `Mutex`es —
/// each pool worker locks only its own slot.
pub struct Scratch {
    rows: usize,
    d: usize,
    ff: usize,
    sl: usize,
    /// (B*S, d) activations
    x: Vec<f32>,
    /// (B*S, d) RMS-normed activations (attention and MLP inputs)
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// (B*S, d) attention output
    attn: Vec<f32>,
    /// (B*S, d) residual-branch projection (wo / w2 outputs)
    proj: Vec<f32>,
    /// (B*S, d_ff) MLP hidden
    h1: Vec<f32>,
    /// one decode token's K and V (2*d floats: K then V) en route to the cache
    kv_tok: Vec<f32>,
    /// decode attention history readback: seq_len tokens of 2*d floats
    kv_hist: Vec<f32>,
    /// per-worker kernel dequant tiles
    tiles: TilePool,
    /// per-worker attention score rows (seq_len each)
    scores: Vec<Mutex<Vec<f32>>>,
    grow_events: u64,
}

impl Scratch {
    pub fn new(schema: &Schema, pool: &Pool) -> Self {
        let rows = schema.eval_batch * schema.seq_len;
        let (d, ff, sl) = (schema.d_model, schema.d_ff, schema.seq_len);
        Self {
            rows,
            d,
            ff,
            sl,
            x: vec![0.0; rows * d],
            xn: vec![0.0; rows * d],
            q: vec![0.0; rows * d],
            k: vec![0.0; rows * d],
            v: vec![0.0; rows * d],
            attn: vec![0.0; rows * d],
            proj: vec![0.0; rows * d],
            h1: vec![0.0; rows * ff],
            kv_tok: vec![0.0; 2 * d],
            kv_hist: vec![0.0; sl * 2 * d],
            tiles: TilePool::new(pool),
            scores: (0..pool.workers()).map(|_| Mutex::new(vec![0.0; sl])).collect(),
            grow_events: 0,
        }
    }

    /// Allocation-counting test hook: how many times a forward pass found
    /// the arena under-sized and had to regrow it. Zero after construction
    /// and stable across steady-state calls — i.e. `block_forward` performs
    /// no heap allocation once warm.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    /// Regrow for a different geometry (counts as a grow event). The normal
    /// path never hits this: a `ForwardPass` is built from the schema it
    /// serves.
    fn ensure(&mut self, schema: &Schema, pool: &Pool) {
        let rows = schema.eval_batch * schema.seq_len;
        let (d, ff, sl) = (schema.d_model, schema.d_ff, schema.seq_len);
        if rows == self.rows && d == self.d && ff == self.ff && sl == self.sl {
            return;
        }
        let events = self.grow_events + 1;
        *self = Scratch::new(schema, pool);
        self.grow_events = events;
    }
}

/// A reusable fused forward pass: the pool it parallelizes on plus the
/// scratch arena sized for one schema. Shard workers and the native
/// `ModelExecutor` hold one for their replica's lifetime.
pub struct ForwardPass {
    pool: Pool,
    scratch: Scratch,
}

impl ForwardPass {
    pub fn new(schema: &Schema, pool: Pool) -> Self {
        Self { scratch: Scratch::new(schema, &pool), pool }
    }

    /// See `Scratch::grow_events` — the zero-allocation test hook.
    pub fn grow_events(&self) -> u64 {
        self.scratch.grow_events()
    }

    /// Full-sequence forward over the packed weights: `tokens` is a
    /// flattened (B, S) batch; returns logits (B, S, V) flattened. Only the
    /// returned logits vector is allocated; every intermediate lives in the
    /// scratch arena.
    pub fn forward(&mut self, qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
        let s = &qm.schema;
        let (b, sl, d, vocab) = (s.eval_batch, s.seq_len, s.d_model, s.vocab);
        ensure!(tokens.len() == b * sl, "token batch must be ({b},{sl})");
        self.scratch.ensure(s, &self.pool);
        let rows = b * sl;
        let dims = Dims { b, sl, n_heads: s.n_heads };
        let Scratch { x, xn, q, k, v, attn, proj, h1, tiles, scores, .. } = &mut self.scratch;

        // embed + positional: x[r,t] = embed[token] + pos[t]
        for row in 0..b {
            for t in 0..sl {
                let tok = tokens[row * sl + t];
                ensure!(tok >= 0 && (tok as usize) < vocab, "token {tok} outside vocab {vocab}");
                let e = &qm.embed.data[tok as usize * d..(tok as usize + 1) * d];
                let p = &qm.pos.data[t * d..(t + 1) * d];
                let o = &mut x[(row * sl + t) * d..(row * sl + t + 1) * d];
                for j in 0..d {
                    o[j] = e[j] + p[j];
                }
            }
        }

        for blk in &qm.blocks {
            // one payload snapshot per block per pass: a concurrent requant
            // swap can never tear a block mid-kernel (Arc clone, no alloc)
            let mats = blk.mats();
            block_forward(
                x,
                dims,
                &blk.g1.data,
                &blk.g2.data,
                &mats.qmats,
                &self.pool,
                BlockBufs { xn, q, k, v, attn, proj, h1, tiles, scores },
            );
        }

        // head: rms(x, gf) @ head -> (B*S, V)
        rms_into(x, &qm.gf.data, xn);
        let mut logits = vec![0.0f32; rows * vocab];
        matmul_f32(xn, &qm.head.data, rows, d, vocab, &self.pool, &mut logits);
        Ok(logits)
    }

    /// One incremental decode step: run `token` (at position `st.pos()`)
    /// through every block against the K/V history cached for `st`'s
    /// sequence, append the new K/V (through the cache's precision codec),
    /// and write the next-token logits into `logits` (`vocab` floats).
    ///
    /// With a `Raw`-precision cache this is **bit-identical** to the
    /// full-sequence `forward` at the same position: the GEMV kernels
    /// accumulate `k` in ascending order like the GEMM row they replace,
    /// `decode_attention` is the arithmetic-order twin of `attention_into`'s
    /// last row, and the Raw codec round-trips f32 bits exactly. Quantized
    /// KV (Q8/Q4) trades bounded attention noise for cache bytes — the
    /// decode equivalence suite states and asserts the tolerance.
    ///
    /// Steady state does **zero** heap allocation: every intermediate lives
    /// in the scratch arena, the cache history is read back via
    /// `KvCache::read_into`, and appends fill pages `DecodeState::reserve`d
    /// up front. Zero thread spawns, too — the GEMVs reuse the parked pool.
    pub fn decode_step_into(
        &mut self,
        qm: &QuantizedModel,
        token: i32,
        st: &mut DecodeState,
        cache: &mut KvCache,
        logits: &mut [f32],
    ) -> Result<()> {
        let s = &qm.schema;
        let (d, sl, vocab) = (s.d_model, s.seq_len, s.vocab);
        ensure!(logits.len() == vocab, "logits buffer must hold {vocab} floats");
        ensure!(token >= 0 && (token as usize) < vocab, "token {token} outside vocab {vocab}");
        ensure!(
            st.n_blocks == qm.blocks.len(),
            "decode state built for {} blocks, model has {}",
            st.n_blocks,
            qm.blocks.len()
        );
        ensure!(st.pos < sl, "decode position {} beyond the {sl}-token context window", st.pos);
        let g = cache.geometry();
        ensure!(
            g.n_heads == s.n_heads && g.n_heads * g.head_dim == d,
            "kv geometry ({} heads x {}) does not match schema ({} heads, d_model {d})",
            g.n_heads,
            g.head_dim,
            s.n_heads,
        );
        self.scratch.ensure(s, &self.pool);
        let t = st.pos;
        let Scratch { x, xn, q, attn, proj, h1, kv_tok, kv_hist, tiles, scores, .. } =
            &mut self.scratch;
        let x = &mut x[..d];
        let xn = &mut xn[..d];
        let q = &mut q[..d];
        let attn = &mut attn[..d];
        let proj = &mut proj[..d];

        // embed + positional for the one new token
        let e = &qm.embed.data[token as usize * d..(token as usize + 1) * d];
        let p = &qm.pos.data[t * d..(t + 1) * d];
        for j in 0..d {
            x[j] = e[j] + p[j];
        }

        for (bi, blk) in qm.blocks.iter().enumerate() {
            let key = st.key(bi);
            // payload snapshot: the whole step runs on one generation even
            // if a requant swap publishes mid-step (Arc clone, no alloc)
            let mats = blk.mats();
            let ff = mats.qmats[4].cols;
            rms_into(x, &blk.g1.data, xn);
            matvec_qmat(xn, &mats.qmats[0], &self.pool, tiles, q);
            {
                let (ktok, vtok) = kv_tok.split_at_mut(d);
                matvec_qmat(xn, &mats.qmats[1], &self.pool, tiles, ktok);
                matvec_qmat(xn, &mats.qmats[2], &self.pool, tiles, vtok);
            }
            // the new token's K/V go through the cache codec like the rest
            // of the history: quantized-KV noise applies uniformly
            cache.append(key, kv_tok)?;
            let hist = &mut kv_hist[..(t + 1) * 2 * d];
            for (u, slot) in hist.chunks_mut(2 * d).enumerate() {
                cache.read_into(key, u, slot)?;
            }
            {
                let mut sc = scores[0].lock().unwrap();
                decode_attention(q, hist, t + 1, s.n_heads, &mut sc[..t + 1], attn);
            }
            matvec_qmat(attn, &mats.qmats[3], &self.pool, tiles, proj);
            for j in 0..d {
                x[j] += proj[j];
            }
            rms_into(x, &blk.g2.data, xn);
            let h1 = &mut h1[..ff];
            matvec_qmat(xn, &mats.qmats[4], &self.pool, tiles, h1);
            for h in h1.iter_mut() {
                *h = gelu(*h);
            }
            matvec_qmat(h1, &mats.qmats[5], &self.pool, tiles, proj);
            for j in 0..d {
                x[j] += proj[j];
            }
        }

        rms_into(x, &qm.gf.data, xn);
        matvec_f32(xn, &qm.head.data, d, vocab, &self.pool, logits);
        st.pos += 1;
        Ok(())
    }

    /// One **batched** decode step: advance `states.len()` live sequences
    /// by one token each through every block, gathering their activations
    /// into one (M, d) matrix so each weight matrix costs a single
    /// `kernels::matmul_qmat` call per block per step — every packed tile
    /// is unpacked once per *step* instead of once per *sequence* (the
    /// continuous-batching throughput lever; shallow×wide shapes ride the
    /// column-banded GEMM partition from `kernels::gemm_banding`).
    ///
    /// `tokens[i]` is sequence `i`'s next input token, `logits` holds
    /// `states.len() * vocab` floats (row `i` = sequence `i`'s next-token
    /// logits). Sequences may sit at different positions: attention stays
    /// per-sequence, read from each sequence's own KV pages via
    /// `KvCache::read_into`, exactly as `decode_step_into` does.
    ///
    /// **Bit-identity:** every GEMM row is produced independently with the
    /// `k` reduction in ascending order — identical to the GEMV it replaces
    /// (`matvec_qmat` is the one-row `matmul_qmat`, property-tested per
    /// precision) — `rms_into` is row-wise, and `decode_attention` runs on
    /// one sequence's rows only. Gathering M sequences into one step
    /// therefore cannot move a single logit bit relative to M separate
    /// `decode_step_into` calls; the serving layer exploits this as its
    /// batched-vs-per-sequence equivalence oracle.
    ///
    /// Steady state performs **zero** heap allocations and zero thread
    /// spawns: the batched rows live in the same scratch arena the prefill
    /// GEMMs use (`x/xn/q/k/v/attn/proj/h1` hold up to
    /// `eval_batch * seq_len` rows, which bounds the admissible batch), the
    /// new K/V rows are staged through the arena's `kv_tok` buffer into
    /// pages `DecodeState::reserve`d up front, and the history readback
    /// reuses `kv_hist`.
    pub fn decode_step_batched(
        &mut self,
        qm: &QuantizedModel,
        tokens: &[i32],
        states: &mut [DecodeState],
        cache: &mut KvCache,
        logits: &mut [f32],
    ) -> Result<()> {
        let s = &qm.schema;
        let (d, sl, vocab) = (s.d_model, s.seq_len, s.vocab);
        let m = states.len();
        ensure!(m > 0, "batched decode needs at least one sequence");
        ensure!(tokens.len() == m, "got {} tokens for {m} sequences", tokens.len());
        ensure!(
            logits.len() == m * vocab,
            "logits buffer must hold {m} x {vocab} floats, got {}",
            logits.len()
        );
        ensure!(
            m <= s.eval_batch * sl,
            "decode batch {m} exceeds the scratch arena's {} rows",
            s.eval_batch * sl
        );
        let g = cache.geometry();
        ensure!(
            g.n_heads == s.n_heads && g.n_heads * g.head_dim == d,
            "kv geometry ({} heads x {}) does not match schema ({} heads, d_model {d})",
            g.n_heads,
            g.head_dim,
            s.n_heads,
        );
        for (i, st) in states.iter().enumerate() {
            let token = tokens[i];
            ensure!(
                token >= 0 && (token as usize) < vocab,
                "token {token} (row {i}) outside vocab {vocab}"
            );
            ensure!(
                st.n_blocks == qm.blocks.len(),
                "decode state {i} built for {} blocks, model has {}",
                st.n_blocks,
                qm.blocks.len()
            );
            ensure!(
                st.pos < sl,
                "decode position {} (row {i}) beyond the {sl}-token context window",
                st.pos
            );
            // duplicate sequences would interleave appends on the same KV
            // stream and corrupt both cursors — reject up front (M is a
            // handful; the scan is trivial next to one GEMM)
            ensure!(
                states[..i].iter().all(|prev| prev.seq != st.seq),
                "sequence {} appears twice in the decode batch",
                st.seq
            );
        }
        self.scratch.ensure(s, &self.pool);
        let Scratch { x, xn, q, k, v, attn, proj, h1, kv_tok, kv_hist, tiles, scores, .. } =
            &mut self.scratch;
        let x = &mut x[..m * d];
        let xn = &mut xn[..m * d];
        let q = &mut q[..m * d];
        let k = &mut k[..m * d];
        let v = &mut v[..m * d];
        let attn = &mut attn[..m * d];
        let proj = &mut proj[..m * d];

        // embed + positional, one row per sequence at its own position
        for (i, st) in states.iter().enumerate() {
            let tok = tokens[i] as usize;
            let e = &qm.embed.data[tok * d..(tok + 1) * d];
            let p = &qm.pos.data[st.pos * d..(st.pos + 1) * d];
            let o = &mut x[i * d..(i + 1) * d];
            for j in 0..d {
                o[j] = e[j] + p[j];
            }
        }

        for (bi, blk) in qm.blocks.iter().enumerate() {
            // payload snapshot: every sequence in this batched step reads
            // the same generation — a swap landing mid-step cannot split
            // the batch across precisions (Arc clone, no alloc)
            let mats = blk.mats();
            let ff = mats.qmats[4].cols;
            rms_into(x, &blk.g1.data, xn);
            // one fused GEMM per weight matrix for ALL live sequences —
            // each packed tile unpacked once per step
            matmul_qmat(xn, &mats.qmats[0], m, &self.pool, tiles, q);
            matmul_qmat(xn, &mats.qmats[1], m, &self.pool, tiles, k);
            matmul_qmat(xn, &mats.qmats[2], m, &self.pool, tiles, v);
            {
                let mut sc = scores[0].lock().unwrap();
                for (i, st) in states.iter().enumerate() {
                    let key = st.key(bi);
                    let t = st.pos;
                    // stage row i's K/V contiguously (K then V) and push it
                    // through the cache codec like the rest of the history
                    {
                        let (ktok, vtok) = kv_tok.split_at_mut(d);
                        ktok.copy_from_slice(&k[i * d..(i + 1) * d]);
                        vtok.copy_from_slice(&v[i * d..(i + 1) * d]);
                    }
                    cache.append(key, kv_tok)?;
                    let hist = &mut kv_hist[..(t + 1) * 2 * d];
                    for (u, slot) in hist.chunks_mut(2 * d).enumerate() {
                        cache.read_into(key, u, slot)?;
                    }
                    decode_attention(
                        &q[i * d..(i + 1) * d],
                        hist,
                        t + 1,
                        s.n_heads,
                        &mut sc[..t + 1],
                        &mut attn[i * d..(i + 1) * d],
                    );
                }
            }
            matmul_qmat(attn, &mats.qmats[3], m, &self.pool, tiles, proj);
            for (xi, oi) in x.iter_mut().zip(proj.iter()) {
                *xi += *oi;
            }
            rms_into(x, &blk.g2.data, xn);
            let h1 = &mut h1[..m * ff];
            matmul_qmat(xn, &mats.qmats[4], m, &self.pool, tiles, h1);
            for h in h1.iter_mut() {
                *h = gelu(*h);
            }
            matmul_qmat(h1, &mats.qmats[5], m, &self.pool, tiles, proj);
            for (xi, oi) in x.iter_mut().zip(proj.iter()) {
                *xi += *oi;
            }
        }

        rms_into(x, &qm.gf.data, xn);
        matmul_f32(xn, &qm.head.data, m, d, vocab, &self.pool, logits);
        for st in states.iter_mut() {
            st.pos += 1;
        }
        Ok(())
    }

    /// Allocating convenience wrapper over `decode_step_into` (tests,
    /// benches, CLI). The serving hot loop holds a logits buffer and calls
    /// `decode_step_into` directly.
    pub fn decode_step(
        &mut self,
        qm: &QuantizedModel,
        token: i32,
        st: &mut DecodeState,
        cache: &mut KvCache,
    ) -> Result<Vec<f32>> {
        let mut logits = vec![0.0f32; qm.schema.vocab];
        self.decode_step_into(qm, token, st, cache, &mut logits)?;
        Ok(logits)
    }
}

/// Per-sequence incremental decode cursor: which KV-cache sequence this
/// generation appends to and how many positions have been decoded so far.
/// The KV pages themselves live in the owning shard's `serving::KvCache`
/// (each block gets its own K/V stream under a derived key; sequences are
/// pinned to their shard's cache), and the arithmetic scratch is the
/// `ForwardPass`'s arena — shared across all of a shard's sequences.
#[derive(Clone, Debug)]
pub struct DecodeState {
    seq: u64,
    n_blocks: usize,
    pos: usize,
}

impl DecodeState {
    /// Start a fresh sequence `seq` for a model with `n_blocks` blocks.
    /// `seq` ids above `u64::MAX / n_blocks` are rejected by key derivation
    /// in debug builds; serving request ids are nowhere near that.
    pub fn new(seq: u64, n_blocks: usize) -> Self {
        Self { seq, n_blocks, pos: 0 }
    }

    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Positions decoded so far (== the next position to fill).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Cache key of this sequence's K/V stream for block `blk`.
    fn key(&self, blk: usize) -> u64 {
        debug_assert!(blk < self.n_blocks);
        self.seq * self.n_blocks as u64 + blk as u64
    }

    /// Pre-allocate this sequence's KV pages for `tokens` positions across
    /// every block, so steady-state `decode_step` appends never touch the
    /// allocator. On failure some blocks may have been reserved — call
    /// `release` before abandoning the sequence.
    pub fn reserve(&self, cache: &mut KvCache, tokens: usize) -> Result<()> {
        for blk in 0..self.n_blocks {
            cache.reserve(self.key(blk), tokens)?;
        }
        Ok(())
    }

    /// Drop every block's hold on this sequence's KV pages. Pages shared
    /// with other sequences or pinned by the prefix index stay resident;
    /// blocks that never got a table (e.g. after a mid-`reserve` failure)
    /// are skipped, so this is safe on partially-seated sequences.
    pub fn release(&self, cache: &mut KvCache) {
        for blk in 0..self.n_blocks {
            let _ = cache.release(self.key(blk));
        }
    }

    /// Seat this *fresh* sequence on the longest cached prefix of `ctx`
    /// (DESIGN.md §14): every block attaches the same number of context
    /// tokens from the shard cache's prefix index — full shared pages by
    /// refcount, the partially-shared page by copy-on-write — and the
    /// cursor advances past them, so the caller ingests only the unshared
    /// suffix `ctx[state.pos()..]`. At least the last context token is
    /// always left to ingest (it produces the first logits). Returns what
    /// was reused (zero on a cold index); the subsequent `reserve` then
    /// charges the budget only for the remaining window.
    pub fn attach_prefix(&mut self, cache: &mut KvCache, ctx: &[i32]) -> PrefixAttach {
        debug_assert_eq!(self.pos, 0, "attach_prefix requires a fresh sequence");
        let streams: Vec<u64> = (0..self.n_blocks).map(|b| self.key(b)).collect();
        let at = cache.attach_prefix(ctx, &streams, ctx.len().saturating_sub(1));
        self.pos = at.tokens;
        at
    }

    /// Publish this sequence's ingested context into the cache's prefix
    /// index so later same-prefix sequences can `attach_prefix` to it. Call
    /// after `ctx` has been fully ingested (the index holds its own
    /// references, so the published pages outlive this sequence).
    pub fn register_prefix(&self, cache: &mut KvCache, ctx: &[i32]) {
        let streams: Vec<u64> = (0..self.n_blocks).map(|b| self.key(b)).collect();
        cache.register_prefix(&ctx[..ctx.len().min(self.pos)], &streams);
    }

    /// KV bytes this sequence currently pins in `cache` (all blocks).
    pub fn kv_bytes(&self, cache: &KvCache) -> usize {
        (0..self.n_blocks)
            .map(|blk| cache.sequence_bytes(cache.sequence_tokens(self.key(blk))))
            .sum()
    }
}

/// Disjoint reborrows of the scratch arena handed to `block_forward` — the
/// hot loop writes only these, never the allocator.
struct BlockBufs<'a> {
    xn: &'a mut [f32],
    q: &'a mut [f32],
    k: &'a mut [f32],
    v: &'a mut [f32],
    attn: &'a mut [f32],
    proj: &'a mut [f32],
    h1: &'a mut [f32],
    tiles: &'a TilePool,
    scores: &'a [Mutex<Vec<f32>>],
}

/// One pre-RMSNorm decoder block over the (B*S, d) activations, served from
/// packed payloads via the fused kernels:
///   h = x + Attn(rms(x, g1); Wq, Wk, Wv, Wo)
///   y = h + W2 @ gelu(W1 @ rms(h, g2))
fn block_forward(
    x: &mut [f32],
    dims: Dims,
    g1: &[f32],
    g2: &[f32],
    mats: &[QMat],
    pool: &Pool,
    bufs: BlockBufs<'_>,
) {
    let BlockBufs { xn, q, k, v, attn, proj, h1, tiles, scores } = bufs;
    let rows = dims.b * dims.sl;
    let ff = mats[4].cols;

    rms_into(x, g1, xn);
    matmul_qmat(xn, &mats[0], rows, pool, tiles, q);
    matmul_qmat(xn, &mats[1], rows, pool, tiles, k);
    matmul_qmat(xn, &mats[2], rows, pool, tiles, v);
    attention_into(q, k, v, dims, pool, scores, attn);
    matmul_qmat(attn, &mats[3], rows, pool, tiles, proj);
    for (xi, oi) in x.iter_mut().zip(proj.iter()) {
        *xi += *oi;
    }

    rms_into(x, g2, xn);
    let h1 = &mut h1[..rows * ff];
    matmul_qmat(xn, &mats[4], rows, pool, tiles, h1);
    for h in h1.iter_mut() {
        *h = gelu(*h);
    }
    matmul_qmat(h1, &mats[5], rows, pool, tiles, proj);
    for (xi, oi) in x.iter_mut().zip(proj.iter()) {
        *xi += *oi;
    }
}

/// Causal multi-head attention into `out`, parallelized across batch rows
/// (one band per request — rows never mix across the batch dim, which is
/// what makes per-request responses batching-invariant). Each worker uses
/// its own score row from `scores`.
fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dims: Dims,
    pool: &Pool,
    scores: &[Mutex<Vec<f32>>],
    out: &mut [f32],
) {
    let Dims { b, sl, n_heads } = dims;
    let d = q.len() / (b * sl);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    assert!(scores.len() >= pool.workers());
    pool.par_bands_mut(out, sl * d, |wkr, bi, chunk| {
        let mut sc = scores[wkr].lock().unwrap();
        let sc = &mut sc[..sl];
        chunk.fill(0.0);
        for h in 0..n_heads {
            let off = h * hd;
            for t in 0..sl {
                let qrow = &q[(bi * sl + t) * d + off..(bi * sl + t) * d + off + hd];
                let mut m = f32::NEG_INFINITY;
                for u in 0..=t {
                    let krow = &k[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += qrow[j] * krow[j];
                    }
                    sc[u] = dot * scale;
                    if sc[u] > m {
                        m = sc[u];
                    }
                }
                let mut z = 0.0f32;
                for u in 0..=t {
                    sc[u] = (sc[u] - m).exp();
                    z += sc[u];
                }
                let orow = &mut chunk[t * d + off..t * d + off + hd];
                for u in 0..=t {
                    let w = sc[u] / z;
                    let vrow = &v[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    for j in 0..hd {
                        orow[j] += w * vrow[j];
                    }
                }
            }
        }
    });
}

/// Causal attention for one decode position over the cached K/V history.
/// `hist` holds `len` tokens of `2*d` floats each (K then V, as stored by
/// `decode_step_into`); `q` is the new position's query row. This is the
/// arithmetic-order twin of `attention_into` restricted to its last row —
/// same dot order, same max-subtracted softmax, same ascending-`u` output
/// accumulation — which is what makes Raw-KV decode bit-identical to the
/// full-sequence pass.
fn decode_attention(
    q: &[f32],
    hist: &[f32],
    len: usize,
    n_heads: usize,
    sc: &mut [f32],
    out: &mut [f32],
) {
    let d = q.len();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert_eq!(hist.len(), len * 2 * d);
    debug_assert!(sc.len() >= len);
    out.fill(0.0);
    for h in 0..n_heads {
        let off = h * hd;
        let qrow = &q[off..off + hd];
        let mut m = f32::NEG_INFINITY;
        for u in 0..len {
            let krow = &hist[u * 2 * d + off..u * 2 * d + off + hd];
            let mut dot = 0.0f32;
            for j in 0..hd {
                dot += qrow[j] * krow[j];
            }
            sc[u] = dot * scale;
            if sc[u] > m {
                m = sc[u];
            }
        }
        let mut z = 0.0f32;
        for u in 0..len {
            sc[u] = (sc[u] - m).exp();
            z += sc[u];
        }
        let orow = &mut out[off..off + hd];
        for u in 0..len {
            let w = sc[u] / z;
            let vrow = &hist[u * 2 * d + d + off..u * 2 * d + d + off + hd];
            for j in 0..hd {
                orow[j] += w * vrow[j];
            }
        }
    }
}

/// Full-sequence forward, matching `ModelExecutor::forward`: a one-shot
/// serial `ForwardPass`. Callers on a hot path should hold a `ForwardPass`
/// instead so the scratch arena is reused across calls.
pub fn forward(qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
    ForwardPass::new(&qm.schema, Pool::serial()).forward(qm, tokens)
}

// ---- dequantize-then-matmul reference path (tests/benches only) ---------------

/// Dequantize every block's matrices to f32 — the shadow copies the fused
/// path no longer keeps resident. Reference/bench use only.
pub fn dequantize_blocks(qm: &QuantizedModel) -> Vec<Vec<Tensor>> {
    qm.blocks
        .iter()
        .map(|b| {
            let mats = b.mats();
            mats.qmats.iter().map(dequantize).collect()
        })
        .collect()
}

/// Serial dequantized-weights forward over pre-dequantized `mats` (one
/// `Vec<Tensor>` of six per block, from `dequantize_blocks`) — the
/// pre-kernel serving path, kept as the numerical baseline for kernel
/// equivalence tests and the bench's before/after comparison.
pub fn forward_dequant(
    qm: &QuantizedModel,
    tokens: &[i32],
    mats: &[Vec<Tensor>],
) -> Result<Vec<f32>> {
    let s = &qm.schema;
    let (b, sl, d, vocab) = (s.eval_batch, s.seq_len, s.d_model, s.vocab);
    ensure!(tokens.len() == b * sl, "token batch must be ({b},{sl})");
    assert_eq!(mats.len(), qm.blocks.len());

    let rows = b * sl;
    let mut x = vec![0.0f32; rows * d];
    for row in 0..b {
        for t in 0..sl {
            let tok = tokens[row * sl + t];
            ensure!(tok >= 0 && (tok as usize) < vocab, "token {tok} outside vocab {vocab}");
            let e = &qm.embed.data[tok as usize * d..(tok as usize + 1) * d];
            let p = &qm.pos.data[t * d..(t + 1) * d];
            let o = &mut x[(row * sl + t) * d..(row * sl + t + 1) * d];
            for j in 0..d {
                o[j] = e[j] + p[j];
            }
        }
    }

    for (blk, m) in qm.blocks.iter().zip(mats) {
        block_forward_ref(&mut x, b, sl, s.n_heads, &blk.g1.data, &blk.g2.data, m);
    }

    let xn = rms_rows(&x, &qm.gf.data);
    Ok(matmul(&xn, &qm.head.data, rows, d, vocab))
}

/// Reference forward that dequantizes on the fly (tests only): the
/// dequantize-then-matmul path the fused kernels are verified against.
pub fn forward_reference(qm: &QuantizedModel, tokens: &[i32]) -> Result<Vec<f32>> {
    forward_dequant(qm, tokens, &dequantize_blocks(qm))
}

/// One decoder block of the reference path over dequantized f32 weights.
fn block_forward_ref(
    x: &mut [f32],
    b: usize,
    sl: usize,
    n_heads: usize,
    g1: &[f32],
    g2: &[f32],
    mats: &[Tensor],
) {
    let rows = b * sl;
    let d = g1.len();
    let ff = mats[4].dims2().1;

    let xn = rms_rows(x, g1);
    let q = matmul(&xn, &mats[0].data, rows, d, d);
    let k = matmul(&xn, &mats[1].data, rows, d, d);
    let v = matmul(&xn, &mats[2].data, rows, d, d);
    let a = attention(&q, &k, &v, b, sl, d, n_heads);
    let ao = matmul(&a, &mats[3].data, rows, d, d);
    for (xi, oi) in x.iter_mut().zip(&ao) {
        *xi += oi;
    }

    let hn = rms_rows(x, g2);
    let mut h1 = matmul(&hn, &mats[4].data, rows, d, ff);
    for h in h1.iter_mut() {
        *h = gelu(*h);
    }
    let h2 = matmul(&h1, &mats[5].data, rows, ff, d);
    for (xi, oi) in x.iter_mut().zip(&h2) {
        *xi += oi;
    }
}

/// Row-wise RMSNorm with gain into `out`: x * g / sqrt(mean(x^2) + 1e-6).
fn rms_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let d = g.len();
    let rows = x.len() / d;
    for i in 0..rows {
        let r = &x[i * d..(i + 1) * d];
        let mut ss = 0.0f32;
        for &val in r {
            ss += val * val;
        }
        let inv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
        let o = &mut out[i * d..(i + 1) * d];
        for j in 0..d {
            o[j] = r[j] * g[j] * inv;
        }
    }
}

/// Allocating RMSNorm (reference path).
fn rms_rows(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rms_into(x, g, &mut out);
    out
}

/// (m,k) @ (k,n) row-major serial matmul, ikj loop order for stride-1 inner
/// loops (reference path; the fused kernels accumulate in the same order).
fn matmul(a: &[f32], bmat: &[f32], m: usize, kdim: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * kdim);
    debug_assert_eq!(bmat.len(), kdim * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * kdim..(i + 1) * kdim];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &bmat[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Causal multi-head attention (allocating serial reference): softmax of
/// q·k / sqrt(hd) over positions <= t. Deliberately does NOT share code
/// with `attention_into` — this is the independent oracle the fused path's
/// whole-model equivalence tests compare against.
fn attention(q: &[f32], k: &[f32], v: &[f32], b: usize, sl: usize, d: usize, n_heads: usize) -> Vec<f32> {
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; b * sl * d];
    let mut scores = vec![0.0f32; sl];
    for bi in 0..b {
        for h in 0..n_heads {
            let off = h * hd;
            for t in 0..sl {
                let qrow = &q[(bi * sl + t) * d + off..(bi * sl + t) * d + off + hd];
                let mut m = f32::NEG_INFINITY;
                for u in 0..=t {
                    let krow = &k[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    let mut dot = 0.0f32;
                    for j in 0..hd {
                        dot += qrow[j] * krow[j];
                    }
                    scores[u] = dot * scale;
                    if scores[u] > m {
                        m = scores[u];
                    }
                }
                let mut z = 0.0f32;
                for u in 0..=t {
                    scores[u] = (scores[u] - m).exp();
                    z += scores[u];
                }
                let orow = &mut out[(bi * sl + t) * d + off..(bi * sl + t) * d + off + hd];
                for u in 0..=t {
                    let w = scores[u] / z;
                    let vrow = &v[(bi * sl + u) * d + off..(bi * sl + u) * d + off + hd];
                    for j in 0..hd {
                        orow[j] += w * vrow[j];
                    }
                }
            }
        }
    }
    out
}

/// tanh-approximate GELU (`jax.nn.gelu` default).
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Test-only counting allocator: every heap allocation on the current
/// thread bumps a thread-local counter, so tests can assert the fused
/// forward's steady state really is allocation-free (a serial pool runs the
/// whole pass on the calling thread). `try_with` keeps allocation during
/// TLS teardown from aborting the process.
#[cfg(test)]
pub(crate) mod alloc_hook {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    pub struct CountingAlloc;

    fn bump() {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
    }

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            bump();
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    #[global_allocator]
    static COUNTING: CountingAlloc = CountingAlloc;

    /// Allocations observed on the current thread so far.
    pub fn thread_allocs() -> u64 {
        ALLOCS.with(|c| c.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ewq::QuantPlan;
    use crate::model::{ModelExecutor, QuantizedModel};
    use crate::quant::Precision;
    use crate::runtime::Runtime;
    use crate::serving::kvcache::KvGeometry;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
    use crate::zoo::{ModelDir, Schema};

    fn kv_geom(s: &Schema) -> KvGeometry {
        KvGeometry { page_tokens: 4, n_heads: s.n_heads, head_dim: s.d_model / s.n_heads }
    }

    fn tiny_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "tiny".into(),
                n_blocks: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                vocab: 64,
                seq_len: 8,
                eval_batch: 4,
            },
            profile: Profile::UShape,
            seed: 77,
        })
    }

    fn tokens(schema: &Schema) -> Vec<i32> {
        let (b, s) = (schema.eval_batch, schema.seq_len);
        let mut toks = vec![0i32; b * s];
        for row in 0..b {
            for t in 0..4 {
                toks[row * s + t] = ((row * 7 + t * 3) % schema.vocab) as i32;
            }
        }
        toks
    }

    fn mixed_plan(n: usize) -> QuantPlan {
        let mut plan = QuantPlan::uniform("tiny", n, Precision::Q8);
        plan.assignments[0] = Precision::Q4;
        if n > 1 {
            plan.assignments[n - 1] = Precision::T2;
        }
        plan
    }

    #[test]
    fn raw_forward_shapes_and_finiteness() {
        let model = tiny_model();
        let s = &model.schema;
        let plan = QuantPlan::uniform("tiny", s.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let logits = forward(&qm, &tokens(s)).unwrap();
        assert_eq!(logits.len(), s.eval_batch * s.seq_len * s.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        // not degenerate: logits vary across vocab
        let (mn, mx) = logits.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &x| {
            (a.min(x), b.max(x))
        });
        assert!(mx > mn);
    }

    #[test]
    fn forward_is_deterministic() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let a = forward(&qm, &tokens(&model.schema)).unwrap();
        let b = forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fused_matches_dequantized_reference_every_precision_and_worker_count() {
        // the kernel-layer acceptance property at the whole-model level:
        // fused-from-packed == dequantize-then-matmul, for every precision,
        // 1/2/7 workers — bit-identical for f32, <= 1e-5 rel err for packed
        // (in practice also bit-identical; the bound is the contract)
        let model = tiny_model();
        let n = model.schema.n_blocks;
        let toks = tokens(&model.schema);
        let mut plans = vec![mixed_plan(n)];
        for p in [Precision::Raw, Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
            plans.push(QuantPlan::uniform("tiny", n, p));
        }
        for plan in &plans {
            let qm = QuantizedModel::build(&model, plan).unwrap();
            let reference = forward_reference(&qm, &toks).unwrap();
            let raw_plan = plan.assignments.iter().all(|&p| p == Precision::Raw);
            for workers in [1usize, 2, 7] {
                let mut fp = ForwardPass::new(&model.schema, Pool::new(workers));
                let fused = fp.forward(&qm, &toks).unwrap();
                assert_eq!(fused.len(), reference.len());
                for (i, (f, r)) in fused.iter().zip(&reference).enumerate() {
                    if raw_plan {
                        assert_eq!(
                            f.to_bits(),
                            r.to_bits(),
                            "raw plan must be bit-identical: elem {i}, workers={workers}"
                        );
                    } else {
                        let tol = 1e-5 * r.abs().max(1.0);
                        assert!(
                            (f - r).abs() <= tol,
                            "{} elem {i} workers={workers}: fused {f} vs ref {r}",
                            plan.summary()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_forward_is_bit_identical_across_worker_counts() {
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        let serial = ForwardPass::new(&model.schema, Pool::serial()).forward(&qm, &toks).unwrap();
        for workers in [2usize, 3, 7, crate::config::ParallelConfig::test_workers(4)] {
            let pooled =
                ForwardPass::new(&model.schema, Pool::new(workers)).forward(&qm, &toks).unwrap();
            assert_eq!(serial, pooled, "workers={workers}");
        }
    }

    #[test]
    fn forward_bit_identical_under_forced_scalar_kernels() {
        // the EWQ_FORCE_SCALAR toggle end-to-end: a whole-model forward on
        // the pinned scalar kernels reproduces the auto-dispatched one
        // bit-for-bit (the env read is per kernel call, like
        // EWQ_TEST_WORKERS). The env lock serializes the var mutators; a
        // transiently-set var only ever forces other concurrent tests onto
        // the scalar path, which is bit-identical, so nothing else flakes.
        let _guard = crate::simd::env_lock();
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        let auto = ForwardPass::new(&model.schema, Pool::new(3)).forward(&qm, &toks).unwrap();
        let old = std::env::var("EWQ_FORCE_SCALAR").ok();
        std::env::set_var("EWQ_FORCE_SCALAR", "1");
        let scalar = ForwardPass::new(&model.schema, Pool::new(3)).forward(&qm, &toks).unwrap();
        match old {
            Some(v) => std::env::set_var("EWQ_FORCE_SCALAR", v),
            None => std::env::remove_var("EWQ_FORCE_SCALAR"),
        }
        for (i, (a, b)) in auto.iter().zip(&scalar).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: auto {a} vs forced-scalar {b}");
        }
    }

    #[test]
    fn forward_bit_identical_under_every_kernel_path_pin() {
        // EWQ_KERNEL_PATH end-to-end: pinning each path (including avx512
        // on hosts without it, where kernel_path() warns once and falls
        // back) reproduces the auto-dispatched whole-model forward
        // bit-for-bit. Same env-lock discipline as the force-scalar test.
        let _guard = crate::simd::env_lock();
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        let auto = ForwardPass::new(&model.schema, Pool::new(3)).forward(&qm, &toks).unwrap();
        let old = std::env::var("EWQ_KERNEL_PATH").ok();
        for pin in ["scalar", "avx2", "avx512"] {
            std::env::set_var("EWQ_KERNEL_PATH", pin);
            let pinned =
                ForwardPass::new(&model.schema, Pool::new(3)).forward(&qm, &toks).unwrap();
            for (i, (a, b)) in auto.iter().zip(&pinned).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "elem {i} pin={pin}: auto {a} vs {b}");
            }
        }
        match old {
            Some(v) => std::env::set_var("EWQ_KERNEL_PATH", v),
            None => std::env::remove_var("EWQ_KERNEL_PATH"),
        }
    }

    #[test]
    fn steady_state_pooled_forward_performs_zero_thread_spawns() {
        // the persistent-pool acceptance criterion: helpers are spawned on
        // the first pooled forward and only parked/woken by the ~7 kernel
        // scopes per block afterwards — never re-spawned
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        let pool = Pool::new(4);
        assert_eq!(pool.spawn_events(), 0, "no threads before the first forward");
        let mut fp = ForwardPass::new(&model.schema, pool.clone());
        let warm = fp.forward(&qm, &toks).unwrap();
        let spawned = pool.spawn_events();
        assert_eq!(spawned, 3, "workers - 1 helpers, all spawned by the first forward");
        for _ in 0..5 {
            assert_eq!(fp.forward(&qm, &toks).unwrap(), warm);
        }
        assert_eq!(
            pool.spawn_events(),
            spawned,
            "steady-state pooled forwards perform zero thread spawns"
        );
        assert!(pool.wake_events() > 0, "parked helpers are woken per kernel scope");
    }

    #[test]
    fn forward_pass_is_allocation_free_in_steady_state() {
        // the arena hook: steady-state forwards never regrow scratch, i.e.
        // block_forward performs zero heap allocation once warm
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        for workers in [1usize, 3] {
            let mut fp = ForwardPass::new(&model.schema, Pool::new(workers));
            assert_eq!(fp.grow_events(), 0, "pre-sized from schema");
            let a = fp.forward(&qm, &toks).unwrap();
            let warm = fp.grow_events();
            let b = fp.forward(&qm, &toks).unwrap();
            let c = fp.forward(&qm, &toks).unwrap();
            assert_eq!(a, b);
            assert_eq!(b, c);
            assert_eq!(fp.grow_events(), warm, "steady state must not regrow scratch");
            assert_eq!(warm, 0, "schema-sized arena never grows at all");
        }
    }

    #[test]
    fn block_forward_steady_state_does_zero_heap_allocation() {
        // the real allocator-level check behind the grow_events hook: with a
        // serial pool the whole pass runs on this thread, so the counting
        // allocator sees every allocation the hot path would make. The only
        // permitted one is the returned logits vector.
        let model = tiny_model();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let toks = tokens(&model.schema);
        let mut fp = ForwardPass::new(&model.schema, Pool::serial());
        let warm = fp.forward(&qm, &toks).unwrap(); // warm the arena
        let before = super::alloc_hook::thread_allocs();
        let out = fp.forward(&qm, &toks).unwrap();
        let delta = super::alloc_hook::thread_allocs() - before;
        assert_eq!(out, warm);
        assert!(
            delta <= 2,
            "steady-state forward allocated {delta} times (expected only the logits vec)"
        );
    }

    #[test]
    fn raw_kv_decode_is_bit_identical_to_full_forward() {
        // the decode acceptance property at the module level: with a Raw
        // KV cache, token-by-token decode_step reproduces the full-sequence
        // ForwardPass logits bit-for-bit at every position, for mixed and
        // uniform plans and for any worker count (the integration suite
        // re-proves this over random models/precisions)
        let model = tiny_model();
        let s = model.schema.clone();
        let toks = tokens(&s);
        let row0 = &toks[..s.seq_len];
        let plans = [
            mixed_plan(s.n_blocks),
            QuantPlan::uniform("tiny", s.n_blocks, Precision::Raw),
            QuantPlan::uniform("tiny", s.n_blocks, Precision::Q3),
        ];
        for plan in &plans {
            let qm = QuantizedModel::build(&model, plan).unwrap();
            for workers in [1usize, 3, crate::config::ParallelConfig::test_workers(2)] {
                let mut fp = ForwardPass::new(&s, Pool::new(workers));
                let full = fp.forward(&qm, &toks).unwrap();
                let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
                let mut st = DecodeState::new(7, s.n_blocks);
                for (t, &tok) in row0.iter().enumerate() {
                    let logits = fp.decode_step(&qm, tok, &mut st, &mut cache).unwrap();
                    let expect = &full[t * s.vocab..(t + 1) * s.vocab];
                    for (i, (a, b)) in logits.iter().zip(expect).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} t={t} elem {i} workers={workers}: decode {a} vs full {b}",
                            plan.summary()
                        );
                    }
                }
                assert_eq!(st.pos(), s.seq_len);
            }
        }
    }

    #[test]
    fn quantized_kv_decode_within_stated_tolerance() {
        // Quantized KV tolerance, stated rather than hand-waved: the codec
        // rounds each element to within step/2 where step = maxabs/127 (Q8)
        // or maxabs/7 (Q4), i.e. a relative K/V error of at most 0.5/127 ~
        // 3.9e-3 resp. 0.5/7 ~ 7.2e-2 per token. Allowing a growth factor
        // of C = 64 through the 2-block network (attention softmax + two
        // residual MLPs + norms), the logit drift must stay within
        //   C * rel_step * (1 + max|logit_raw_kv|).
        let model = tiny_model();
        let s = model.schema.clone();
        let toks = tokens(&s);
        let row0 = &toks[..s.seq_len];
        let plan = mixed_plan(s.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let decode_all = |kv: Precision| -> Vec<Vec<f32>> {
            let mut fp = ForwardPass::new(&s, Pool::serial());
            let mut cache = KvCache::new(kv_geom(&s), 1 << 24, kv);
            let mut st = DecodeState::new(1, s.n_blocks);
            row0.iter()
                .map(|&tok| fp.decode_step(&qm, tok, &mut st, &mut cache).unwrap())
                .collect()
        };
        let raw = decode_all(Precision::Raw);
        let logit_scale =
            1.0 + raw.iter().flatten().fold(0.0f32, |m, &v| m.max(v.abs()));
        let max_err = |steps: &[Vec<f32>]| -> f32 {
            steps
                .iter()
                .zip(&raw)
                .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y).abs()))
                .fold(0.0f32, f32::max)
        };
        let q8 = decode_all(Precision::Q8);
        let q4 = decode_all(Precision::Q4);
        assert!(q8.iter().flatten().all(|v| v.is_finite()));
        assert!(q4.iter().flatten().all(|v| v.is_finite()));
        let (e8, e4) = (max_err(&q8), max_err(&q4));
        let (tol8, tol4) = (64.0 * 0.5 / 127.0 * logit_scale, 64.0 * 0.5 / 7.0 * logit_scale);
        assert!(e8 <= tol8, "q8 kv drift {e8} > stated tolerance {tol8}");
        assert!(e4 <= tol4, "q4 kv drift {e4} > stated tolerance {tol4}");
        assert!(e8 < e4, "kv precision must order the drift: q8 {e8} !< q4 {e4}");
        assert!(e8 > 0.0, "q8 kv must actually quantize (else the test is vacuous)");
    }

    #[test]
    fn steady_state_decode_step_does_zero_heap_allocation() {
        // the decode-side zero-alloc criterion: with the sequence's pages
        // reserved up front and a caller-held logits buffer, a steady-state
        // decode_step_into performs literally zero allocations (the serial
        // pool runs everything on this thread, so the counting allocator
        // sees every allocation the hot path would make)
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = mixed_plan(s.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut fp = ForwardPass::new(&s, Pool::serial());
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Q8);
        let mut st = DecodeState::new(3, s.n_blocks);
        st.reserve(&mut cache, s.seq_len).unwrap();
        let reserved = cache.allocated_bytes();
        let mut logits = vec![0.0f32; s.vocab];
        fp.decode_step_into(&qm, 1, &mut st, &mut cache, &mut logits).unwrap(); // warm
        let grow = fp.grow_events();
        let before = super::alloc_hook::thread_allocs();
        for tok in [2i32, 3, 4] {
            fp.decode_step_into(&qm, tok, &mut st, &mut cache, &mut logits).unwrap();
        }
        let delta = super::alloc_hook::thread_allocs() - before;
        assert_eq!(delta, 0, "steady-state decode_step allocated {delta} times");
        assert_eq!(fp.grow_events(), grow, "decode must not regrow scratch");
        assert_eq!(grow, 0, "schema-sized arena never grows");
        assert_eq!(cache.allocated_bytes(), reserved, "appends fill reserved pages only");
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn steady_state_decode_performs_zero_thread_spawns() {
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = mixed_plan(s.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let pool = Pool::new(4);
        let mut fp = ForwardPass::new(&s, pool.clone());
        // warm: the full forward spawns the helpers (workers - 1, once)
        let _ = fp.forward(&qm, &tokens(&s)).unwrap();
        let spawned = pool.spawn_events();
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
        let mut st = DecodeState::new(9, s.n_blocks);
        for t in 0..s.seq_len {
            let _ = fp.decode_step(&qm, (t % s.vocab) as i32, &mut st, &mut cache).unwrap();
        }
        assert_eq!(
            pool.spawn_events(),
            spawned,
            "decode steps must never spawn threads — they reuse the parked pool"
        );
    }

    #[test]
    fn decode_step_guards_reject_bad_inputs() {
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = QuantPlan::uniform("tiny", s.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut fp = ForwardPass::new(&s, Pool::serial());
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
        // out-of-vocab tokens
        let mut st = DecodeState::new(1, s.n_blocks);
        assert!(fp.decode_step(&qm, -1, &mut st, &mut cache).is_err());
        assert!(fp.decode_step(&qm, s.vocab as i32, &mut st, &mut cache).is_err());
        assert_eq!(st.pos(), 0, "failed steps must not advance the cursor");
        // a wrong-shaped cache is rejected before any mutation
        let mut bad = KvCache::new(
            KvGeometry { page_tokens: 4, n_heads: s.n_heads, head_dim: 1 },
            1 << 20,
            Precision::Raw,
        );
        assert!(fp.decode_step(&qm, 1, &mut st, &mut bad).is_err());
        // a state built for a different depth is rejected
        let mut wrong = DecodeState::new(2, s.n_blocks + 1);
        assert!(fp.decode_step(&qm, 1, &mut wrong, &mut cache).is_err());
        // the context window is finite: position seq_len must fail cleanly
        for t in 0..s.seq_len {
            fp.decode_step(&qm, (t % 4) as i32, &mut st, &mut cache).unwrap();
        }
        assert!(fp.decode_step(&qm, 1, &mut st, &mut cache).is_err());
        assert_eq!(st.pos(), s.seq_len);
    }

    #[test]
    fn batched_decode_is_bit_identical_to_per_sequence_decode() {
        // the continuous-batching acceptance property at the module level:
        // one fused GEMM over the gathered rows == M separate
        // decode_step_into calls, bit-for-bit, while the batch composition
        // changes under foot — sequence 3 is admitted two steps late and
        // the short streams retire early, so the batch is ragged the whole
        // way down (GEMM rows are independent with k ascending, rms_into is
        // row-wise, and attention reads only the owning sequence's KV
        // pages, so gather + compaction cannot move a bit)
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = mixed_plan(s.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let starts = [0usize, 0, 0, 2];
        let lens = [8usize, 5, 3, 5];
        let streams: Vec<Vec<i32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| (0..len).map(|t| ((i * 11 + t * 5 + 1) % s.vocab) as i32).collect())
            .collect();
        for workers in [1usize, 3] {
            let mut fp = ForwardPass::new(&s, Pool::new(workers));
            // oracle: each sequence alone through the per-sequence GEMV path
            let mut expect: Vec<Vec<Vec<f32>>> = Vec::new();
            {
                let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
                for (i, toks) in streams.iter().enumerate() {
                    let mut st = DecodeState::new(i as u64, s.n_blocks);
                    let mut logits = vec![0.0f32; s.vocab];
                    let mut per_step = Vec::new();
                    for &tok in toks {
                        fp.decode_step_into(&qm, tok, &mut st, &mut cache, &mut logits).unwrap();
                        per_step.push(logits.clone());
                    }
                    st.release(&mut cache);
                    expect.push(per_step);
                }
            }
            // batched: admission/retirement at step boundaries, one fused
            // step per round over whoever is live
            let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
            let mut states: Vec<DecodeState> =
                (0..streams.len()).map(|i| DecodeState::new(i as u64, s.n_blocks)).collect();
            let mut logits = vec![0.0f32; streams.len() * s.vocab];
            let rounds = starts.iter().zip(&lens).map(|(a, b)| a + b).max().unwrap();
            let mut occupancies = Vec::new();
            for round in 0..rounds {
                let live: Vec<usize> = (0..streams.len())
                    .filter(|&i| round >= starts[i] && round < starts[i] + lens[i])
                    .collect();
                let m = live.len();
                assert!(m > 0);
                occupancies.push(m);
                let toks: Vec<i32> = live.iter().map(|&i| streams[i][round - starts[i]]).collect();
                let mut batch: Vec<DecodeState> =
                    live.iter().map(|&i| states[i].clone()).collect();
                fp.decode_step_batched(
                    &qm,
                    &toks,
                    &mut batch,
                    &mut cache,
                    &mut logits[..m * s.vocab],
                )
                .unwrap();
                for (row, &i) in live.iter().enumerate() {
                    let t = round - starts[i];
                    let got = &logits[row * s.vocab..(row + 1) * s.vocab];
                    for (j, (a, b)) in got.iter().zip(&expect[i][t]).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "seq {i} step {t} elem {j} workers={workers}: \
                             batched {a} vs per-seq {b}"
                        );
                    }
                    states[i] = batch[row].clone();
                }
            }
            for (i, &len) in lens.iter().enumerate() {
                assert_eq!(states[i].pos(), len, "seq {i} must land at its stream length");
            }
            // the schedule must actually exercise gather, growth and the
            // ragged tail — otherwise the property above proved nothing
            assert_eq!(occupancies.iter().max(), Some(&4));
            assert_eq!(occupancies.last(), Some(&1));
        }
    }

    #[test]
    fn steady_state_batched_decode_does_zero_heap_allocation() {
        // the batched twin of the decode zero-alloc criterion: with every
        // sequence's pages reserved and a caller-held (M, vocab) logits
        // buffer, a steady-state decode_step_batched allocates nothing —
        // the gathered rows live in the same schema-sized arena prefill
        // uses, staged K/V go through kv_tok, history through kv_hist
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = mixed_plan(s.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut fp = ForwardPass::new(&s, Pool::serial());
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Q8);
        let mut states: Vec<DecodeState> =
            (0..3).map(|i| DecodeState::new(i as u64, s.n_blocks)).collect();
        for st in &states {
            st.reserve(&mut cache, s.seq_len).unwrap();
        }
        let reserved = cache.allocated_bytes();
        let mut logits = vec![0.0f32; states.len() * s.vocab];
        fp.decode_step_batched(&qm, &[1, 2, 3], &mut states, &mut cache, &mut logits).unwrap();
        let grow = fp.grow_events();
        let before = super::alloc_hook::thread_allocs();
        for round in 0..3i32 {
            let toks = [round + 2, round + 3, round + 4];
            fp.decode_step_batched(&qm, &toks, &mut states, &mut cache, &mut logits).unwrap();
        }
        let delta = super::alloc_hook::thread_allocs() - before;
        assert_eq!(delta, 0, "steady-state batched decode allocated {delta} times");
        assert_eq!(fp.grow_events(), grow, "batched decode must not regrow scratch");
        assert_eq!(grow, 0, "schema-sized arena never grows");
        assert_eq!(cache.allocated_bytes(), reserved, "appends fill reserved pages only");
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_decode_guards_reject_bad_inputs() {
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = QuantPlan::uniform("tiny", s.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut fp = ForwardPass::new(&s, Pool::serial());
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Raw);
        let mut states: Vec<DecodeState> =
            (0..2).map(|i| DecodeState::new(i as u64, s.n_blocks)).collect();
        let mut logits = vec![0.0f32; 2 * s.vocab];
        // an empty batch is a caller bug, not a no-op
        assert!(fp.decode_step_batched(&qm, &[], &mut [], &mut cache, &mut []).is_err());
        // token count != batch size
        assert!(fp.decode_step_batched(&qm, &[1], &mut states, &mut cache, &mut logits).is_err());
        // logits sized for one row, batch of two
        assert!(fp
            .decode_step_batched(&qm, &[1, 2], &mut states, &mut cache, &mut logits[..s.vocab])
            .is_err());
        // out-of-vocab token in the second row
        assert!(fp
            .decode_step_batched(&qm, &[1, s.vocab as i32], &mut states, &mut cache, &mut logits)
            .is_err());
        // the same sequence twice would interleave appends on one KV stream
        let mut dup = vec![DecodeState::new(9, s.n_blocks), DecodeState::new(9, s.n_blocks)];
        assert!(fp.decode_step_batched(&qm, &[1, 2], &mut dup, &mut cache, &mut logits).is_err());
        assert!(states.iter().all(|st| st.pos() == 0), "failed steps must not advance cursors");
        // a batch wider than the scratch arena's row capacity is rejected
        let cap = s.eval_batch * s.seq_len;
        let mut wide: Vec<DecodeState> =
            (0..cap + 1).map(|i| DecodeState::new(100 + i as u64, s.n_blocks)).collect();
        let wtoks = vec![1i32; cap + 1];
        let mut wlogits = vec![0.0f32; (cap + 1) * s.vocab];
        assert!(fp.decode_step_batched(&qm, &wtoks, &mut wide, &mut cache, &mut wlogits).is_err());
        // the context window is finite: a row at pos == seq_len fails cleanly
        let mut one = vec![DecodeState::new(50, s.n_blocks)];
        let mut l1 = vec![0.0f32; s.vocab];
        for t in 0..s.seq_len {
            fp.decode_step_batched(&qm, &[(t % 4) as i32], &mut one, &mut cache, &mut l1).unwrap();
        }
        assert!(fp.decode_step_batched(&qm, &[1], &mut one, &mut cache, &mut l1).is_err());
        assert_eq!(one[0].pos(), s.seq_len);
    }

    #[test]
    fn decode_state_tracks_and_releases_kv_bytes() {
        let model = tiny_model();
        let s = model.schema.clone();
        let plan = QuantPlan::uniform("tiny", s.n_blocks, Precision::Q4);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut fp = ForwardPass::new(&s, Pool::serial());
        let mut cache = KvCache::new(kv_geom(&s), 1 << 24, Precision::Q4);
        let mut st = DecodeState::new(5, s.n_blocks);
        assert_eq!(st.kv_bytes(&cache), 0);
        for t in 0..6 {
            fp.decode_step(&qm, (t % s.vocab) as i32, &mut st, &mut cache).unwrap();
            assert_eq!(
                st.kv_bytes(&cache),
                s.n_blocks * cache.sequence_bytes(t + 1),
                "per-block pages sum at t={t}"
            );
        }
        assert_eq!(cache.allocated_bytes(), st.kv_bytes(&cache));
        st.release(&mut cache);
        assert_eq!(cache.allocated_bytes(), 0);
        assert_eq!(st.kv_bytes(&cache), 0);
        assert!(cache.peak_bytes() > 0);
    }

    #[test]
    fn scratch_regrows_once_for_a_new_geometry() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        // a pass sized for a different schema must adapt (and count it)
        let mut other = model.schema.clone();
        other.d_model = 16;
        other.d_ff = 32;
        let mut fp = ForwardPass::new(&other, Pool::serial());
        let l = fp.forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(fp.grow_events(), 1);
        assert_eq!(l, forward(&qm, &tokens(&model.schema)).unwrap());
        // and is steady afterwards
        let _ = fp.forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(fp.grow_events(), 1);
    }

    #[test]
    fn quantization_drift_orders_with_precision() {
        let model = tiny_model();
        let n = model.schema.n_blocks;
        let toks = tokens(&model.schema);
        let run = |p: Precision| {
            let qm = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, p)).unwrap();
            forward(&qm, &toks).unwrap()
        };
        let raw = run(Precision::Raw);
        let max_err = |l: &[f32]| {
            l.iter().zip(&raw).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max)
        };
        let e8 = max_err(&run(Precision::Q8));
        let e4 = max_err(&run(Precision::Q4));
        let e2 = max_err(&run(Precision::T2));
        assert!(e8 < e4, "q8 {e8} !< q4 {e4}");
        assert!(e4 < e2, "q4 {e4} !< t2 {e2}");
    }

    #[test]
    fn q3_and_mixed_plans_execute() {
        let model = tiny_model();
        let n = model.schema.n_blocks;
        let q3 = QuantizedModel::build(&model, &QuantPlan::uniform("m", n, Precision::Q3))
            .unwrap();
        assert!(forward(&q3, &tokens(&model.schema)).unwrap().iter().all(|x| x.is_finite()));
        let mut plan = QuantPlan::uniform("m", n, Precision::Raw);
        plan.assignments[n - 1] = Precision::Q4;
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        assert!(forward(&qm, &tokens(&model.schema)).unwrap().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn out_of_vocab_token_is_rejected() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Raw);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let mut toks = tokens(&model.schema);
        toks[0] = model.schema.vocab as i32; // one past the end
        assert!(forward(&qm, &toks).is_err());
        assert!(forward_reference(&qm, &toks).is_err());
        toks[0] = -1;
        assert!(forward(&qm, &toks).is_err());
    }

    #[test]
    fn executor_dispatches_to_native_for_synthetic_models() {
        // a synthetic ModelDir has no artifacts, so the executor must take
        // the native path in every build configuration
        let model = tiny_model();
        let rt = Runtime::cpu().unwrap();
        let ex = ModelExecutor::new(&rt, &model);
        assert_eq!(ex.backend(), "native-ref");
        ex.warmup().unwrap();
        let plan = QuantPlan::uniform("tiny", model.schema.n_blocks, Precision::Q8);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let via_executor = ex.forward(&qm, &tokens(&model.schema)).unwrap();
        let direct = forward(&qm, &tokens(&model.schema)).unwrap();
        assert_eq!(via_executor, direct);
        let next = ex.next_tokens(&qm, &tokens(&model.schema), 3).unwrap();
        assert_eq!(next.len(), model.schema.eval_batch);
        assert!(next.iter().all(|&t| (0..model.schema.vocab as i32).contains(&t)));
    }

    #[test]
    fn pooled_executor_matches_serial_executor() {
        let model = tiny_model();
        let rt = Runtime::cpu().unwrap();
        let plan = mixed_plan(model.schema.n_blocks);
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let serial = ModelExecutor::new(&rt, &model);
        let pooled = ModelExecutor::with_pool(&rt, &model, Pool::new(4));
        let toks = tokens(&model.schema);
        assert_eq!(
            serial.forward(&qm, &toks).unwrap(),
            pooled.forward(&qm, &toks).unwrap()
        );
    }

    #[test]
    fn rms_normalizes_magnitude() {
        let g = vec![1.0f32; 8];
        let x: Vec<f32> = (0..8).map(|i| (i as f32 + 1.0) * 10.0).collect();
        let out = rms_rows(&x, &g);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 8.0;
        assert!((ms - 1.0).abs() < 1e-3, "mean square {ms}");
    }

    #[test]
    fn matmul_matches_hand_computed() {
        // (2x3) @ (3x2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn attention_is_causal_and_row_normalized() {
        // with q=k=0 scores are uniform over the visible prefix, so the
        // output at position t is the mean of v[0..=t]
        let (b, sl, d, h) = (1usize, 4usize, 8usize, 2usize);
        let q = vec![0.0f32; b * sl * d];
        let k = vec![0.0f32; b * sl * d];
        let mut v = vec![0.0f32; b * sl * d];
        for t in 0..sl {
            for j in 0..d {
                v[t * d + j] = t as f32;
            }
        }
        let out = attention(&q, &k, &v, b, sl, d, h);
        for t in 0..sl {
            let expect = (0..=t).sum::<usize>() as f32 / (t + 1) as f32;
            for j in 0..d {
                assert!((out[t * d + j] - expect).abs() < 1e-5, "t={t} j={j}");
            }
        }
    }

    #[test]
    fn gelu_reference_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        // large |x|: approaches identity / zero
        assert!((gelu(6.0) - 6.0).abs() < 1e-3);
        assert!(gelu(-6.0).abs() < 1e-3);
    }
}
