//! Minimal configuration system: a TOML-subset parser (flat `key = value`
//! pairs under `[section]` headers — the only shapes our configs use) plus
//! typed config structs for the serving coordinator and experiment drivers.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed config: section -> key -> raw value string.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut sections: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
        let mut current = String::new();
        sections.entry(current.clone()).or_default();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
            } else {
                let (k, v) = line
                    .split_once('=')
                    .with_context(|| format!("line {}: expected key = value", ln + 1))?;
                let v = v.trim().trim_matches('"').to_string();
                sections.get_mut(&current).unwrap().insert(k.trim().to_string(), v);
            }
        }
        Ok(Self { sections })
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section).and_then(|s| s.get(key)).map(|s| s.as_str())
    }

    pub fn get_or<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("[{section}] {key} = {v:?}: {e}")),
        }
    }
}

/// Worker-count configuration for the `par` execution layer (entropy
/// reductions, block analysis, quantization, model build, dataset sweep).
/// Analysis results are bit-identical for any worker count — see
/// `par::Pool` — so this is purely a throughput knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Number of worker threads (>= 1; 1 = serial reference path).
    pub workers: usize,
    /// Pin pool helper threads to cores at spawn (best-effort
    /// `sched_setaffinity`; see `par::affinity`). Results are bit-identical
    /// pinned or not — this only buys cache/NUMA locality, so it defaults
    /// off and degrades to a counted no-op where the kernel refuses it.
    pub pin_workers: bool,
}

impl ParallelConfig {
    /// Serial reference configuration.
    pub fn serial() -> Self {
        Self { workers: 1, pin_workers: false }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self { workers, pin_workers: false }
    }

    pub fn with_workers(workers: usize) -> Self {
        Self { workers: workers.max(1), pin_workers: false }
    }

    /// Builder-style toggle for worker pinning.
    pub fn pinned(mut self, pin: bool) -> Self {
        self.pin_workers = pin;
        self
    }

    /// Read `[parallel] workers = N` and `[parallel] pin_workers = bool`
    /// (defaults: `auto`, unpinned).
    pub fn from_config(c: &Config) -> Result<Self> {
        Ok(Self::with_workers(c.get_or("parallel", "workers", Self::auto().workers)?)
            .pinned(c.get_or("parallel", "pin_workers", false)?))
    }

    /// Worker count exercised by the cross-worker determinism tests:
    /// `EWQ_TEST_WORKERS` when set (CI runs a {1, 2, 7} matrix of the whole
    /// suite under it), else `fallback`. Bit-identity claims are thereby
    /// re-proven at several pool sizes on every PR, not just locally.
    pub fn test_workers(fallback: usize) -> usize {
        std::env::var("EWQ_TEST_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .map(|w| w.max(1))
            .unwrap_or(fallback)
    }
}

impl Default for ParallelConfig {
    fn default() -> Self {
        Self::auto()
    }
}

/// How the serving batcher assigns closed batching windows to shard queues.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Cycle shards in order regardless of load (the original protocol).
    RoundRobin,
    /// Send each window to the shard with the fewest queued + in-flight
    /// batches — balances skewed batch costs (mixed-precision plans, cheap
    /// all-reject windows) instead of blindly alternating.
    ShortestQueue,
    /// Blind-rotation placement, but an idle shard steals the deepest peer
    /// queue's oldest window — balance is recovered by the consumers
    /// instead of predicted by the producer (the event-driven default;
    /// see DESIGN.md §9).
    #[default]
    WorkSteal,
}

impl DispatchPolicy {
    pub fn label(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round_robin",
            DispatchPolicy::ShortestQueue => "shortest_queue",
            DispatchPolicy::WorkSteal => "work_steal",
        }
    }

    /// Whether idle shard workers may steal queued windows from live peers
    /// (every policy rescues windows from dead shards regardless).
    pub fn steals(self) -> bool {
        matches!(self, DispatchPolicy::WorkSteal)
    }
}

impl std::str::FromStr for DispatchPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "round_robin" | "rr" => Ok(DispatchPolicy::RoundRobin),
            "shortest_queue" | "sq" => Ok(DispatchPolicy::ShortestQueue),
            "work_steal" | "ws" => Ok(DispatchPolicy::WorkSteal),
            other => {
                bail!("unknown dispatch policy {other:?} (round_robin|shortest_queue|work_steal)")
            }
        }
    }
}

/// One scripted requant swap for the determinism/chaos harnesses: after the
/// owning shard has dequeued `after_item` work items, re-pack block `block`
/// at `prec` before the next item executes. The schedule is global — every
/// shard applies it at its own item ordinals — which is what makes
/// single-shard (or deterministically-dispatched) runs exactly repeatable:
/// the swap lands at the same step boundary every run. Always compiled (no
/// chaos feature gate): the forced-swap equivalence property runs in the
/// default test build.
#[derive(Clone, Debug, PartialEq)]
pub struct ForcedSwap {
    /// Work items the shard must have dequeued before this swap fires.
    pub after_item: usize,
    /// Block index to re-pack.
    pub block: usize,
    /// Target precision rung.
    pub prec: crate::quant::Precision,
}

/// A degenerate `ServeConfig` value caught at coordinator startup — each of
/// these previously failed far from the cause (a clamp hiding the typo, a
/// downstream panic, or a silent hang).
#[derive(Clone, Debug, PartialEq)]
pub enum ServeConfigError {
    /// `max_decode_batch == 0`: would silently clamp to 1, masking a typo
    /// for a knob whose whole point is > 1.
    ZeroMaxDecodeBatch,
    /// `kv_budget_mb <= 0` (or NaN): every generation would shed with
    /// `KvExhausted` — an all-reject server nobody asked for.
    ZeroKvBudget,
    /// `forward_workers == 0`: would silently clamp to 1.
    ZeroForwardWorkers,
    /// Requant enabled with watermarks that can never act: requires
    /// `0 < low < high`.
    RequantWatermarks { low_mb: f64, high_mb: f64 },
}

impl std::fmt::Display for ServeConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeConfigError::ZeroMaxDecodeBatch => {
                write!(f, "max_decode_batch must be >= 1 (0 would clamp silently)")
            }
            ServeConfigError::ZeroKvBudget => {
                write!(f, "kv_budget_mb must be > 0 (0 sheds every generation)")
            }
            ServeConfigError::ZeroForwardWorkers => {
                write!(f, "forward_workers must be >= 1 (0 would clamp silently)")
            }
            ServeConfigError::RequantWatermarks { low_mb, high_mb } => write!(
                f,
                "requant watermarks must satisfy 0 < low < high, got low {low_mb} MB, high {high_mb} MB"
            ),
        }
    }
}

impl std::error::Error for ServeConfigError {}

/// Serving coordinator configuration (examples/serve.rs, `ewq serve`).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub model: String,
    pub max_batch: usize,
    pub max_wait_us: u64,
    pub memory_budget_mb: f64,
    pub n_machines: usize,
    pub requests: usize,
    /// Shard workers: each owns a full model replica and executes batches
    /// the shared batcher dispatches under `dispatch` (1 = the classic
    /// single-worker coordinator).
    pub workers: usize,
    /// How closed batching windows are assigned to shard queues.
    pub dispatch: DispatchPolicy,
    /// Pool workers *inside* each shard's native forward pass (matmul row
    /// bands / attention rows). 1 = serial forward; raise on hosts with
    /// spare cores per shard. Responses are identical either way.
    pub forward_workers: usize,
    /// Pin each shard worker and its forward pool to a disjoint block of
    /// cores (`ewq serve --pin on`): shard `i` owns cores
    /// `i*forward_workers .. (i+1)*forward_workers` (mod the host core
    /// count), the shard thread pins itself to the block's first core and
    /// its pool helpers spread over the rest. Best-effort and
    /// bit-identical either way (DESIGN.md §16); off by default.
    pub pin_workers: bool,
    /// Tokens to generate per request in the demo drivers (`ewq serve
    /// --decode-tokens`, examples): 0/1 = classic single next-token
    /// requests, N > 1 = streaming generation through the per-shard KV
    /// cache (`Coordinator::submit_gen`).
    pub decode_tokens: usize,
    /// Precision of the per-shard KV cache pages (`Raw`, `Q8` or `Q4` —
    /// the codecs `serving::kvcache` implements). Raw decode is
    /// bit-identical to full-sequence recompute; Q8/Q4 trade bounded
    /// attention noise for cache bytes.
    pub kv_precision: crate::quant::Precision,
    /// Per-shard KV cache budget in MB; a generation that would exceed it
    /// is shed cleanly with a terminal `Status::KvExhausted` response.
    pub kv_budget_mb: f64,
    /// Upper bound on the per-shard continuous-batching decode batch: up to
    /// this many live generations advance per step through one fused
    /// `decode_step_batched` GEMM per weight matrix per block. 1 keeps the
    /// per-sequence GEMV path (the batched path's equivalence oracle —
    /// response streams are bit-identical either way).
    pub max_decode_batch: usize,
    /// Bounded admission (DESIGN.md §13): when every live shard's queue
    /// depth (queued + in-flight windows) has reached this cap, new windows
    /// are shed at enqueue with a terminal `Status::Busy` per request
    /// instead of growing the queues without bound. 0 = unbounded.
    pub max_queued_windows: usize,
    /// Cap on concurrently decoding sequences per shard: admission past the
    /// cap is shed with `Status::Busy` before any KV pages are reserved.
    /// 0 = unbounded (the KV byte budget is then the only limit).
    pub max_live_sequences: usize,
    /// Deadline stamped on every submitted request, in milliseconds from
    /// submission (`Coordinator::submit_with_deadline` overrides per
    /// request). Expired windows are dropped at dequeue and expired decode
    /// jobs retire at the next step boundary, each answered with one
    /// terminal `Status::Expired`. 0 = no deadline.
    pub default_deadline_ms: u64,
    /// Whether generation admission consults the shard KV cache's
    /// prefix-hash index (DESIGN.md §14): a hit attaches the sequence to
    /// already-resident shared-prefix pages copy-free and the first decode
    /// turn ingests only the unshared suffix. `false` is the equivalence
    /// oracle that always ingests the full context fresh.
    pub prefix_cache: bool,
    /// Online precision controller (`serving::requant`, DESIGN.md §15):
    /// between decode windows each shard compares its resident weight bytes
    /// + live KV bytes against the watermarks below and moves blocks
    /// Q8↔Q4↔Q3 — demoting under pressure, promoting back when idle below
    /// the low watermark. Off by default: precision then stays exactly what
    /// the plan assigned.
    pub requant: bool,
    /// Requant low watermark, MB: below this (and with an idle queue) the
    /// controller promotes demoted blocks back toward their plan precision.
    pub requant_low_mb: f64,
    /// Requant high watermark, MB: above this the controller demotes the
    /// lowest-entropy eligible block one rung per step boundary.
    pub requant_high_mb: f64,
    /// Optional trained FastEWQ classifier (`.fewq`) restricting which
    /// blocks the controller may touch; `None` = entropy rank order alone.
    pub requant_classifier: Option<std::path::PathBuf>,
    /// Scripted swap schedule for tests/benches (see `ForcedSwap`); applied
    /// even when `requant` is off, so equivalence tests can pin swap timing
    /// without enabling pressure-driven behavior.
    pub requant_forced: Vec<ForcedSwap>,
    /// Deterministic fault-injection schedule for the chaos harness
    /// (`serving::faultfx`); never read outside tests / `--features chaos`.
    #[cfg(any(test, feature = "chaos"))]
    pub chaos: Option<crate::serving::faultfx::ChaosSchedule>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            model: "tl-llama".into(),
            max_batch: 8,
            max_wait_us: 2_000,
            memory_budget_mb: 16.0,
            n_machines: 2,
            requests: 64,
            workers: 1,
            dispatch: DispatchPolicy::default(),
            forward_workers: 1,
            pin_workers: false,
            decode_tokens: 0,
            kv_precision: crate::quant::Precision::Raw,
            kv_budget_mb: 64.0,
            max_decode_batch: 8,
            max_queued_windows: 0,
            max_live_sequences: 0,
            default_deadline_ms: 0,
            prefix_cache: true,
            requant: false,
            requant_low_mb: 48.0,
            requant_high_mb: 64.0,
            requant_classifier: None,
            requant_forced: Vec::new(),
            #[cfg(any(test, feature = "chaos"))]
            chaos: None,
        }
    }
}

impl ServeConfig {
    pub fn from_config(c: &Config) -> Result<Self> {
        let d = Self::default();
        Ok(Self {
            model: c.get("serve", "model").unwrap_or(&d.model).to_string(),
            max_batch: c.get_or("serve", "max_batch", d.max_batch)?,
            max_wait_us: c.get_or("serve", "max_wait_us", d.max_wait_us)?,
            memory_budget_mb: c.get_or("serve", "memory_budget_mb", d.memory_budget_mb)?,
            n_machines: c.get_or("serve", "n_machines", d.n_machines)?,
            requests: c.get_or("serve", "requests", d.requests)?,
            workers: c.get_or("serve", "workers", d.workers)?,
            dispatch: c.get_or("serve", "dispatch", d.dispatch)?,
            forward_workers: c.get_or("serve", "forward_workers", d.forward_workers)?,
            pin_workers: c.get_or("serve", "pin_workers", d.pin_workers)?,
            decode_tokens: c.get_or("serve", "decode_tokens", d.decode_tokens)?,
            kv_precision: c.get_or("serve", "kv_precision", d.kv_precision)?,
            kv_budget_mb: c.get_or("serve", "kv_budget_mb", d.kv_budget_mb)?,
            max_decode_batch: c.get_or("serve", "max_decode_batch", d.max_decode_batch)?,
            max_queued_windows: c.get_or("serve", "max_queued_windows", d.max_queued_windows)?,
            max_live_sequences: c.get_or("serve", "max_live_sequences", d.max_live_sequences)?,
            default_deadline_ms: c.get_or("serve", "default_deadline_ms", d.default_deadline_ms)?,
            prefix_cache: c.get_or("serve", "prefix_cache", d.prefix_cache)?,
            requant: c.get_or("serve", "requant", d.requant)?,
            requant_low_mb: c.get_or("serve", "requant_low_mb", d.requant_low_mb)?,
            requant_high_mb: c.get_or("serve", "requant_high_mb", d.requant_high_mb)?,
            requant_classifier: c
                .get("serve", "requant_classifier")
                .map(std::path::PathBuf::from),
            requant_forced: Vec::new(),
            #[cfg(any(test, feature = "chaos"))]
            chaos: None,
        })
    }

    /// Reject degenerate values at startup with a typed error instead of a
    /// downstream clamp, panic, or hang. `Coordinator::start_with_model`
    /// calls this first; `ewq serve` calls it before loading the model so
    /// the CLI fails fast too.
    pub fn validate(&self) -> std::result::Result<(), ServeConfigError> {
        if self.max_decode_batch == 0 {
            return Err(ServeConfigError::ZeroMaxDecodeBatch);
        }
        // `!(x > 0.0)` also catches NaN, which `x <= 0.0` would let through
        if !(self.kv_budget_mb > 0.0) {
            return Err(ServeConfigError::ZeroKvBudget);
        }
        if self.forward_workers == 0 {
            return Err(ServeConfigError::ZeroForwardWorkers);
        }
        if self.requant && !(self.requant_low_mb > 0.0 && self.requant_high_mb > self.requant_low_mb)
        {
            return Err(ServeConfigError::RequantWatermarks {
                low_mb: self.requant_low_mb,
                high_mb: self.requant_high_mb,
            });
        }
        Ok(())
    }
}

/// Hand-rolled CLI argument splitter: `--key value` / `--flag` pairs after
/// positional arguments (clap is unavailable offline).
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short options not supported: {a}");
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn opt<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(
            "top = 1\n[serve]\nmodel = \"tl-qwen\" # inline comment\nmax_batch = 4\n\n[bench]\nn = 10\n",
        )
        .unwrap();
        assert_eq!(c.get("", "top"), Some("1"));
        assert_eq!(c.get("serve", "model"), Some("tl-qwen"));
        assert_eq!(c.get_or("serve", "max_batch", 0usize).unwrap(), 4);
        assert_eq!(c.get_or("serve", "missing", 7usize).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("no equals here").is_err());
    }

    #[test]
    fn serve_config_from_config() {
        let c = Config::parse("[serve]\nmodel = tl-phi\nrequests = 16\nworkers = 4\n").unwrap();
        let s = ServeConfig::from_config(&c).unwrap();
        assert_eq!(s.model, "tl-phi");
        assert_eq!(s.requests, 16);
        assert_eq!(s.workers, 4);
        assert_eq!(s.max_batch, ServeConfig::default().max_batch);
        assert_eq!(s.dispatch, DispatchPolicy::WorkSteal, "default policy");
        assert_eq!(s.forward_workers, 1);
        assert_eq!(s.max_queued_windows, 0, "unbounded admission by default");
        assert_eq!(s.max_live_sequences, 0);
        assert_eq!(s.default_deadline_ms, 0, "no deadline by default");
        assert!(s.prefix_cache, "prefix caching is on by default");
        assert!(!s.pin_workers, "pinning is opt-in");
    }

    #[test]
    fn pin_workers_serve_option_parses() {
        let c = Config::parse("[serve]\npin_workers = true\nforward_workers = 2\n").unwrap();
        let s = ServeConfig::from_config(&c).unwrap();
        assert!(s.pin_workers);
        assert_eq!(s.forward_workers, 2);
        assert!(!ServeConfig::default().pin_workers, "off by default");
    }

    #[test]
    fn dispatch_policy_parses_and_labels() {
        let c = Config::parse("[serve]\ndispatch = round_robin\nforward_workers = 3\n").unwrap();
        let s = ServeConfig::from_config(&c).unwrap();
        assert_eq!(s.dispatch, DispatchPolicy::RoundRobin);
        assert_eq!(s.forward_workers, 3);
        assert_eq!("sq".parse::<DispatchPolicy>().unwrap(), DispatchPolicy::ShortestQueue);
        assert_eq!("rr".parse::<DispatchPolicy>().unwrap(), DispatchPolicy::RoundRobin);
        assert_eq!("ws".parse::<DispatchPolicy>().unwrap(), DispatchPolicy::WorkSteal);
        assert_eq!("work_steal".parse::<DispatchPolicy>().unwrap(), DispatchPolicy::WorkSteal);
        assert!("lifo".parse::<DispatchPolicy>().is_err());
        assert_eq!(DispatchPolicy::ShortestQueue.label(), "shortest_queue");
        assert_eq!(DispatchPolicy::RoundRobin.label(), "round_robin");
        assert_eq!(DispatchPolicy::WorkSteal.label(), "work_steal");
        assert!(DispatchPolicy::WorkSteal.steals());
        assert!(!DispatchPolicy::ShortestQueue.steals());
        assert!(!DispatchPolicy::RoundRobin.steals());
        let bad = Config::parse("[serve]\ndispatch = nope\n").unwrap();
        assert!(ServeConfig::from_config(&bad).is_err());
    }

    #[test]
    fn kv_and_decode_serve_options_parse() {
        use crate::quant::Precision;
        let c = Config::parse(
            "[serve]\ndecode_tokens = 6\nkv_precision = 4bit\nkv_budget_mb = 8.5\n\
             max_decode_batch = 16\nmax_queued_windows = 4\nmax_live_sequences = 2\n\
             default_deadline_ms = 250\nprefix_cache = false\n",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c).unwrap();
        assert_eq!(s.decode_tokens, 6);
        assert_eq!(s.kv_precision, Precision::Q4);
        assert!((s.kv_budget_mb - 8.5).abs() < 1e-12);
        assert_eq!(s.max_decode_batch, 16);
        assert_eq!(s.max_queued_windows, 4);
        assert_eq!(s.max_live_sequences, 2);
        assert_eq!(s.default_deadline_ms, 250);
        assert!(!s.prefix_cache);
        let d = ServeConfig::default();
        assert_eq!(d.decode_tokens, 0, "classic next-token serving by default");
        assert_eq!(d.kv_precision, Precision::Raw);
        assert!(d.kv_budget_mb > 0.0);
        assert!(d.max_decode_batch > 1, "continuous batching is on by default");
        assert_eq!("q8".parse::<Precision>().unwrap(), Precision::Q8);
        assert_eq!("raw".parse::<Precision>().unwrap(), Precision::Raw);
        assert_eq!("1.58bit".parse::<Precision>().unwrap(), Precision::T2);
        assert!("5bit".parse::<Precision>().is_err());
        let bad = Config::parse("[serve]\nkv_precision = 5bit\n").unwrap();
        assert!(ServeConfig::from_config(&bad).is_err());
    }

    #[test]
    fn requant_serve_options_parse() {
        let c = Config::parse(
            "[serve]\nrequant = true\nrequant_low_mb = 12.5\nrequant_high_mb = 20.0\n\
             requant_classifier = \"artifacts/fastewq.fewq\"\n",
        )
        .unwrap();
        let s = ServeConfig::from_config(&c).unwrap();
        assert!(s.requant);
        assert!((s.requant_low_mb - 12.5).abs() < 1e-12);
        assert!((s.requant_high_mb - 20.0).abs() < 1e-12);
        assert_eq!(
            s.requant_classifier.as_deref(),
            Some(std::path::Path::new("artifacts/fastewq.fewq"))
        );
        let d = ServeConfig::default();
        assert!(!d.requant, "requant is off by default");
        assert!(d.requant_low_mb > 0.0 && d.requant_high_mb > d.requant_low_mb);
        assert!(d.requant_classifier.is_none());
        assert!(d.requant_forced.is_empty());
    }

    #[test]
    fn validate_rejects_each_degenerate_value_with_a_typed_error() {
        assert_eq!(ServeConfig::default().validate(), Ok(()));

        let cfg = ServeConfig { max_decode_batch: 0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ServeConfigError::ZeroMaxDecodeBatch));

        let cfg = ServeConfig { kv_budget_mb: 0.0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ServeConfigError::ZeroKvBudget));
        let cfg = ServeConfig { kv_budget_mb: -1.0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ServeConfigError::ZeroKvBudget));
        let cfg = ServeConfig { kv_budget_mb: f64::NAN, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ServeConfigError::ZeroKvBudget));

        let cfg = ServeConfig { forward_workers: 0, ..Default::default() };
        assert_eq!(cfg.validate(), Err(ServeConfigError::ZeroForwardWorkers));

        // requant watermarks only checked when requant is on
        let cfg = ServeConfig {
            requant: true,
            requant_low_mb: 8.0,
            requant_high_mb: 8.0,
            ..Default::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(ServeConfigError::RequantWatermarks { low_mb: 8.0, high_mb: 8.0 })
        );
        let cfg = ServeConfig {
            requant: false,
            requant_low_mb: 8.0,
            requant_high_mb: 8.0,
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Ok(()), "watermarks ignored when requant is off");
        let cfg = ServeConfig {
            requant: true,
            requant_low_mb: 0.0,
            requant_high_mb: 9.0,
            ..Default::default()
        };
        assert!(matches!(cfg.validate(), Err(ServeConfigError::RequantWatermarks { .. })));

        // errors render the cause, not a downstream symptom
        let msg = ServeConfigError::ZeroKvBudget.to_string();
        assert!(msg.contains("kv_budget_mb"), "{msg}");
    }

    #[test]
    fn test_workers_env_or_fallback() {
        // the CI determinism matrix sets EWQ_TEST_WORKERS for the whole
        // suite, so this test must accept either world
        match std::env::var("EWQ_TEST_WORKERS") {
            Ok(v) => {
                let expect = v.parse::<usize>().map(|w| w.max(1)).unwrap_or(3);
                assert_eq!(ParallelConfig::test_workers(3), expect);
            }
            Err(_) => {
                assert_eq!(ParallelConfig::test_workers(3), 3);
                assert_eq!(ParallelConfig::test_workers(0), 0, "fallback passes through");
            }
        }
    }

    #[test]
    fn parallel_config_defaults_and_parse() {
        assert_eq!(ParallelConfig::serial().workers, 1);
        assert!(ParallelConfig::auto().workers >= 1);
        assert_eq!(ParallelConfig::with_workers(0).workers, 1);
        assert!(!ParallelConfig::serial().pin_workers, "pinning defaults off");
        assert!(!ParallelConfig::auto().pin_workers);
        assert!(ParallelConfig::with_workers(2).pinned(true).pin_workers);
        let c = Config::parse("[parallel]\nworkers = 6\npin_workers = true\n").unwrap();
        let p = ParallelConfig::from_config(&c).unwrap();
        assert_eq!(p.workers, 6);
        assert!(p.pin_workers);
        let empty = Config::parse("").unwrap();
        let p = ParallelConfig::from_config(&empty).unwrap();
        assert_eq!(p.workers, ParallelConfig::auto().workers);
        assert!(!p.pin_workers);
    }

    #[test]
    fn args_parse_positional_options_flags() {
        let argv: Vec<String> =
            ["exp", "table6", "--model", "tl-llama", "--quick", "--n", "5"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv).unwrap();
        assert_eq!(a.positional, vec!["exp", "table6"]);
        assert_eq!(a.options.get("model").map(|s| s.as_str()), Some("tl-llama"));
        assert_eq!(a.opt("n", 0usize).unwrap(), 5);
        assert!(a.has_flag("quick"));
        assert!(!a.has_flag("model"));
    }

    #[test]
    fn bad_type_errors() {
        let a = Args::parse(&["--n".to_string(), "abc".to_string()]).unwrap();
        assert!(a.opt("n", 0usize).is_err());
    }
}
