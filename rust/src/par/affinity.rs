//! Dependency-free core-affinity shim for worker pinning (DESIGN.md §16).
//!
//! The crate links no libc crate, so `sched_setaffinity`/`sched_getaffinity`
//! are issued as raw syscalls on Linux (x86_64 and aarch64); every other
//! target gets a no-op that reports "pinning unsupported". Pinning is
//! always **best-effort**: a container seccomp policy or cpuset may refuse
//! the syscall, and callers (the `Pool` spawn path, the serving shards)
//! must treat a failed pin as a logged no-op, never an error — the kernels
//! are bit-identical wherever the thread lands, pinning only buys locality.
//!
//! All calls target the *calling thread* (`pid == 0`), which is how the
//! pool uses them: each helper pins itself first thing inside its spawn
//! closure, so the affinity is set before the thread touches its
//! first-touch `TilePool` scratch (NUMA first-touch placement).

/// Width of the CPU mask handed to the kernel: 16 × 64 = 1024 CPUs, the
/// conventional `CPU_SETSIZE`. Cores beyond that are rejected up front.
const MASK_WORDS: usize = 16;

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod sys {
    pub const SET_AFFINITY: usize = 203;
    pub const GET_AFFINITY: usize = 204;

    /// SAFETY: caller passes a mask of at least `len` valid bytes; the
    /// kernel only reads (set) or writes (get) within that window.
    pub unsafe fn sched_affinity(nr: usize, len: usize, mask: *mut u64) -> isize {
        let mut ret = nr as isize;
        std::arch::asm!(
            "syscall",
            inout("rax") ret,
            in("rdi") 0usize, // pid 0 = calling thread
            in("rsi") len,
            in("rdx") mask,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        ret
    }
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
mod sys {
    pub const SET_AFFINITY: usize = 122;
    pub const GET_AFFINITY: usize = 123;

    /// SAFETY: caller passes a mask of at least `len` valid bytes; the
    /// kernel only reads (set) or writes (get) within that window.
    pub unsafe fn sched_affinity(nr: usize, len: usize, mask: *mut u64) -> isize {
        let mut ret = 0isize; // pid 0 = calling thread
        std::arch::asm!(
            "svc 0",
            inout("x0") ret,
            in("x1") len,
            in("x2") mask,
            in("x8") nr,
            options(nostack),
        );
        ret
    }
}

/// Pin the calling thread to a single core. Returns `true` only when the
/// kernel accepted the new mask; `false` for out-of-range cores, refused
/// syscalls (seccomp, cpuset exclusion), and unsupported targets.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_to_core(core: usize) -> bool {
    if core >= MASK_WORDS * 64 {
        return false;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[core / 64] = 1u64 << (core % 64);
    // SAFETY: the mask is a valid MASK_WORDS*8-byte buffer on our stack.
    let ret = unsafe {
        sys::sched_affinity(sys::SET_AFFINITY, MASK_WORDS * 8, mask.as_mut_ptr())
    };
    ret == 0
}

/// No-op fallback: pinning is unsupported off Linux/x86_64/aarch64.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

/// The set of cores the calling thread may currently run on, ascending.
/// `None` when the syscall is unavailable or refused — callers use that as
/// the "skip the pinning assertion" signal in tests.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn current_affinity() -> Option<Vec<usize>> {
    let mut mask = [0u64; MASK_WORDS];
    // SAFETY: the mask is a valid MASK_WORDS*8-byte buffer on our stack.
    let ret = unsafe {
        sys::sched_affinity(sys::GET_AFFINITY, MASK_WORDS * 8, mask.as_mut_ptr())
    };
    // success returns the size in bytes of the kernel's cpumask copied out
    if ret <= 0 {
        return None;
    }
    let words = ((ret as usize) / 8).min(MASK_WORDS);
    let mut cores = Vec::new();
    for (w, &bits) in mask.iter().enumerate().take(words.max(1)) {
        for b in 0..64 {
            if bits & (1u64 << b) != 0 {
                cores.push(w * 64 + b);
            }
        }
    }
    Some(cores)
}

/// No-op fallback: affinity is unreadable off Linux/x86_64/aarch64.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn current_affinity() -> Option<Vec<usize>> {
    None
}

/// Core count the pinning layout should wrap around — `available_parallelism`
/// with a floor of 1 (it errors on some sandboxes).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_range_core_is_rejected() {
        assert!(!pin_to_core(MASK_WORDS * 64));
        assert!(!pin_to_core(usize::MAX));
    }

    #[test]
    fn available_cores_is_at_least_one() {
        assert!(available_cores() >= 1);
    }

    #[test]
    fn pin_round_trips_through_getaffinity() {
        // skip-tolerant: on non-Linux targets or under a seccomp policy
        // that refuses sched_getaffinity there is nothing to assert
        let Some(allowed) = current_affinity() else { return };
        assert!(!allowed.is_empty(), "a running thread is allowed somewhere");
        let target = allowed[0];
        if !pin_to_core(target) {
            return; // sandbox refused sched_setaffinity — best-effort
        }
        let now = current_affinity().expect("getaffinity worked a moment ago");
        assert_eq!(now, vec![target], "pin narrows the mask to exactly one core");
        // no restore needed: affinity is per-thread and this test thread
        // ends with the test
    }
}
