//! Dependency-free scoped worker pool — the parallel-execution seam every
//! block-level hot path runs on (entropy reductions, per-block analysis,
//! quantization row groups, `QuantizedModel::build`, the FastEWQ dataset
//! sweep, and the sharded serving coordinator's replicas).
//!
//! Design rules (see DESIGN.md §"par layer"):
//! - **Scoped**: all parallelism is `std::thread::scope`-based; no detached
//!   threads, no global executor, nothing outlives the call.
//! - **Deterministic**: `par_map_*` returns results in input order, and
//!   `par_chunk_fold` fixes both the chunk layout (a function of data length
//!   only) and the fold order (chunk index order) — so every result is
//!   bit-identical for any worker count, including 1.
//! - **Work-stealing by atomic counter**: tasks are claimed with a single
//!   `fetch_add`, which balances uneven block sizes without a scheduler.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;

use crate::config::ParallelConfig;

/// A sized handle describing how much parallelism to use. Creating a `Pool`
/// is free — threads are spawned per call and joined before returning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Single-worker pool: every `par_*` call degrades to a plain loop on the
    /// calling thread (the serial reference path).
    pub fn serial() -> Self {
        Self::new(1)
    }

    pub fn from_config(cfg: &ParallelConfig) -> Self {
        Self::new(cfg.workers)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(worker_index)` once per worker, concurrently, and wait for all
    /// of them. With one worker, runs inline on the calling thread.
    pub fn scope<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.workers <= 1 {
            f(0);
            return;
        }
        std::thread::scope(|s| {
            for w in 0..self.workers {
                let f = &f;
                s.spawn(move || f(w));
            }
        });
    }

    /// Map `f` over `0..n`, returning results in index order. Tasks are
    /// claimed dynamically (atomic counter), so uneven task costs balance
    /// across workers. Panics in `f` propagate to the caller.
    pub fn par_map_range<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.workers <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let (tx, rx) = channel::<(usize, R)>();
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(self.workers.min(n));
            for _ in 0..self.workers.min(n) {
                let tx = tx.clone();
                let next = &next;
                let f = &f;
                handles.push(s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i);
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                }));
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (i, r) in rx.iter() {
                out[i] = Some(r);
            }
            // join before unwrapping so a worker panic surfaces as itself,
            // not as a missing-result panic here
            for h in handles {
                if let Err(p) = h.join() {
                    std::panic::resume_unwind(p);
                }
            }
            out.into_iter().map(|o| o.expect("worker produced every index")).collect()
        })
    }

    /// Map `f(index, &item)` over a slice, results in input order.
    pub fn par_map_indexed<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.par_map_range(items.len(), |i| f(i, &items[i]))
    }

    /// Split `data` into contiguous bands of `band` elements (the last may
    /// be shorter) and run `f(worker, band_index, band)` over them in
    /// parallel. Bands are claimed dynamically off a shared iterator, each
    /// band is visited exactly once, and writes are confined to the band —
    /// so for any pure-per-band `f` the result is identical for every
    /// worker count. The worker index (`< self.workers()`) lets callers
    /// reuse per-worker scratch buffers without sharing; this is the
    /// in-place primitive the fused GEMM kernels row-band on.
    pub fn par_bands_mut<T, F>(&self, data: &mut [T], band: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let band = band.max(1);
        if self.workers <= 1 || data.len() <= band {
            for (i, c) in data.chunks_mut(band).enumerate() {
                f(0, i, c);
            }
            return;
        }
        let bands = std::sync::Mutex::new(data.chunks_mut(band).enumerate());
        std::thread::scope(|s| {
            for w in 0..self.workers {
                let bands = &bands;
                let f = &f;
                s.spawn(move || loop {
                    // claim under the lock (dropped at end of statement),
                    // run outside it
                    let next = bands.lock().unwrap().next();
                    let Some((i, c)) = next else { break };
                    f(w, i, c);
                });
            }
        });
    }

    /// Deterministic chunked map-reduce over a slice: split `data` into
    /// fixed-size chunks (layout depends only on `data.len()` and `chunk`),
    /// map chunks in parallel, then fold the partials IN CHUNK ORDER on the
    /// calling thread. Identical bits for any worker count.
    pub fn par_chunk_fold<T, A, M, F>(&self, data: &[T], chunk: usize, map: M, init: A, fold: F) -> A
    where
        T: Sync,
        A: Send,
        M: Fn(&[T]) -> A + Sync,
        F: FnMut(A, A) -> A,
    {
        let chunks: Vec<&[T]> = data.chunks(chunk.max(1)).collect();
        let partials = self.par_map_indexed(&chunks, |_, c| map(c));
        partials.into_iter().fold(init, fold)
    }
}

impl Default for Pool {
    fn default() -> Self {
        Self::from_config(&ParallelConfig::default())
    }
}

/// Convenience free function: map over a slice with `cfg.workers` workers.
pub fn par_map_indexed<T, R, F>(cfg: &ParallelConfig, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    Pool::from_config(cfg).par_map_indexed(items, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_range_matches_serial_in_order() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8] {
            let par = Pool::new(workers).par_map_range(100, |i| i * i);
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn map_indexed_passes_items() {
        let items: Vec<i64> = (0..57).map(|i| i - 20).collect();
        let out = Pool::new(4).par_map_indexed(&items, |i, &x| (i as i64) + x);
        let expect: Vec<i64> = (0..57).map(|i| 2 * i - 20).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let out: Vec<u32> = Pool::new(4).par_map_range(0, |_| unreachable!());
        assert!(out.is_empty());
        assert_eq!(Pool::new(4).par_map_range(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn scope_runs_every_worker() {
        let count = AtomicUsize::new(0);
        Pool::new(5).scope(|_w| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
        let count = AtomicUsize::new(0);
        Pool::serial().scope(|w| {
            assert_eq!(w, 0);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn chunk_fold_is_bit_stable_across_worker_counts() {
        // f64 summation depends on order — the fixed chunk layout + ordered
        // fold must give identical bits for every worker count.
        let data: Vec<f64> = (0..100_000).map(|i| ((i * 2654435761_usize) as f64).sqrt()).collect();
        let sum = |pool: &Pool| {
            pool.par_chunk_fold(&data, 1 << 10, |c| c.iter().sum::<f64>(), 0.0, |a, b| a + b)
        };
        let s1 = sum(&Pool::serial());
        for workers in [2, 3, 4, 7] {
            let sp = sum(&Pool::new(workers));
            assert_eq!(s1.to_bits(), sp.to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn chunk_fold_handles_tiny_inputs() {
        let data = [1.5f64, 2.5];
        let s = Pool::new(8).par_chunk_fold(&data, 1024, |c| c.iter().sum::<f64>(), 0.0, |a, b| {
            a + b
        });
        assert_eq!(s, 4.0);
        let empty: [f64; 0] = [];
        let s = Pool::new(2).par_chunk_fold(&empty, 16, |c| c.iter().sum::<f64>(), 0.0, |a, b| {
            a + b
        });
        assert_eq!(s, 0.0);
    }

    #[test]
    fn uneven_task_costs_balance() {
        // tasks with wildly different costs must still land in order
        let out = Pool::new(4).par_map_range(40, |i| {
            let mut acc = 0u64;
            for k in 0..(i % 7) * 10_000 {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        for (i, item) in out.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }

    #[test]
    fn bands_mut_visits_every_band_exactly_once() {
        let mut data = vec![0u64; 1003];
        for workers in [1usize, 2, 5, 8] {
            data.iter_mut().for_each(|x| *x = 0);
            Pool::new(workers).par_bands_mut(&mut data, 64, |_w, i, band| {
                for x in band.iter_mut() {
                    *x += (i + 1) as u64;
                }
            });
            for (j, &x) in data.iter().enumerate() {
                assert_eq!(x, (j / 64 + 1) as u64, "workers={workers} j={j}");
            }
        }
    }

    #[test]
    fn bands_mut_worker_indices_in_range() {
        let mut data = vec![0u8; 500];
        let seen = AtomicUsize::new(0);
        let pool = Pool::new(3);
        pool.par_bands_mut(&mut data, 10, |w, _i, _band| {
            assert!(w < 3);
            seen.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(seen.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn bands_mut_handles_empty_and_oversized_band() {
        let mut empty: [u32; 0] = [];
        Pool::new(4).par_bands_mut(&mut empty, 8, |_, _, _| unreachable!());
        let mut tiny = [1u32, 2, 3];
        Pool::new(4).par_bands_mut(&mut tiny, 100, |w, i, band| {
            assert_eq!((w, i), (0, 0));
            band.iter_mut().for_each(|x| *x *= 2);
        });
        assert_eq!(tiny, [2, 4, 6]);
    }

    #[test]
    fn free_function_uses_config_workers() {
        let cfg = ParallelConfig::with_workers(3);
        let out = par_map_indexed(&cfg, &[10, 20, 30], |i, &x| x + i as i32);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        assert_eq!(Pool::new(0).workers(), 1);
        assert_eq!(Pool::from_config(&ParallelConfig::with_workers(0)).workers(), 1);
    }
}
