//! Dependency-free persistent worker pool — the parallel-execution seam
//! every block-level hot path runs on (entropy reductions, per-block
//! analysis, quantization row groups, `QuantizedModel::build`, the FastEWQ
//! dataset sweep, the fused-GEMM kernels, and the sharded serving
//! coordinator's replicas).
//!
//! Design rules (see DESIGN.md §"par layer" and §9):
//! - **Spawn once, park between scopes**: helper threads are spawned lazily
//!   on the first multi-worker scope and then live for the pool's lifetime,
//!   parked on a condvar between scopes. A steady-state caller (e.g. the
//!   ~7 kernel invocations per `block_forward`) pays a publish + wake, never
//!   a thread spawn/join — `spawn_events()` is the test hook that proves it.
//! - **Epoch/seqlock wake protocol**: publishing a scope stores the job and
//!   bumps an epoch under the state mutex; each parked helper compares the
//!   epoch against the last one it ran and executes every scope exactly
//!   once. The caller doubles as worker 0 and blocks until the helper
//!   completion count drains, so scope bodies may freely borrow the
//!   caller's stack.
//! - **Deterministic**: `par_map_*` returns results in input order, and
//!   `par_chunk_fold` fixes both the chunk layout (a function of data length
//!   only) and the fold order (chunk index order) — so every result is
//!   bit-identical for any worker count, including 1.
//! - **Work-stealing by atomic counter**: tasks are claimed with a single
//!   `fetch_add`, which balances uneven block sizes without a scheduler.
//! - **Re-entrant by degradation**: a scope started while another scope of
//!   the same pool is in flight (including from inside a scope body) runs
//!   inline on the calling thread instead of deadlocking on the helpers.
//! - **Optional core pinning**: `Pool::new_pinned` gives the pool a core
//!   list; each helper pins itself (best-effort `sched_setaffinity`, see
//!   [`affinity`]) first thing inside its spawn closure — before its first
//!   allocation, so first-touch scratch like the kernels' `TilePool` lands
//!   NUMA-local. The caller (worker 0) is never pinned by the pool; the
//!   serving shards pin their own threads. A refused pin is a counted
//!   no-op (`pin_events()` reports successes), never an error.

pub mod affinity;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, TryLockError};

use crate::config::ParallelConfig;

/// Lock helper: a panic inside a scope body (or a shard worker) can poison
/// a mutex while the protected state is still consistent (panics are
/// captured, or contained by the serving death guard) — keep serving after
/// one. Shared with `serving::queues`, the other concurrency layer.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A scope body as the helpers see it (the type-alias context pins the
/// trait object's lifetime bound to `'static`; the publish-side transmute
/// is what erases the real borrow).
type ScopeBody = dyn Fn(usize) + Sync;

/// Type-erased pointer to a scope body. Helpers only ever dereference it
/// between job publish and the caller's completion wait, while the original
/// closure is still borrowed on the caller's stack.
struct Job(*const ScopeBody);

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and the pointer is only dereferenced while the caller blocks in
// `run_scope`, so sending the pointer to helper threads is sound.
unsafe impl Send for Job {}

/// Shared pool state, guarded by one mutex.
struct State {
    /// Scope counter: bumped once per published job. Helpers compare it
    /// against the last epoch they executed (the seqlock-style wake check).
    epoch: u64,
    /// The in-flight scope body; `Some` exactly while an epoch is
    /// outstanding.
    job: Option<Job>,
    /// Helpers still running the current job.
    running: usize,
    /// First panic payload captured from a helper this scope.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Set once when the last `Pool` handle drops; helpers exit.
    shutdown: bool,
}

/// State shared with the helper threads (kept alive by their `Arc`s even
/// while the owning `Pool` is mid-drop).
struct Core {
    state: Mutex<State>,
    /// Helpers park here between scopes.
    work_cv: Condvar,
    /// The scope caller parks here until every helper has finished.
    done_cv: Condvar,
    /// Helper threads ever spawned by this pool (the spawn-once test hook).
    spawns: AtomicU64,
    /// Park → wake transitions across all helpers (telemetry; a helper that
    /// finds the next epoch already published without waiting is not
    /// counted — it never parked).
    wakes: AtomicU64,
    /// Helper threads successfully pinned to a core at spawn (telemetry +
    /// test hook; stays 0 on unpinned pools and when the kernel refuses
    /// `sched_setaffinity`).
    pins: AtomicU64,
}

/// Owned by the `Pool` handles; dropping the last one shuts the helpers
/// down and joins them.
struct Shared {
    workers: usize,
    /// Core list for helper pinning: helper `w` pins itself to
    /// `cores[w % cores.len()]` at spawn. `None` = unpinned pool.
    pin_cores: Option<Vec<usize>>,
    core: Arc<Core>,
    /// Helper thread handles, spawned lazily on the first parallel scope.
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Serializes scopes: one job in flight per pool. `run_scope` falls
    /// back to inline execution when it cannot take this immediately.
    scope_lock: Mutex<()>,
}

impl Drop for Shared {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.core.state);
            st.shutdown = true;
        }
        self.core.work_cv.notify_all();
        for h in lock(&self.handles).drain(..) {
            let _ = h.join();
        }
    }
}

/// Helper thread main loop: park until a new epoch is published (or
/// shutdown), run the job once, report completion.
fn helper_loop(core: Arc<Core>, worker: usize, mut seen: u64) {
    loop {
        let ptr = {
            let mut st = lock(&core.state);
            let mut parked = false;
            while !st.shutdown && st.epoch == seen {
                parked = true;
                st = core.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            if st.shutdown {
                return;
            }
            if parked {
                core.wakes.fetch_add(1, Ordering::Relaxed);
            }
            seen = st.epoch;
            st.job.as_ref().expect("job published with the epoch").0
        };
        // SAFETY: the publisher keeps the closure alive (blocked in
        // `run_scope`) until `running` drains back to zero below.
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| (unsafe { &*ptr })(worker)));
        let mut st = lock(&core.state);
        if let Err(p) = result {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.running -= 1;
        if st.running == 0 {
            core.done_cv.notify_one();
        }
    }
}

/// A handle on a persistent worker pool. Clones share the same helper
/// threads; the helpers shut down when the last handle drops. Creating a
/// pool is cheap — helper threads are spawned lazily on the first
/// multi-worker scope and parked (never re-spawned) between scopes.
#[derive(Clone)]
pub struct Pool {
    shared: Arc<Shared>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("workers", &self.workers())
            .field("spawned", &self.spawn_events())
            .finish()
    }
}

impl Pool {
    pub fn new(workers: usize) -> Self {
        Self::new_pinned(workers, None)
    }

    /// Pool whose helpers pin themselves at spawn: helper `w` (1-based, the
    /// caller is worker 0 and is never pinned by the pool) pins to
    /// `cores[w % cores.len()]` before entering its park loop, so any
    /// first-touch scratch it allocates is local to that core's node.
    /// Pinning is best-effort — a refused `sched_setaffinity` (non-Linux,
    /// seccomp, cpuset) degrades to an unpinned helper and is observable
    /// only through `pin_events()`. `None` or an empty core list means
    /// no pinning (identical to `Pool::new`).
    pub fn new_pinned(workers: usize, pin_cores: Option<Vec<usize>>) -> Self {
        Self {
            shared: Arc::new(Shared {
                workers: workers.max(1),
                pin_cores: pin_cores.filter(|cs| !cs.is_empty()),
                core: Arc::new(Core {
                    state: Mutex::new(State {
                        epoch: 0,
                        job: None,
                        running: 0,
                        panic: None,
                        shutdown: false,
                    }),
                    work_cv: Condvar::new(),
                    done_cv: Condvar::new(),
                    spawns: AtomicU64::new(0),
                    wakes: AtomicU64::new(0),
                    pins: AtomicU64::new(0),
                }),
                handles: Mutex::new(Vec::new()),
                scope_lock: Mutex::new(()),
            }),
        }
    }

    /// Single-worker pool: every `par_*` call degrades to a plain loop on the
    /// calling thread (the serial reference path). Never spawns a thread.
    pub fn serial() -> Self {
        Self::new(1)
    }

    pub fn from_config(cfg: &ParallelConfig) -> Self {
        if cfg.pin_workers {
            let n = affinity::available_cores();
            Self::new_pinned(cfg.workers, Some((0..cfg.workers.max(1)).map(|w| w % n).collect()))
        } else {
            Self::new(cfg.workers)
        }
    }

    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Helper threads spawned so far (test hook for the spawn-once
    /// invariant: at most `workers() - 1`, all on the first parallel scope,
    /// zero in steady state and zero forever on a serial pool).
    pub fn spawn_events(&self) -> u64 {
        self.shared.core.spawns.load(Ordering::Relaxed)
    }

    /// Park → wake transitions across all helpers so far (telemetry for the
    /// serving layer's occupancy reports).
    pub fn wake_events(&self) -> u64 {
        self.shared.core.wakes.load(Ordering::Relaxed)
    }

    /// Helpers successfully pinned to a core at spawn. At most
    /// `workers() - 1`; exactly 0 on unpinned pools, and possibly 0 on a
    /// pinned pool whose sandbox refuses `sched_setaffinity` (pinning is
    /// best-effort by design).
    pub fn pin_events(&self) -> u64 {
        self.shared.core.pins.load(Ordering::Relaxed)
    }

    /// Spawn any missing helper threads. Called with `scope_lock` held and
    /// no epoch outstanding, so the epoch read here is stable until the
    /// caller publishes the next job.
    fn ensure_spawned(&self) {
        let helpers = self.shared.workers - 1;
        let mut hs = lock(&self.shared.handles);
        if hs.len() >= helpers {
            return;
        }
        let seen = lock(&self.shared.core.state).epoch;
        while hs.len() < helpers {
            let worker = hs.len() + 1;
            let core = self.shared.core.clone();
            let pin = self.shared.pin_cores.as_ref().map(|cs| cs[worker % cs.len()]);
            self.shared.core.spawns.fetch_add(1, Ordering::Relaxed);
            hs.push(
                std::thread::Builder::new()
                    .name(format!("ewq-pool-{worker}"))
                    .spawn(move || {
                        // pin before the first allocation or park so the
                        // helper's first-touch scratch is node-local
                        if let Some(c) = pin {
                            if affinity::pin_to_core(c) {
                                core.pins.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        helper_loop(core, worker, seen)
                    })
                    .expect("spawn pool worker"),
            );
        }
    }

    /// Publish one scope to the parked helpers, run worker 0 on the calling
    /// thread, and block until every helper has finished — the primitive
    /// every `par_*` entry point builds on. Falls back to running the whole
    /// body inline as worker 0 when another scope of this pool is already
    /// in flight (nested or concurrent use), which is always correct: every
    /// scope body must tolerate any worker count, including 1.
    // the transmute only erases the closure's borrow lifetime — clippy
    // cannot see that and flags it as a no-op
    #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
    fn run_scope(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.shared.workers <= 1 {
            f(0);
            return;
        }
        let guard = match self.shared.scope_lock.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                f(0);
                return;
            }
        };
        self.ensure_spawned();
        let core = &self.shared.core;
        {
            let mut st = lock(&core.state);
            // SAFETY: the borrow is erased to 'static only for the window
            // where this thread blocks below until `running == 0`; no
            // helper touches the pointer after that.
            st.job = Some(Job(unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), *const ScopeBody>(f)
            }));
            st.running = self.shared.workers - 1;
            st.panic = None;
            st.epoch += 1;
        }
        core.work_cv.notify_all();
        // the caller doubles as worker 0; its own panic is deferred until
        // the helpers are done so they never outlive the borrows they run on
        let caller = std::panic::catch_unwind(AssertUnwindSafe(|| f(0)));
        let mut st = lock(&core.state);
        while st.running > 0 {
            st = core.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        let helper_panic = st.panic.take();
        drop(st);
        drop(guard);
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if let Some(p) = helper_panic {
            std::panic::resume_unwind(p);
        }
    }

    /// Run `f(worker_index)` once per worker, concurrently, and wait for all
    /// of them. With one worker, runs inline on the calling thread.
    ///
    /// ```
    /// use std::sync::atomic::{AtomicUsize, Ordering};
    /// use ewq::par::Pool;
    ///
    /// let pool = Pool::new(4);
    /// let hits = AtomicUsize::new(0);
    /// // the body may borrow the caller's stack; scope blocks until every
    /// // worker (including the caller, as worker 0) has finished
    /// pool.scope(|worker| {
    ///     assert!(worker < 4);
    ///     hits.fetch_add(1, Ordering::Relaxed);
    /// });
    /// assert_eq!(hits.into_inner(), 4);
    /// ```
    pub fn scope<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.run_scope(&f);
    }

    /// Map `f` over `0..n`, returning results in index order. Tasks are
    /// claimed dynamically (atomic counter), so uneven task costs balance
    /// across workers. Panics in `f` propagate to the caller.
    pub fn par_map_range<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.workers() <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let slots = SendSlots(out.as_mut_ptr());
        let next = AtomicUsize::new(0);
        self.run_scope(&|_w| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let r = f(i);
            // SAFETY: `i` was claimed by exactly one worker via the atomic
            // counter, so this slot is written at most once, and the owning
            // Vec outlives the scope (run_scope blocks until all workers
            // are done).
            unsafe { slots.write(i, r) };
        });
        out.into_iter().map(|o| o.expect("worker produced every index")).collect()
    }

    /// Map `f(index, &item)` over a slice, results in input order.
    pub fn par_map_indexed<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.par_map_range(items.len(), |i| f(i, &items[i]))
    }

    /// Split `data` into contiguous bands of `band` elements (the last may
    /// be shorter) and run `f(worker, band_index, band)` over them in
    /// parallel. Bands are claimed dynamically off a shared iterator, each
    /// band is visited exactly once, and writes are confined to the band —
    /// so for any pure-per-band `f` the result is identical for every
    /// worker count. The worker index (`< self.workers()`) lets callers
    /// reuse per-worker scratch buffers without sharing; this is the
    /// in-place primitive the fused GEMM kernels row-band on.
    pub fn par_bands_mut<T, F>(&self, data: &mut [T], band: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let band = band.max(1);
        if self.workers() <= 1 || data.len() <= band {
            for (i, c) in data.chunks_mut(band).enumerate() {
                f(0, i, c);
            }
            return;
        }
        let bands = Mutex::new(data.chunks_mut(band).enumerate());
        self.run_scope(&|w| loop {
            // claim under the lock (dropped at end of statement), run
            // outside it
            let next = lock(&bands).next();
            let Some((i, c)) = next else { break };
            f(w, i, c);
        });
    }

    /// Column-banded in-place partition of a row-major `(rows, row_len)`
    /// matrix stored flat in `data`: the columns are split into contiguous
    /// bands of `band` columns (the last may be narrower) and
    /// `f(worker, band_index, view)` runs over the bands in parallel. Each
    /// band is claimed by exactly one worker off an atomic counter and the
    /// `ColBandMut` view confines its writes to that band's column range of
    /// every row — the **strided-write** sibling of `par_bands_mut`, for
    /// outputs partitioned along the row (n) dimension instead of across
    /// whole rows. For any pure-per-band `f` the result is identical for
    /// every worker count; the worker index lets callers reuse per-worker
    /// scratch (this is what the column-banded fused GEMM tiles on, so each
    /// packed tile is unpacked exactly once per call).
    pub fn par_col_bands_mut<T, F>(&self, data: &mut [T], row_len: usize, band: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut ColBandMut<T>) + Sync,
    {
        if data.is_empty() || row_len == 0 {
            return;
        }
        assert_eq!(data.len() % row_len, 0, "data must be whole rows of row_len");
        let band = band.max(1);
        let rows = data.len() / row_len;
        let n_bands = row_len.div_ceil(band);
        let base = ColPtr(data.as_mut_ptr());
        let run_band = |w: usize, bi: usize| {
            let c0 = bi * band;
            let cw = band.min(row_len - c0);
            // SAFETY: bands partition the columns disjointly, each band
            // index is claimed exactly once, and the backing slice outlives
            // the scope (run_scope blocks until every worker drains) — so
            // views never alias and never dangle.
            let mut view = ColBandMut { base: base.0, rows, row_len, c0, cw };
            f(w, bi, &mut view);
        };
        if self.workers() <= 1 || n_bands <= 1 {
            for bi in 0..n_bands {
                run_band(0, bi);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        self.run_scope(&|w| loop {
            let bi = next.fetch_add(1, Ordering::Relaxed);
            if bi >= n_bands {
                break;
            }
            run_band(w, bi);
        });
    }

    /// Deterministic chunked map-reduce over a slice: split `data` into
    /// fixed-size chunks (layout depends only on `data.len()` and `chunk`),
    /// map chunks in parallel, then fold the partials IN CHUNK ORDER on the
    /// calling thread. Identical bits for any worker count.
    pub fn par_chunk_fold<T, A, M, F>(&self, data: &[T], chunk: usize, map: M, init: A, fold: F) -> A
    where
        T: Sync,
        A: Send,
        M: Fn(&[T]) -> A + Sync,
        F: FnMut(A, A) -> A,
    {
        let chunks: Vec<&[T]> = data.chunks(chunk.max(1)).collect();
        let partials = self.par_map_indexed(&chunks, |_, c| map(c));
        partials.into_iter().fold(init, fold)
    }
}

impl Default for Pool {
    fn default() -> Self {
        Self::from_config(&ParallelConfig::default())
    }
}

/// One worker's exclusive window onto the columns `c0..c0+cw` of every row
/// of a flat row-major matrix — the view `par_col_bands_mut` hands its
/// band closures. Only constructed inside `par_col_bands_mut`, which
/// guarantees bands never overlap; `row_mut` borrows `&mut self`, so a
/// closure can hold at most one row segment at a time.
pub struct ColBandMut<T> {
    base: *mut T,
    rows: usize,
    row_len: usize,
    c0: usize,
    cw: usize,
}

impl<T> ColBandMut<T> {
    /// Number of matrix rows (every band sees all of them).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// The absolute column range this band owns.
    pub fn cols(&self) -> std::ops::Range<usize> {
        self.c0..self.c0 + self.cw
    }

    /// Band width in columns.
    pub fn width(&self) -> usize {
        self.cw
    }

    /// Mutable view of this band's segment of row `r` (`width()` elements,
    /// starting at absolute column `cols().start`).
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        assert!(r < self.rows, "row {r} out of {} rows", self.rows);
        // SAFETY: the band exclusively owns columns c0..c0+cw of every row
        // (disjoint from every other band), r*row_len + c0 + cw <= the
        // backing slice length, and the returned borrow is tied to
        // &mut self so segments cannot alias each other through this view.
        unsafe { std::slice::from_raw_parts_mut(self.base.add(r * self.row_len + self.c0), self.cw) }
    }
}

/// Shared base pointer for the column-band views. Workers carve disjoint
/// per-band windows out of it; `T: Send` makes handing those windows to
/// other threads sound.
struct ColPtr<T>(*mut T);

// SAFETY: only ever dereferenced through disjoint ColBandMut windows while
// the owning scope blocks in run_scope.
unsafe impl<T: Send> Sync for ColPtr<T> {}

/// Shared raw view of the `par_map_range` output slots. Disjoint writes
/// only: every index is claimed by exactly one worker.
struct SendSlots<R>(*mut Option<R>);

// SAFETY: workers move `R` values into distinct slots through a shared
// reference; `R: Send` makes the cross-thread move sound.
unsafe impl<R: Send> Sync for SendSlots<R> {}

impl<R> SendSlots<R> {
    /// SAFETY: caller guarantees `i` is in bounds, written by one worker
    /// only, and that the backing Vec outlives every write.
    unsafe fn write(&self, i: usize, val: R) {
        *self.0.add(i) = Some(val);
    }
}

/// Convenience free function: map over a slice with `cfg.workers` workers.
/// The transient pool spawns (and joins) its helpers within the call —
/// hold a `Pool` instead on hot paths so the workers stay parked between
/// calls.
pub fn par_map_indexed<T, R, F>(cfg: &ParallelConfig, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    Pool::from_config(cfg).par_map_indexed(items, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_range_matches_serial_in_order() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, ParallelConfig::test_workers(5)] {
            let par = Pool::new(workers).par_map_range(100, |i| i * i);
            assert_eq!(par, serial, "workers={workers}");
        }
    }

    #[test]
    fn map_indexed_passes_items() {
        let items: Vec<i64> = (0..57).map(|i| i - 20).collect();
        let out = Pool::new(4).par_map_indexed(&items, |i, &x| (i as i64) + x);
        let expect: Vec<i64> = (0..57).map(|i| 2 * i - 20).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let out: Vec<u32> = Pool::new(4).par_map_range(0, |_| unreachable!());
        assert!(out.is_empty());
        assert_eq!(Pool::new(4).par_map_range(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn scope_runs_every_worker() {
        let count = AtomicUsize::new(0);
        Pool::new(5).scope(|_w| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
        let count = AtomicUsize::new(0);
        Pool::serial().scope(|w| {
            assert_eq!(w, 0);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn workers_spawn_once_and_park_between_scopes() {
        // the persistent-pool invariant: helpers appear on the first
        // parallel scope and are only parked/woken — never re-spawned —
        // by the scopes after it
        let pool = Pool::new(3);
        assert_eq!(pool.spawn_events(), 0, "lazy: no threads before first scope");
        let first = pool.par_map_range(10, |i| i * 3);
        assert_eq!(first, (0..10).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(pool.spawn_events(), 2, "workers - 1 helpers on first use");
        let mut data = vec![0u64; 256];
        for _ in 0..20 {
            let _ = pool.par_map_range(10, |i| i);
            pool.scope(|_w| {});
            pool.par_bands_mut(&mut data, 16, |_w, i, band| {
                band.iter_mut().for_each(|x| *x = i as u64);
            });
        }
        assert_eq!(pool.spawn_events(), 2, "steady state performs zero thread spawns");
        assert!(pool.wake_events() >= 2, "parked helpers are woken per scope");
        // clones share the same helpers
        let clone = pool.clone();
        let _ = clone.par_map_range(10, |i| i);
        assert_eq!(pool.spawn_events(), 2);
    }

    #[test]
    fn serial_pool_never_spawns() {
        let pool = Pool::serial();
        let _ = pool.par_map_range(100, |i| i);
        pool.scope(|_| {});
        assert_eq!(pool.spawn_events(), 0);
        assert_eq!(pool.wake_events(), 0);
    }

    #[test]
    fn panic_in_scope_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.par_map_range(16, |i| {
                if i == 11 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err(), "worker panic must reach the caller");
        // helpers survive a panicked scope and keep serving
        assert_eq!(pool.par_map_range(4, |i| i * 2), vec![0, 2, 4, 6]);
        assert_eq!(pool.spawn_events(), 3, "no respawn after a panic");
    }

    #[test]
    fn nested_scopes_degrade_to_inline() {
        // a scope started from inside another scope of the same pool runs
        // inline instead of deadlocking on the busy helpers
        let pool = Pool::new(2);
        let out = pool.par_map_range(4, |i| {
            pool.par_map_range(3, |j| j).iter().sum::<usize>() + i
        });
        assert_eq!(out, vec![3, 4, 5, 6]);
    }

    #[test]
    fn chunk_fold_is_bit_stable_across_worker_counts() {
        // f64 summation depends on order — the fixed chunk layout + ordered
        // fold must give identical bits for every worker count.
        let data: Vec<f64> = (0..100_000).map(|i| ((i * 2654435761_usize) as f64).sqrt()).collect();
        let sum = |pool: &Pool| {
            pool.par_chunk_fold(&data, 1 << 10, |c| c.iter().sum::<f64>(), 0.0, |a, b| a + b)
        };
        let s1 = sum(&Pool::serial());
        for workers in [2, 3, 4, 7, ParallelConfig::test_workers(2)] {
            let sp = sum(&Pool::new(workers));
            assert_eq!(s1.to_bits(), sp.to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn chunk_fold_handles_tiny_inputs() {
        let data = [1.5f64, 2.5];
        let s = Pool::new(8).par_chunk_fold(&data, 1024, |c| c.iter().sum::<f64>(), 0.0, |a, b| {
            a + b
        });
        assert_eq!(s, 4.0);
        let empty: [f64; 0] = [];
        let s = Pool::new(2).par_chunk_fold(&empty, 16, |c| c.iter().sum::<f64>(), 0.0, |a, b| {
            a + b
        });
        assert_eq!(s, 0.0);
    }

    #[test]
    fn uneven_task_costs_balance() {
        // tasks with wildly different costs must still land in order
        let out = Pool::new(4).par_map_range(40, |i| {
            let mut acc = 0u64;
            for k in 0..(i % 7) * 10_000 {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        for (i, item) in out.iter().enumerate() {
            assert_eq!(item.0, i);
        }
    }

    #[test]
    fn bands_mut_visits_every_band_exactly_once() {
        let mut data = vec![0u64; 1003];
        for workers in [1usize, 2, 5, 8] {
            data.iter_mut().for_each(|x| *x = 0);
            Pool::new(workers).par_bands_mut(&mut data, 64, |_w, i, band| {
                for x in band.iter_mut() {
                    *x += (i + 1) as u64;
                }
            });
            for (j, &x) in data.iter().enumerate() {
                assert_eq!(x, (j / 64 + 1) as u64, "workers={workers} j={j}");
            }
        }
    }

    #[test]
    fn bands_mut_worker_indices_in_range() {
        let mut data = vec![0u8; 500];
        let seen = AtomicUsize::new(0);
        let pool = Pool::new(3);
        pool.par_bands_mut(&mut data, 10, |w, _i, _band| {
            assert!(w < 3);
            seen.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(seen.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn bands_mut_handles_empty_and_oversized_band() {
        let mut empty: [u32; 0] = [];
        Pool::new(4).par_bands_mut(&mut empty, 8, |_, _, _| unreachable!());
        let mut tiny = [1u32, 2, 3];
        Pool::new(4).par_bands_mut(&mut tiny, 100, |w, i, band| {
            assert_eq!((w, i), (0, 0));
            band.iter_mut().for_each(|x| *x *= 2);
        });
        assert_eq!(tiny, [2, 4, 6]);
    }

    #[test]
    fn col_bands_mut_visits_every_column_of_every_row_exactly_once() {
        // 7 rows x 53 cols (ragged last band): element (r, c) must be
        // written exactly once, by the band owning column c
        let (rows, row_len, band) = (7usize, 53usize, 8usize);
        for workers in [1usize, 2, 5, ParallelConfig::test_workers(3)] {
            let mut data = vec![0u64; rows * row_len];
            Pool::new(workers).par_col_bands_mut(&mut data, row_len, band, |_w, bi, view| {
                assert_eq!(view.rows(), rows);
                assert_eq!(view.cols().start, bi * band);
                assert_eq!(view.width(), view.cols().len());
                for r in 0..view.rows() {
                    for (ci, x) in view.row_mut(r).iter_mut().enumerate() {
                        *x += (r * row_len + bi * band + ci + 1) as u64;
                    }
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, (i + 1) as u64, "workers={workers} flat index {i}");
            }
        }
    }

    #[test]
    fn col_bands_mut_serial_matches_pooled_bitwise() {
        // f32 writes that depend on band index and column — any worker
        // count must produce identical bytes
        let (rows, row_len, band) = (5usize, 37usize, 10usize);
        let run = |workers: usize| {
            let mut data = vec![0.0f32; rows * row_len];
            Pool::new(workers).par_col_bands_mut(&mut data, row_len, band, |_w, bi, view| {
                for r in 0..view.rows() {
                    let c0 = view.cols().start;
                    for (ci, x) in view.row_mut(r).iter_mut().enumerate() {
                        *x = ((bi * 31 + r * 7 + c0 + ci) as f32).sqrt();
                    }
                }
            });
            data
        };
        let serial = run(1);
        for workers in [2usize, 3, 8] {
            let pooled = run(workers);
            for (i, (a, b)) in serial.iter().zip(&pooled).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "workers={workers} i={i}");
            }
        }
    }

    #[test]
    fn col_bands_mut_handles_empty_oversized_and_single_band() {
        let mut empty: [u32; 0] = [];
        Pool::new(4).par_col_bands_mut(&mut empty, 8, 4, |_, _, _| unreachable!());
        let mut data = vec![1u32; 12]; // 3 rows x 4 cols, band wider than row
        Pool::new(4).par_col_bands_mut(&mut data, 4, 100, |w, bi, view| {
            assert_eq!((w, bi), (0, 0), "single band runs inline");
            assert_eq!(view.width(), 4);
            for r in 0..view.rows() {
                view.row_mut(r).iter_mut().for_each(|x| *x *= 2);
            }
        });
        assert!(data.iter().all(|&x| x == 2));
    }

    #[test]
    #[should_panic(expected = "whole rows")]
    fn col_bands_mut_rejects_ragged_data() {
        let mut data = vec![0u8; 10];
        Pool::new(2).par_col_bands_mut(&mut data, 3, 2, |_, _, _| {});
    }

    #[test]
    fn free_function_uses_config_workers() {
        let cfg = ParallelConfig::with_workers(3);
        let out = par_map_indexed(&cfg, &[10, 20, 30], |i, &x| x + i as i32);
        assert_eq!(out, vec![10, 21, 32]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        assert_eq!(Pool::new(0).workers(), 1);
        assert_eq!(Pool::from_config(&ParallelConfig::with_workers(0)).workers(), 1);
    }

    #[test]
    fn unpinned_pools_never_count_pin_events() {
        let pool = Pool::new(3);
        let _ = pool.par_map_range(8, |i| i);
        assert_eq!(pool.pin_events(), 0);
        // an empty core list means "no pinning", same as None
        let empty = Pool::new_pinned(3, Some(Vec::new()));
        let _ = empty.par_map_range(8, |i| i);
        assert_eq!(empty.pin_events(), 0);
    }

    #[test]
    fn pinned_pool_pins_helpers_at_spawn() {
        // skip-tolerant by design: pinning is best-effort, and a sandbox
        // that refuses sched_setaffinity must not fail the suite — the
        // observable contract is "results identical, pin_events() counts
        // only kernel-accepted pins"
        let Some(allowed) = affinity::current_affinity() else { return };
        assert!(!allowed.is_empty());
        let target = allowed[0];
        let pool = Pool::new_pinned(3, Some(vec![target]));
        assert_eq!(pool.pin_events(), 0, "lazy: no pinning before the first scope");
        let out = pool.par_map_range(16, |i| i * 2);
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
        assert!(pool.pin_events() <= 2, "at most workers - 1 helpers pin");
        if pool.pin_events() == 2 {
            // both helpers accepted the pin: their masks must now be
            // exactly the target core (worker 0 — this thread — is not
            // pinned by the pool)
            let masks = Mutex::new(vec![None; 3]);
            pool.scope(|w| {
                if w > 0 {
                    lock(&masks)[w] = Some(affinity::current_affinity());
                }
            });
            for (w, m) in lock(&masks).iter().enumerate().skip(1) {
                assert_eq!(
                    m.clone().flatten().as_deref(),
                    Some(&[target][..]),
                    "helper {w} runs pinned to core {target}"
                );
            }
        }
    }

    #[test]
    fn pinned_config_builds_pinned_pool_with_identical_results() {
        let cfg = ParallelConfig::with_workers(3).pinned(true);
        let pool = Pool::from_config(&cfg);
        let serial: Vec<usize> = (0..64).map(|i| i * i + 1).collect();
        assert_eq!(pool.par_map_range(64, |i| i * i + 1), serial);
        // pin successes are bounded by helper count whatever the sandbox did
        assert!(pool.pin_events() <= 2);
    }
}
