//! Fused quantized-GEMM kernels: cache-blocked matmuls that consume `QMat`
//! packed payloads directly, so a served replica never materializes (or
//! keeps resident) an f32 shadow copy of its quantized weights.
//!
//! Layout of one call (`matmul_qmat`, C = A·W with A `(m,k)` activations
//! row-major and W a packed `(k,n)` matrix):
//!
//! - the output is partitioned over the existing `par::Pool` by one of two
//!   **banding strategies**, chosen by shape (`gemm_banding`):
//!   - **row bands** (`Pool::par_bands_mut`): each worker owns contiguous
//!     output rows and walks every `TILE_K × TILE_N` tile of W — the deep-m
//!     strategy, where each band's tile unpack amortizes over many rows;
//!   - **column bands** (`Pool::par_col_bands_mut`): each worker owns an
//!     n-range and sweeps all m rows through its tiles, so every packed
//!     tile is unpacked **exactly once per call** instead of once per row
//!     band — the shallow-m strategy (small batches, decode-adjacent
//!     shapes), at the cost of each worker re-reading the (m,k) activations;
//! - inside a band, W tiles are group-unpacked (`quant::dequantize_tile_path`)
//!   into a per-worker scratch tile (`TilePool`, 8 KiB, 64-byte-aligned —
//!   L1-resident, and zmm stores never split a cache line) and multiplied
//!   against the activation rows with a stride-1 inner loop. On SIMD paths
//!   the band loop additionally issues software prefetch for the *next*
//!   packed tile + scale group (`quant::prefetch_tile`) while the current
//!   one unpacks — a pure hint that never moves a result bit; disable with
//!   `EWQ_PREFETCH=0` (DESIGN.md §16);
//! - the inner loops are **SIMD** (`crate::simd`, AVX-512F/AVX2 behind
//!   runtime detection; `EWQ_KERNEL_PATH=scalar|avx2|avx512` pins an
//!   explicit path, `EWQ_FORCE_SCALAR` pins the portable scalar fallback),
//!   vectorized across the **n** dimension only — one lane per output
//!   column — so `k` still accumulates in ascending order for every output
//!   element, the same order as the serial reference matmul. The fused
//!   kernel is therefore **bit-identical** to `matmul(a, dequantize(w))`
//!   for every precision, path, banding, and worker count (DESIGN.md §11);
//! - `Payload::Raw` dispatches to `matmul_f32`, the k-tiled f32 kernel that
//!   reads the payload in place (no tile copy needed).
//!
//! Steady-state calls do zero heap allocation — each worker's tile buffer
//! is allocated exactly once, on that worker's own thread the first time it
//! claims a band (first-touch, so the page lands NUMA-local to a pinned
//! worker; see `par::Pool::new_pinned`) — and zero thread spawns:
//! `par::Pool` keeps its workers parked between kernel invocations, so each
//! call costs one publish + wake, not a spawn/join barrier (see DESIGN.md
//! §9).

use std::sync::Mutex;

use crate::par::Pool;
use crate::quant::{dequantize_tile_path, prefetch_tile, Payload, QMat};
use crate::simd::axpy;
pub use crate::simd::{kernel_path, KernelPath};

/// Tile height along the reduction (`k`) dimension. A multiple of every
/// packing-group size (1/2/4/8 rows for Q8/Q4/T2/Q3), so every tile starts
/// and ends on a group boundary.
pub const TILE_K: usize = 32;
/// Tile width along the output (`n`) dimension; `TILE_K * TILE_N` f32 = 8 KiB.
pub const TILE_N: usize = 64;

/// One worker's 64-byte-aligned `TILE_K * TILE_N` f32 scratch tile.
/// `Vec<f32>` only guarantees 4-byte alignment; aligning to the cache line
/// means a 64-byte zmm store never splits a line and every tile row starts
/// on a line boundary, so the unpack writes and the axpy reads stream
/// cleanly. Allocated zeroed so the first touch faults the pages in on the
/// allocating (owning) worker's thread.
struct AlignedTile {
    ptr: std::ptr::NonNull<f32>,
    len: usize,
}

// SAFETY: the tile is plainly-owned heap memory; the per-slot Mutex in
// TilePool serializes every access across threads.
unsafe impl Send for AlignedTile {}

impl AlignedTile {
    fn new(len: usize) -> Self {
        let layout = std::alloc::Layout::from_size_align(len * 4, 64).unwrap();
        // SAFETY: layout has non-zero size (len is TILE_K * TILE_N).
        let raw = unsafe { std::alloc::alloc_zeroed(layout) } as *mut f32;
        let ptr = std::ptr::NonNull::new(raw)
            .unwrap_or_else(|| std::alloc::handle_alloc_error(layout));
        Self { ptr, len }
    }

    fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: ptr owns `len` f32s for self's lifetime.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedTile {
    fn drop(&mut self) {
        let layout = std::alloc::Layout::from_size_align(self.len * 4, 64).unwrap();
        // SAFETY: allocated in `new` with this exact layout.
        unsafe { std::alloc::dealloc(self.ptr.as_ptr() as *mut u8, layout) };
    }
}

/// Per-worker dequantization tile buffers, created once per executor and
/// reused by every `matmul_qmat` call — the scratch arena half that keeps
/// the fused kernels allocation-free in steady state. Each worker locks its
/// own (uncontended) slot once per band; the aligned tile behind the slot
/// is allocated lazily, on the owning worker's **first touch**, so under a
/// pinned pool (`Pool::new_pinned`) the memory faults in NUMA-local to the
/// core that will reuse it forever after. Construction itself allocates
/// nothing and spawns nothing.
pub struct TilePool {
    bufs: Vec<Mutex<Option<AlignedTile>>>,
}

impl TilePool {
    /// One lazily-allocated `TILE_K * TILE_N` slot per worker of `pool`.
    pub fn new(pool: &Pool) -> Self {
        Self { bufs: (0..pool.workers()).map(|_| Mutex::new(None)).collect() }
    }

    pub fn workers(&self) -> usize {
        self.bufs.len()
    }
}

/// Lock worker `wkr`'s slot and hand its tile to `f`, allocating the
/// aligned tile on this (the owning) worker's first touch.
#[inline]
fn with_tile<R>(tiles: &TilePool, wkr: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut slot = tiles.bufs[wkr].lock().unwrap();
    f(slot.get_or_insert_with(|| AlignedTile::new(TILE_K * TILE_N)).as_mut_slice())
}

/// How `matmul_qmat` partitions its output over the pool. Either choice
/// yields identical bits — every output element is produced whole inside
/// one band, accumulating `k` in ascending order — so this is purely a
/// throughput knob (`gemm_banding` picks by shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Banding {
    /// Contiguous output-row bands (`par_bands_mut`); each band re-runs the
    /// tile unpack sweep.
    Rows,
    /// Contiguous output-column bands (`par_col_bands_mut`); every packed
    /// tile is unpacked exactly once per call.
    Cols,
}

impl Banding {
    /// Label for bench JSON / logs: `"rows"` or `"cols"`.
    pub fn label(self) -> &'static str {
        match self {
            Banding::Rows => "rows",
            Banding::Cols => "cols",
        }
    }
}

/// The shape rule `matmul_qmat` applies: row banding splits `m` into about
/// `2 * workers` bands, each of which re-unpacks every tile of W — cheap
/// when the bands are deep (the unpack amortizes over many rows), wasteful
/// when they are shallow. Column banding unpacks each tile exactly once but
/// re-reads the `(m,k)` activations once per band, so it pays exactly when
/// the row blocks are shallow and the output is wide enough to hand every
/// worker whole `TILE_N` columns. Serial pools always row-band (one band,
/// zero redundancy either way).
pub fn gemm_banding(m: usize, n: usize, pool: &Pool) -> Banding {
    let w = pool.workers();
    if w <= 1 || n < 2 * TILE_N {
        return Banding::Rows;
    }
    if m <= 8 * w {
        Banding::Cols
    } else {
        Banding::Rows
    }
}

/// Rows per parallel band. Each band re-runs the tile unpack sweep, so
/// band count trades load balance against redundant dequantization
/// (overhead ratio ≈ tile-unpack cost / band rows): one band on a serial
/// pool (zero redundancy), two bands per worker pooled — enough for the
/// shared claim iterator to absorb skew while keeping the per-band unpack
/// amortized over a deep row block. Any band size yields identical bits —
/// every output element is produced whole inside one band.
fn band_rows(m: usize, pool: &Pool) -> usize {
    if pool.workers() <= 1 {
        return m.max(1);
    }
    m.div_ceil(pool.workers() * 2).max(1)
}

/// `out = a @ b` for plain f32 operands (`a` is `(m,k)`, `b` is `(k,n)`,
/// all row-major; `out` is overwritten). k-tiled for B-row reuse across the
/// band and row-banded over `pool`; `k` accumulates in ascending order, so
/// the result is bit-identical to the serial ikj reference for any worker
/// count, tile size, and inner-loop path.
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, pool: &Pool, out: &mut [f32]) {
    matmul_f32_path(a, b, m, k, n, pool, kernel_path(), out)
}

/// `matmul_f32` with the inner-loop path chosen by the caller (benches and
/// the scalar↔SIMD property tests; the wrapper resolves it per call).
#[allow(clippy::too_many_arguments)]
pub fn matmul_f32_path(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    pool: &Pool,
    path: KernelPath,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let band = band_rows(m, pool);
    pool.par_bands_mut(out, band * n, |_w, bi, chunk| {
        let r0 = bi * band;
        let rows = chunk.len() / n;
        chunk.fill(0.0);
        for k0 in (0..k).step_by(TILE_K) {
            let kh = TILE_K.min(k - k0);
            for ri in 0..rows {
                let arow = &a[(r0 + ri) * k + k0..(r0 + ri) * k + k0 + kh];
                let orow = &mut chunk[ri * n..(ri + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    axpy(orow, av, &b[(k0 + kk) * n..(k0 + kk + 1) * n], path);
                }
            }
        }
    });
}

/// `out = a @ w` where `w` is a packed `QMat` (`(k,n)` = `(w.rows, w.cols)`)
/// — the fused serving kernel: group-wise dequantization into per-worker
/// `TILE_K × TILE_N` scratch tiles, multiplied in place with the SIMD inner
/// loops. Banding is chosen by shape (`gemm_banding`) and the path by
/// `kernel_path()`; bit-identical to `matmul_f32(a, dequantize(w))` for
/// every precision, worker count, banding, and path. `Payload::Raw` reads
/// the payload directly through `matmul_f32`.
pub fn matmul_qmat(a: &[f32], w: &QMat, m: usize, pool: &Pool, tiles: &TilePool, out: &mut [f32]) {
    let banding = gemm_banding(m, w.cols, pool);
    matmul_qmat_with(a, w, m, pool, tiles, kernel_path(), banding, out)
}

/// `matmul_qmat` with the inner-loop path and banding strategy chosen by
/// the caller (benches and the equivalence property tests force each
/// combination; the wrapper resolves both per call).
#[allow(clippy::too_many_arguments)]
pub fn matmul_qmat_with(
    a: &[f32],
    w: &QMat,
    m: usize,
    pool: &Pool,
    tiles: &TilePool,
    path: KernelPath,
    banding: Banding,
    out: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if let Payload::Raw(d) = &w.payload {
        return matmul_f32_path(a, d, m, k, n, pool, path, out);
    }
    if m == 0 || n == 0 {
        return;
    }
    assert!(
        tiles.workers() >= pool.workers(),
        "TilePool sized for {} workers, pool has {}",
        tiles.workers(),
        pool.workers()
    );
    // resolved once per call, like the path itself: prefetch rides on any
    // SIMD path unless EWQ_PREFETCH turns it off
    let pf = path.prefetches() && crate::simd::prefetch_enabled();
    match banding {
        Banding::Rows => matmul_qmat_rows(a, w, m, k, n, pool, tiles, path, pf, out),
        Banding::Cols => matmul_qmat_cols(a, w, m, k, n, pool, tiles, path, pf, out),
    }
}

/// The next `(k0, n0)` tile origin after the current one in a band's sweep
/// order (n fastest, then k) — where the prefetch hint points. May land
/// past the matrix; `prefetch_tile` clamps.
#[inline]
fn next_tile(k0: usize, n0: usize, n_end: usize) -> (usize, usize) {
    if n0 + TILE_N < n_end {
        (k0, n0 + TILE_N)
    } else {
        (k0 + TILE_K, 0)
    }
}

/// Row-banded fused GEMM body: each band walks every tile of W.
#[allow(clippy::too_many_arguments)]
fn matmul_qmat_rows(
    a: &[f32],
    w: &QMat,
    m: usize,
    k: usize,
    n: usize,
    pool: &Pool,
    tiles: &TilePool,
    path: KernelPath,
    pf: bool,
    out: &mut [f32],
) {
    let band = band_rows(m, pool);
    pool.par_bands_mut(out, band * n, |wkr, bi, chunk| {
        with_tile(tiles, wkr, |tile| {
            let r0 = bi * band;
            let rows = chunk.len() / n;
            chunk.fill(0.0);
            for k0 in (0..k).step_by(TILE_K) {
                let kh = TILE_K.min(k - k0);
                for n0 in (0..n).step_by(TILE_N) {
                    let nw = TILE_N.min(n - n0);
                    if pf {
                        let (nk, nn) = next_tile(k0, n0, n);
                        prefetch_tile(w, nk..nk + TILE_K, nn..nn + TILE_N);
                    }
                    dequantize_tile_path(w, k0..k0 + kh, n0..n0 + nw, path, &mut tile[..kh * nw]);
                    for ri in 0..rows {
                        let arow = &a[(r0 + ri) * k + k0..(r0 + ri) * k + k0 + kh];
                        let orow = &mut chunk[ri * n + n0..ri * n + n0 + nw];
                        for (kk, &av) in arow.iter().enumerate() {
                            axpy(orow, av, &tile[kk * nw..(kk + 1) * nw], path);
                        }
                    }
                }
            }
        });
    });
}

/// Column-banded fused GEMM body: each worker owns an n-range (whole
/// `TILE_N` tiles, via `band_cols`), sweeps all `m` activation rows through
/// its tiles, and therefore unpacks every packed tile exactly once per
/// call. Per output element the `k` order is unchanged (`k0` ascending,
/// `kk` ascending within a tile) — identical bits to the row-banded body.
#[allow(clippy::too_many_arguments)]
fn matmul_qmat_cols(
    a: &[f32],
    w: &QMat,
    m: usize,
    k: usize,
    n: usize,
    pool: &Pool,
    tiles: &TilePool,
    path: KernelPath,
    pf: bool,
    out: &mut [f32],
) {
    let band = band_cols(n, pool);
    pool.par_col_bands_mut(out, n, band, |wkr, _bi, view| {
        with_tile(tiles, wkr, |tile| {
            let c0 = view.cols().start;
            let cw = view.width();
            for r in 0..m {
                view.row_mut(r).fill(0.0);
            }
            for k0 in (0..k).step_by(TILE_K) {
                let kh = TILE_K.min(k - k0);
                for n0 in (0..cw).step_by(TILE_N) {
                    let nw = TILE_N.min(cw - n0);
                    if pf {
                        let (nk, nn) = next_tile(k0, n0, cw);
                        prefetch_tile(w, nk..nk + TILE_K, c0 + nn..c0 + nn + TILE_N);
                    }
                    dequantize_tile_path(
                        w,
                        k0..k0 + kh,
                        c0 + n0..c0 + n0 + nw,
                        path,
                        &mut tile[..kh * nw],
                    );
                    for ri in 0..m {
                        let arow = &a[ri * k + k0..ri * k + k0 + kh];
                        let orow = &mut view.row_mut(ri)[n0..n0 + nw];
                        for (kk, &av) in arow.iter().enumerate() {
                            axpy(orow, av, &tile[kk * nw..(kk + 1) * nw], path);
                        }
                    }
                }
            }
        });
    });
}

/// Column band width for the GEMV kernels and the column-banded GEMM: the
/// whole row serial, about two bands per worker pooled, rounded up to whole
/// `TILE_N` tiles so no dequant tile is ever split across bands. Any band
/// size yields identical bits — every output element is produced whole
/// inside one band, accumulating `k` in ascending order.
fn band_cols(n: usize, pool: &Pool) -> usize {
    if pool.workers() <= 1 {
        return n.max(1);
    }
    n.div_ceil(pool.workers() * 2).div_ceil(TILE_N).max(1) * TILE_N
}

/// `out = a @ b` for a single activation row (`a` is length `k`, `b` is
/// `(k,n)` row-major, `out` length `n`) — the f32 decode GEMV. Column-banded
/// over `pool`; every output element accumulates `k` in ascending order, so
/// the result is **bit-identical** to `matmul_f32` on a 1-row input for any
/// worker count and path. Steady-state calls do zero heap allocation.
pub fn matvec_f32(a: &[f32], b: &[f32], k: usize, n: usize, pool: &Pool, out: &mut [f32]) {
    matvec_f32_path(a, b, k, n, pool, kernel_path(), out)
}

/// `matvec_f32` with the inner-loop path chosen by the caller.
pub fn matvec_f32_path(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    pool: &Pool,
    path: KernelPath,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), k);
    debug_assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), n);
    if n == 0 {
        return;
    }
    let band = band_cols(n, pool);
    pool.par_bands_mut(out, band, |_w, bi, chunk| {
        let c0 = bi * band;
        let cw = chunk.len();
        chunk.fill(0.0);
        for (kk, &av) in a.iter().enumerate() {
            axpy(chunk, av, &b[kk * n + c0..kk * n + c0 + cw], path);
        }
    });
}

/// `out = a @ w` for a single activation row against a packed `QMat`
/// (`(k,n)` = `(w.rows, w.cols)`) — the fused decode GEMV: group-wise
/// dequantization into the same per-worker `TILE_K × TILE_N` scratch tiles
/// as `matmul_qmat`, multiplied in place with the SIMD inner loops. Column
/// bands fan out on `pool` (a GEMV is the m = 1 case, where column banding
/// is the only partition that parallelizes at all); `k` accumulates in
/// ascending order per output element, so the result is **bit-identical**
/// to `matmul_qmat` on a 1-row input (and hence to the dequantize-then-
/// matmul reference) for every precision, worker count, and path.
/// `Payload::Raw` dispatches to `matvec_f32`.
pub fn matvec_qmat(a: &[f32], w: &QMat, pool: &Pool, tiles: &TilePool, out: &mut [f32]) {
    matvec_qmat_path(a, w, pool, tiles, kernel_path(), out)
}

/// `matvec_qmat` with the inner-loop path chosen by the caller.
pub fn matvec_qmat_path(
    a: &[f32],
    w: &QMat,
    pool: &Pool,
    tiles: &TilePool,
    path: KernelPath,
    out: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), k);
    assert_eq!(out.len(), n);
    if let Payload::Raw(d) = &w.payload {
        return matvec_f32_path(a, d, k, n, pool, path, out);
    }
    if n == 0 {
        return;
    }
    assert!(
        tiles.workers() >= pool.workers(),
        "TilePool sized for {} workers, pool has {}",
        tiles.workers(),
        pool.workers()
    );
    let pf = path.prefetches() && crate::simd::prefetch_enabled();
    let band = band_cols(n, pool);
    pool.par_bands_mut(out, band, |wkr, bi, chunk| {
        with_tile(tiles, wkr, |tile| {
            let c0 = bi * band;
            let cw = chunk.len();
            chunk.fill(0.0);
            for k0 in (0..k).step_by(TILE_K) {
                let kh = TILE_K.min(k - k0);
                for n0 in (0..cw).step_by(TILE_N) {
                    let nw = TILE_N.min(cw - n0);
                    if pf {
                        let (nk, nn) = next_tile(k0, n0, cw);
                        prefetch_tile(w, nk..nk + TILE_K, c0 + nn..c0 + nn + TILE_N);
                    }
                    dequantize_tile_path(
                        w,
                        k0..k0 + kh,
                        c0 + n0..c0 + n0 + nw,
                        path,
                        &mut tile[..kh * nw],
                    );
                    let ochunk = &mut chunk[n0..n0 + nw];
                    for kk in 0..kh {
                        axpy(ochunk, a[k0 + kk], &tile[kk * nw..(kk + 1) * nw], path);
                    }
                }
            }
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::quant::{dequantize, quantize, Precision};
    use crate::rng::Xoshiro256pp;
    use crate::tensor::Tensor;

    /// All inner-loop paths (unavailable SIMD paths degrade to scalar,
    /// making the comparisons trivially true there and real wherever the
    /// hardware/toolchain can run them) and both banding strategies.
    const PATHS: [KernelPath; 3] = [KernelPath::Scalar, KernelPath::Avx2, KernelPath::Avx512];
    const BANDINGS: [Banding; 2] = [Banding::Rows, Banding::Cols];

    /// The serial ikj reference the fused kernels must match bit-for-bit.
    fn reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn rand_vec(len: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut r = Xoshiro256pp::new(seed);
        (0..len).map(|_| r.normal_f32(0.0, std)).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn f32_kernel_bit_identical_to_reference_any_worker_count() {
        // odd shapes on purpose: partial k-tiles, ragged bands
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 5), (13, 33, 19), (17, 96, 67)] {
            let a = rand_vec(m * k, 100 + m as u64, 0.7);
            let b = rand_vec(k * n, 200 + n as u64, 0.7);
            let expect = reference(&a, &b, m, k, n);
            for workers in [1usize, 2, 7] {
                for path in PATHS {
                    let mut out = vec![f32::NAN; m * n];
                    matmul_f32_path(&a, &b, m, k, n, &Pool::new(workers), path, &mut out);
                    assert_bits_eq(
                        &out,
                        &expect,
                        &format!("f32 {m}x{k}x{n} w={workers} {}", path.label()),
                    );
                }
            }
        }
    }

    #[test]
    fn fused_kernels_match_dequantized_reference_every_precision() {
        // Property: for every format, odd (m,k,n) shapes, and 1/2/7 pool
        // workers, the fused packed-payload kernel (auto path + banding)
        // equals the dequantize-then-matmul reference within 1e-5 rel err
        // (it is in fact bit-identical; the looser bound is the documented
        // contract).
        check(
            0xE1A9,
            24,
            8,
            |g| {
                let m = 2 * g.usize_in(0, 9) + 1; // odd 1..17
                let k = 8 * (2 * g.usize_in(0, 7) + 1); // 8 * odd: group-aligned for all formats
                let n = 2 * g.usize_in(0, 40) + 1; // odd 1..81
                let prec = [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
                    [g.usize_in(0, 4)];
                let seed = g.rng.next_u64();
                (m, k, n, prec, seed)
            },
            |&(m, k, n, prec, seed)| {
                let a = rand_vec(m * k, seed, 0.8);
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, seed ^ 1, 0.5)), prec);
                let wd = dequantize(&w);
                let expect = reference(&a, &wd.data, m, k, n);
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    let mut out = vec![f32::NAN; m * n];
                    matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
                    for (i, (f, r)) in out.iter().zip(&expect).enumerate() {
                        let tol = 1e-5 * r.abs().max(1.0);
                        if (f - r).abs() > tol {
                            return Err(format!(
                                "{} {m}x{k}x{n} w={workers} elem {i}: fused {f} vs ref {r}",
                                prec.label()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_path_banding_worker_combination_bit_identical() {
        // The tentpole equivalence property: {Scalar, Avx2, Avx512} x
        // {Rows, Cols} x every packed precision x 1/2/7 workers — every
        // combination must reproduce the scalar serial row-banded kernel
        // bit-for-bit (and that one the dequantized ikj reference).
        check(
            0x51AD,
            18,
            8,
            |g| {
                let m = 2 * g.usize_in(0, 8) + 1; // odd 1..17
                let k = 8 * (2 * g.usize_in(0, 5) + 1); // group-aligned
                let n = 2 * g.usize_in(0, 80) + 1; // odd 1..161: multiple col bands
                let prec = [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
                    [g.usize_in(0, 4)];
                let seed = g.rng.next_u64();
                (m, k, n, prec, seed)
            },
            |&(m, k, n, prec, seed)| {
                let a = rand_vec(m * k, seed, 0.8);
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, seed ^ 1, 0.5)), prec);
                let serial_pool = Pool::serial();
                let serial_tiles = TilePool::new(&serial_pool);
                let mut baseline = vec![f32::NAN; m * n];
                matmul_qmat_with(
                    &a, &w, m, &serial_pool, &serial_tiles,
                    KernelPath::Scalar, Banding::Rows, &mut baseline,
                );
                let expect = reference(&a, &dequantize(&w).data, m, k, n);
                for (i, (f, r)) in baseline.iter().zip(&expect).enumerate() {
                    if f.to_bits() != r.to_bits() {
                        return Err(format!(
                            "{} {m}x{k}x{n} scalar/rows/serial elem {i}: {f} vs ikj ref {r}",
                            prec.label()
                        ));
                    }
                }
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    for path in PATHS {
                        for banding in BANDINGS {
                            let mut out = vec![f32::NAN; m * n];
                            matmul_qmat_with(&a, &w, m, &pool, &tiles, path, banding, &mut out);
                            for (i, (f, r)) in out.iter().zip(&baseline).enumerate() {
                                if f.to_bits() != r.to_bits() {
                                    return Err(format!(
                                        "{} {m}x{k}x{n} w={workers} {}/{} elem {i}: {f} vs {r}",
                                        prec.label(),
                                        path.label(),
                                        banding.label()
                                    ));
                                }
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fused_kernel_is_exactly_deterministic_across_worker_counts() {
        let (m, k, n) = (13usize, 40usize, 37usize);
        let a = rand_vec(m * k, 7, 0.8);
        for prec in [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
            let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 8, 0.5)), prec);
            let run = |workers: usize| {
                let pool = Pool::new(workers);
                let tiles = TilePool::new(&pool);
                let mut out = vec![0.0f32; m * n];
                matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
                out
            };
            let serial = run(1);
            // also bit-identical to the dequantized reference, not just bounded
            let expect = reference(&a, &dequantize(&w).data, m, k, n);
            assert_bits_eq(&serial, &expect, prec.label());
            for workers in [2usize, 3, 7, crate::config::ParallelConfig::test_workers(5)] {
                assert_bits_eq(&run(workers), &serial, &format!("{} w={workers}", prec.label()));
            }
        }
    }

    #[test]
    fn auto_dispatch_matches_forced_scalar_rows() {
        // whatever kernel_path()/gemm_banding select, the public wrappers
        // must reproduce the portable scalar row-banded kernel bit-for-bit
        let (m, k, n) = (5usize, 48usize, 150usize);
        let a = rand_vec(m * k, 91, 0.8);
        for prec in [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
            let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 92, 0.5)), prec);
            let pool = Pool::new(3);
            let tiles = TilePool::new(&pool);
            let mut auto = vec![f32::NAN; m * n];
            matmul_qmat(&a, &w, m, &pool, &tiles, &mut auto);
            let mut forced = vec![f32::NAN; m * n];
            matmul_qmat_with(
                &a, &w, m, &pool, &tiles, KernelPath::Scalar, Banding::Rows, &mut forced,
            );
            assert_bits_eq(&auto, &forced, prec.label());
        }
    }

    #[test]
    fn gemm_banding_shape_rule() {
        // serial pools always row-band
        assert_eq!(gemm_banding(4, 1024, &Pool::serial()), Banding::Rows);
        // narrow outputs cannot feed whole-tile column bands
        assert_eq!(gemm_banding(4, TILE_N, &Pool::new(4)), Banding::Rows);
        // shallow + wide: column bands (unpack once per call)
        assert_eq!(gemm_banding(4, 4 * TILE_N, &Pool::new(4)), Banding::Cols);
        assert_eq!(gemm_banding(32, 4 * TILE_N, &Pool::new(4)), Banding::Cols);
        // deep row blocks amortize the unpack: row bands
        assert_eq!(gemm_banding(1000, 4 * TILE_N, &Pool::new(4)), Banding::Rows);
    }

    #[test]
    fn repeated_kernel_calls_reuse_parked_workers() {
        // the serving hot path: many matmul scopes against one pool must
        // spawn helpers exactly once (the persistent-pool invariant at the
        // kernel seam) — under both bandings
        let (m, k, n) = (9usize, 32usize, 160usize);
        let a = rand_vec(m * k, 31, 0.8);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 32, 0.5)), Precision::Q4);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let mut out = vec![0.0f32; m * n];
        for banding in BANDINGS {
            for _ in 0..5 {
                matmul_qmat_with(&a, &w, m, &pool, &tiles, kernel_path(), banding, &mut out);
            }
        }
        assert_eq!(pool.spawn_events(), 2, "workers - 1 spawns across 10 kernel calls");
    }

    #[test]
    fn raw_payload_dispatches_through_f32_kernel() {
        let (m, k, n) = (5usize, 24usize, 11usize);
        let a = rand_vec(m * k, 21, 0.6);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 22, 0.6)), Precision::Raw);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let expect = reference(&a, &dequantize(&w).data, m, k, n);
        let mut fused = vec![0.0f32; m * n];
        matmul_qmat(&a, &w, m, &pool, &tiles, &mut fused);
        assert_bits_eq(&fused, &expect, "raw auto");
        // forced column banding on a Raw payload still routes through the
        // row-banded f32 kernel — same bits
        let mut forced = vec![0.0f32; m * n];
        matmul_qmat_with(
            &a, &w, m, &pool, &tiles, KernelPath::Scalar, Banding::Cols, &mut forced,
        );
        assert_bits_eq(&forced, &expect, "raw forced cols");
    }

    #[test]
    fn matvec_f32_bit_identical_to_matmul_on_one_row() {
        // odd widths on purpose: partial column bands and tiles
        for &(k, n) in &[(1usize, 1usize), (7, 5), (33, 19), (96, 131), (40, 257)] {
            let a = rand_vec(k, 300 + k as u64, 0.7);
            let b = rand_vec(k * n, 400 + n as u64, 0.7);
            let mut expect = vec![f32::NAN; n];
            matmul_f32_path(&a, &b, 1, k, n, &Pool::serial(), KernelPath::Scalar, &mut expect);
            for workers in [1usize, 2, 7, crate::config::ParallelConfig::test_workers(3)] {
                for path in PATHS {
                    let mut out = vec![f32::NAN; n];
                    matvec_f32_path(&a, &b, k, n, &Pool::new(workers), path, &mut out);
                    assert_bits_eq(
                        &out,
                        &expect,
                        &format!("matvec f32 {k}x{n} w={workers} {}", path.label()),
                    );
                }
            }
        }
    }

    #[test]
    fn matvec_qmat_bit_identical_to_matmul_on_one_row_every_precision() {
        // Property: for every format (incl. Raw dispatch), group-aligned k,
        // odd n, 1/2/7 pool workers, and both inner-loop paths, the fused
        // GEMV equals matmul_qmat on a 1-row input bit-for-bit — the decode
        // path's kernel contract.
        check(
            0xDEC0,
            24,
            8,
            |g| {
                let k = 8 * (2 * g.usize_in(0, 7) + 1); // 8 * odd: group-aligned
                let n = 2 * g.usize_in(0, 80) + 1; // odd 1..161
                let prec = [
                    Precision::Raw,
                    Precision::Q8,
                    Precision::Q4,
                    Precision::Q3,
                    Precision::T2,
                ][g.usize_in(0, 5)];
                let seed = g.rng.next_u64();
                (k, n, prec, seed)
            },
            |&(k, n, prec, seed)| {
                let a = rand_vec(k, seed, 0.8);
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, seed ^ 1, 0.5)), prec);
                let serial_pool = Pool::serial();
                let serial_tiles = TilePool::new(&serial_pool);
                let mut expect = vec![f32::NAN; n];
                matmul_qmat(&a, &w, 1, &serial_pool, &serial_tiles, &mut expect);
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    for path in PATHS {
                        let mut out = vec![f32::NAN; n];
                        matvec_qmat_path(&a, &w, &pool, &tiles, path, &mut out);
                        for (i, (f, r)) in out.iter().zip(&expect).enumerate() {
                            if f.to_bits() != r.to_bits() {
                                return Err(format!(
                                    "{} {k}x{n} w={workers} {} elem {i}: gemv {f} vs gemm {r}",
                                    prec.label(),
                                    path.label()
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matvec_reuses_parked_workers_and_tiles() {
        // the decode hot path: many GEMV scopes against one pool must spawn
        // helpers exactly once and never allocate tile buffers
        let (k, n) = (32usize, 97usize);
        let a = rand_vec(k, 51, 0.8);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 52, 0.5)), Precision::Q4);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let mut out = vec![0.0f32; n];
        for _ in 0..10 {
            matvec_qmat(&a, &w, &pool, &tiles, &mut out);
        }
        assert_eq!(pool.spawn_events(), 2, "workers - 1 spawns across 10 GEMV calls");
    }

    #[test]
    fn band_cols_covers_all_columns_in_whole_tiles() {
        assert_eq!(band_cols(100, &Pool::serial()), 100);
        for n in [1usize, 63, 64, 65, 257] {
            for workers in [2usize, 3, 7] {
                let b = band_cols(n, &Pool::new(workers));
                assert!(b >= 1);
                assert_eq!(b % TILE_N, 0, "pooled bands align to whole tiles");
            }
        }
    }

    #[test]
    fn tile_pool_matches_pool_width() {
        assert_eq!(TilePool::new(&Pool::serial()).workers(), 1);
        assert_eq!(TilePool::new(&Pool::new(6)).workers(), 6);
        // tile constants cover every packing group size
        for gr in [1usize, 2, 4, 8] {
            assert_eq!(TILE_K % gr, 0);
        }
    }

    #[test]
    fn tile_scratch_is_64_byte_aligned() {
        // the satellite contract: scratch tiles sit on cache-line (and zmm)
        // boundaries, are full-size, and come back zeroed
        let mut t = AlignedTile::new(TILE_K * TILE_N);
        let s = t.as_mut_slice();
        assert_eq!(s.as_ptr() as usize % 64, 0, "64-byte alignment");
        assert_eq!(s.len(), TILE_K * TILE_N);
        assert!(s.iter().all(|&v| v == 0.0), "alloc_zeroed");
        // and the slots a real kernel call touches are those same aligned
        // tiles, allocated lazily: none before the call, >= 1 after
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        assert!(
            tiles.bufs.iter().all(|b| b.lock().unwrap().is_none()),
            "construction allocates no tiles"
        );
        let (m, k, n) = (4usize, 32usize, 130usize);
        let a = rand_vec(m * k, 61, 0.5);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 62, 0.5)), Precision::Q8);
        let mut out = vec![0.0f32; m * n];
        matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
        let mut touched = 0usize;
        for b in &tiles.bufs {
            if let Some(t) = b.lock().unwrap().as_mut() {
                assert_eq!(t.as_mut_slice().as_ptr() as usize % 64, 0, "worker tile alignment");
                touched += 1;
            }
        }
        assert!(touched >= 1, "at least the claiming worker touched its tile");
    }

    #[test]
    fn prefetch_on_off_bit_identical() {
        // EWQ_PREFETCH is a pure scheduling hint: the auto-dispatched fused
        // GEMM and GEMV must produce identical bits with it on and off, for
        // every packed precision. Env-mutating, so it takes the simd env
        // lock like the other toggle tests.
        let _guard = crate::simd::env_lock();
        let (m, k, n) = (5usize, 48usize, 150usize);
        let a = rand_vec(m * k, 71, 0.8);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let old = std::env::var("EWQ_PREFETCH").ok();
        for prec in [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
            let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 72, 0.5)), prec);
            std::env::remove_var("EWQ_PREFETCH");
            let mut on = vec![f32::NAN; m * n];
            matmul_qmat(&a, &w, m, &pool, &tiles, &mut on);
            let mut gemv_on = vec![f32::NAN; n];
            matvec_qmat(&a[..k], &w, &pool, &tiles, &mut gemv_on);
            std::env::set_var("EWQ_PREFETCH", "0");
            let mut off = vec![f32::NAN; m * n];
            matmul_qmat(&a, &w, m, &pool, &tiles, &mut off);
            let mut gemv_off = vec![f32::NAN; n];
            matvec_qmat(&a[..k], &w, &pool, &tiles, &mut gemv_off);
            assert_bits_eq(&on, &off, &format!("{} gemm prefetch on vs off", prec.label()));
            assert_bits_eq(
                &gemv_on,
                &gemv_off,
                &format!("{} gemv prefetch on vs off", prec.label()),
            );
        }
        match old {
            Some(v) => std::env::set_var("EWQ_PREFETCH", v),
            None => std::env::remove_var("EWQ_PREFETCH"),
        }
    }

    #[test]
    fn ragged_tile_edges_bit_identical_across_paths() {
        // k and n deliberately NOT multiples of TILE_K/TILE_N: the partial
        // tiles at both edges drive the 16-lane AVX-512 unpacks (and the
        // 8-lane AVX2 ones) through their scalar tails, where a lane-width
        // bug would hide on round shapes
        for &(m, k, n) in &[(3usize, 40usize, 65usize), (5, 24, 63), (2, 56, 97), (4, 8, 15)] {
            assert!(k % 8 == 0 && k % TILE_K != 0 && n % TILE_N != 0, "shape picks its edge");
            let a = rand_vec(m * k, 500 + k as u64, 0.8);
            for prec in [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 600 + n as u64, 0.5)), prec);
                let serial_pool = Pool::serial();
                let serial_tiles = TilePool::new(&serial_pool);
                let mut baseline = vec![f32::NAN; m * n];
                matmul_qmat_with(
                    &a, &w, m, &serial_pool, &serial_tiles,
                    KernelPath::Scalar, Banding::Rows, &mut baseline,
                );
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    for path in PATHS {
                        for banding in BANDINGS {
                            let mut out = vec![f32::NAN; m * n];
                            matmul_qmat_with(&a, &w, m, &pool, &tiles, path, banding, &mut out);
                            assert_bits_eq(
                                &out,
                                &baseline,
                                &format!(
                                    "{} {m}x{k}x{n} w={workers} {}/{}",
                                    prec.label(),
                                    path.label(),
                                    banding.label()
                                ),
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn banding_labels() {
        assert_eq!(Banding::Rows.label(), "rows");
        assert_eq!(Banding::Cols.label(), "cols");
        assert_eq!(KernelPath::Scalar.label(), "scalar");
    }
}
