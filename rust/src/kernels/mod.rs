//! Fused quantized-GEMM kernels: cache-blocked matmuls that consume `QMat`
//! packed payloads directly, so a served replica never materializes (or
//! keeps resident) an f32 shadow copy of its quantized weights.
//!
//! Layout of one call (`matmul_qmat`, C = A·W with A `(m,k)` activations
//! row-major and W a packed `(k,n)` matrix):
//!
//! - the output is split into contiguous **row bands** distributed over the
//!   existing `par::Pool` (`Pool::par_bands_mut`) — each band is written by
//!   exactly one worker, so results are bit-identical for any worker count;
//! - inside a band, W is walked in `TILE_K × TILE_N` tiles. Each tile is
//!   group-unpacked (`quant::dequantize_tile`) into a per-worker scratch
//!   buffer (`TilePool`, 8 KiB — L1-resident) and then multiplied against
//!   the band's activation rows with a stride-1 inner loop;
//! - `k` is accumulated in ascending order for every output element, the
//!   same order as the serial reference matmul, so the fused kernel is
//!   **bit-identical** to `matmul(a, dequantize(w))` — quantization noise
//!   is preserved exactly and precision-ladder experiments are unaffected;
//! - `Payload::Raw` dispatches to `matmul_f32`, the k-tiled f32 kernel that
//!   reads the payload in place (no tile copy needed).
//!
//! Steady-state calls do zero heap allocation — tile buffers live in a
//! `TilePool` created once per executor (see `model::refexec::Scratch`) —
//! and zero thread spawns: `par::Pool` keeps its workers parked between
//! kernel invocations, so each call costs one publish + wake, not a
//! spawn/join barrier (see DESIGN.md §9).

use std::sync::Mutex;

use crate::par::Pool;
use crate::quant::{dequantize_tile, Payload, QMat};

/// Tile height along the reduction (`k`) dimension. A multiple of every
/// packing-group size (1/2/4/8 rows for Q8/Q4/T2/Q3), so every tile starts
/// and ends on a group boundary.
pub const TILE_K: usize = 32;
/// Tile width along the output (`n`) dimension; `TILE_K * TILE_N` f32 = 8 KiB.
pub const TILE_N: usize = 64;

/// Per-worker dequantization tile buffers, allocated once per executor and
/// reused by every `matmul_qmat` call — the scratch arena half that keeps
/// the fused kernels allocation-free in steady state. Each worker locks its
/// own (uncontended) slot once per band.
pub struct TilePool {
    bufs: Vec<Mutex<Vec<f32>>>,
}

impl TilePool {
    /// One `TILE_K * TILE_N` buffer per worker of `pool`.
    pub fn new(pool: &Pool) -> Self {
        Self {
            bufs: (0..pool.workers())
                .map(|_| Mutex::new(vec![0.0f32; TILE_K * TILE_N]))
                .collect(),
        }
    }

    pub fn workers(&self) -> usize {
        self.bufs.len()
    }
}

/// Rows per parallel band. Each band re-runs the tile unpack sweep, so
/// band count trades load balance against redundant dequantization
/// (overhead ratio ≈ tile-unpack cost / band rows): one band on a serial
/// pool (zero redundancy), two bands per worker pooled — enough for the
/// shared claim iterator to absorb skew while keeping the per-band unpack
/// amortized over a deep row block. Any band size yields identical bits —
/// every output element is produced whole inside one band.
fn band_rows(m: usize, pool: &Pool) -> usize {
    if pool.workers() <= 1 {
        return m.max(1);
    }
    m.div_ceil(pool.workers() * 2).max(1)
}

/// `out = a @ b` for plain f32 operands (`a` is `(m,k)`, `b` is `(k,n)`,
/// all row-major; `out` is overwritten). k-tiled for B-row reuse across the
/// band and row-banded over `pool`; `k` accumulates in ascending order, so
/// the result is bit-identical to the serial ikj reference for any worker
/// count and tile size.
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, pool: &Pool, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let band = band_rows(m, pool);
    pool.par_bands_mut(out, band * n, |_w, bi, chunk| {
        let r0 = bi * band;
        let rows = chunk.len() / n;
        chunk.fill(0.0);
        for k0 in (0..k).step_by(TILE_K) {
            let kh = TILE_K.min(k - k0);
            for ri in 0..rows {
                let arow = &a[(r0 + ri) * k + k0..(r0 + ri) * k + k0 + kh];
                let orow = &mut chunk[ri * n..(ri + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &b[(k0 + kk) * n..(k0 + kk + 1) * n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
}

/// `out = a @ w` where `w` is a packed `QMat` (`(k,n)` = `(w.rows, w.cols)`)
/// — the fused serving kernel: group-wise dequantization into per-worker
/// `TILE_K × TILE_N` scratch tiles, multiplied in place. Bit-identical to
/// `matmul_f32(a, dequantize(w))` for every precision and worker count.
/// `Payload::Raw` reads the payload directly through `matmul_f32`.
pub fn matmul_qmat(a: &[f32], w: &QMat, m: usize, pool: &Pool, tiles: &TilePool, out: &mut [f32]) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), m * k);
    assert_eq!(out.len(), m * n);
    if let Payload::Raw(d) = &w.payload {
        return matmul_f32(a, d, m, k, n, pool, out);
    }
    if m == 0 || n == 0 {
        return;
    }
    assert!(
        tiles.workers() >= pool.workers(),
        "TilePool sized for {} workers, pool has {}",
        tiles.workers(),
        pool.workers()
    );
    let band = band_rows(m, pool);
    pool.par_bands_mut(out, band * n, |wkr, bi, chunk| {
        let mut tile = tiles.bufs[wkr].lock().unwrap();
        let tile = tile.as_mut_slice();
        let r0 = bi * band;
        let rows = chunk.len() / n;
        chunk.fill(0.0);
        for k0 in (0..k).step_by(TILE_K) {
            let kh = TILE_K.min(k - k0);
            for n0 in (0..n).step_by(TILE_N) {
                let nw = TILE_N.min(n - n0);
                dequantize_tile(w, k0..k0 + kh, n0..n0 + nw, &mut tile[..kh * nw]);
                for ri in 0..rows {
                    let arow = &a[(r0 + ri) * k + k0..(r0 + ri) * k + k0 + kh];
                    let orow = &mut chunk[ri * n + n0..ri * n + n0 + nw];
                    for (kk, &av) in arow.iter().enumerate() {
                        let trow = &tile[kk * nw..(kk + 1) * nw];
                        for j in 0..nw {
                            orow[j] += av * trow[j];
                        }
                    }
                }
            }
        }
    });
}

/// Column band width for the GEMV kernels: the whole row serial, about two
/// bands per worker pooled, rounded up to whole `TILE_N` tiles so no dequant
/// tile is ever split across bands. Any band size yields identical bits —
/// every output element is produced whole inside one band, accumulating `k`
/// in ascending order.
fn band_cols(n: usize, pool: &Pool) -> usize {
    if pool.workers() <= 1 {
        return n.max(1);
    }
    n.div_ceil(pool.workers() * 2).div_ceil(TILE_N).max(1) * TILE_N
}

/// `out = a @ b` for a single activation row (`a` is length `k`, `b` is
/// `(k,n)` row-major, `out` length `n`) — the f32 decode GEMV. Column-banded
/// over `pool`; every output element accumulates `k` in ascending order, so
/// the result is **bit-identical** to `matmul_f32` on a 1-row input for any
/// worker count. Steady-state calls do zero heap allocation.
pub fn matvec_f32(a: &[f32], b: &[f32], k: usize, n: usize, pool: &Pool, out: &mut [f32]) {
    debug_assert_eq!(a.len(), k);
    debug_assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), n);
    if n == 0 {
        return;
    }
    let band = band_cols(n, pool);
    pool.par_bands_mut(out, band, |_w, bi, chunk| {
        let c0 = bi * band;
        let cw = chunk.len();
        chunk.fill(0.0);
        for (kk, &av) in a.iter().enumerate() {
            let brow = &b[kk * n + c0..kk * n + c0 + cw];
            for j in 0..cw {
                chunk[j] += av * brow[j];
            }
        }
    });
}

/// `out = a @ w` for a single activation row against a packed `QMat`
/// (`(k,n)` = `(w.rows, w.cols)`) — the fused decode GEMV: group-wise
/// dequantization into the same per-worker `TILE_K × TILE_N` scratch tiles
/// as `matmul_qmat`, multiplied in place. Column bands fan out on `pool`;
/// `k` accumulates in ascending order per output element, so the result is
/// **bit-identical** to `matmul_qmat` on a 1-row input (and hence to the
/// dequantize-then-matmul reference) for every precision and worker count.
/// `Payload::Raw` dispatches to `matvec_f32`.
pub fn matvec_qmat(a: &[f32], w: &QMat, pool: &Pool, tiles: &TilePool, out: &mut [f32]) {
    let (k, n) = (w.rows, w.cols);
    debug_assert_eq!(a.len(), k);
    assert_eq!(out.len(), n);
    if let Payload::Raw(d) = &w.payload {
        return matvec_f32(a, d, k, n, pool, out);
    }
    if n == 0 {
        return;
    }
    assert!(
        tiles.workers() >= pool.workers(),
        "TilePool sized for {} workers, pool has {}",
        tiles.workers(),
        pool.workers()
    );
    let band = band_cols(n, pool);
    pool.par_bands_mut(out, band, |wkr, bi, chunk| {
        let mut tile = tiles.bufs[wkr].lock().unwrap();
        let tile = tile.as_mut_slice();
        let c0 = bi * band;
        let cw = chunk.len();
        chunk.fill(0.0);
        for k0 in (0..k).step_by(TILE_K) {
            let kh = TILE_K.min(k - k0);
            for n0 in (0..cw).step_by(TILE_N) {
                let nw = TILE_N.min(cw - n0);
                dequantize_tile(w, k0..k0 + kh, c0 + n0..c0 + n0 + nw, &mut tile[..kh * nw]);
                let ochunk = &mut chunk[n0..n0 + nw];
                for kk in 0..kh {
                    let av = a[k0 + kk];
                    let trow = &tile[kk * nw..(kk + 1) * nw];
                    for j in 0..nw {
                        ochunk[j] += av * trow[j];
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::quant::{dequantize, quantize, Precision};
    use crate::rng::Xoshiro256pp;
    use crate::tensor::Tensor;

    /// The serial ikj reference the fused kernels must match bit-for-bit.
    fn reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn rand_vec(len: usize, seed: u64, std: f32) -> Vec<f32> {
        let mut r = Xoshiro256pp::new(seed);
        (0..len).map(|_| r.normal_f32(0.0, std)).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
        assert_eq!(a.len(), b.len(), "{ctx}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn f32_kernel_bit_identical_to_reference_any_worker_count() {
        // odd shapes on purpose: partial k-tiles, ragged bands
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 5), (13, 33, 19), (17, 96, 67)] {
            let a = rand_vec(m * k, 100 + m as u64, 0.7);
            let b = rand_vec(k * n, 200 + n as u64, 0.7);
            let expect = reference(&a, &b, m, k, n);
            for workers in [1usize, 2, 7] {
                let mut out = vec![f32::NAN; m * n];
                matmul_f32(&a, &b, m, k, n, &Pool::new(workers), &mut out);
                assert_bits_eq(&out, &expect, &format!("f32 {m}x{k}x{n} w={workers}"));
            }
        }
    }

    #[test]
    fn fused_kernels_match_dequantized_reference_every_precision() {
        // Property: for every format, odd (m,k,n) shapes, and 1/2/7 pool
        // workers, the fused packed-payload kernel equals the dequantize-
        // then-matmul reference within 1e-5 rel err (it is in fact
        // bit-identical; the looser bound is the documented contract).
        check(
            0xE1A9,
            24,
            8,
            |g| {
                let m = 2 * g.usize_in(0, 9) + 1; // odd 1..17
                let k = 8 * (2 * g.usize_in(0, 7) + 1); // 8 * odd: group-aligned for all formats
                let n = 2 * g.usize_in(0, 40) + 1; // odd 1..81
                let prec = [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2]
                    [g.usize_in(0, 4)];
                let seed = g.rng.next_u64();
                (m, k, n, prec, seed)
            },
            |&(m, k, n, prec, seed)| {
                let a = rand_vec(m * k, seed, 0.8);
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, seed ^ 1, 0.5)), prec);
                let wd = dequantize(&w);
                let expect = reference(&a, &wd.data, m, k, n);
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    let mut out = vec![f32::NAN; m * n];
                    matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
                    for (i, (f, r)) in out.iter().zip(&expect).enumerate() {
                        let tol = 1e-5 * r.abs().max(1.0);
                        if (f - r).abs() > tol {
                            return Err(format!(
                                "{} {m}x{k}x{n} w={workers} elem {i}: fused {f} vs ref {r}",
                                prec.label()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fused_kernel_is_exactly_deterministic_across_worker_counts() {
        let (m, k, n) = (13usize, 40usize, 37usize);
        let a = rand_vec(m * k, 7, 0.8);
        for prec in [Precision::Q8, Precision::Q4, Precision::Q3, Precision::T2] {
            let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 8, 0.5)), prec);
            let run = |workers: usize| {
                let pool = Pool::new(workers);
                let tiles = TilePool::new(&pool);
                let mut out = vec![0.0f32; m * n];
                matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
                out
            };
            let serial = run(1);
            // also bit-identical to the dequantized reference, not just bounded
            let expect = reference(&a, &dequantize(&w).data, m, k, n);
            assert_bits_eq(&serial, &expect, prec.label());
            for workers in [2usize, 3, 7, crate::config::ParallelConfig::test_workers(5)] {
                assert_bits_eq(&run(workers), &serial, &format!("{} w={workers}", prec.label()));
            }
        }
    }

    #[test]
    fn repeated_kernel_calls_reuse_parked_workers() {
        // the serving hot path: many matmul scopes against one pool must
        // spawn helpers exactly once (the persistent-pool invariant at the
        // kernel seam)
        let (m, k, n) = (9usize, 32usize, 21usize);
        let a = rand_vec(m * k, 31, 0.8);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 32, 0.5)), Precision::Q4);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let mut out = vec![0.0f32; m * n];
        for _ in 0..10 {
            matmul_qmat(&a, &w, m, &pool, &tiles, &mut out);
        }
        assert_eq!(pool.spawn_events(), 2, "workers - 1 spawns across 10 kernel calls");
    }

    #[test]
    fn raw_payload_dispatches_through_f32_kernel() {
        let (m, k, n) = (5usize, 24usize, 11usize);
        let a = rand_vec(m * k, 21, 0.6);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 22, 0.6)), Precision::Raw);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let mut fused = vec![0.0f32; m * n];
        matmul_qmat(&a, &w, m, &pool, &tiles, &mut fused);
        let expect = reference(&a, &dequantize(&w).data, m, k, n);
        assert_bits_eq(&fused, &expect, "raw");
    }

    #[test]
    fn matvec_f32_bit_identical_to_matmul_on_one_row() {
        // odd widths on purpose: partial column bands and tiles
        for &(k, n) in &[(1usize, 1usize), (7, 5), (33, 19), (96, 131), (40, 257)] {
            let a = rand_vec(k, 300 + k as u64, 0.7);
            let b = rand_vec(k * n, 400 + n as u64, 0.7);
            let mut expect = vec![f32::NAN; n];
            matmul_f32(&a, &b, 1, k, n, &Pool::serial(), &mut expect);
            for workers in [1usize, 2, 7, crate::config::ParallelConfig::test_workers(3)] {
                let mut out = vec![f32::NAN; n];
                matvec_f32(&a, &b, k, n, &Pool::new(workers), &mut out);
                assert_bits_eq(&out, &expect, &format!("matvec f32 {k}x{n} w={workers}"));
            }
        }
    }

    #[test]
    fn matvec_qmat_bit_identical_to_matmul_on_one_row_every_precision() {
        // Property: for every format (incl. Raw dispatch), group-aligned k,
        // odd n, and 1/2/7 pool workers, the fused GEMV equals matmul_qmat
        // on a 1-row input bit-for-bit — the decode path's kernel contract.
        check(
            0xDEC0,
            24,
            8,
            |g| {
                let k = 8 * (2 * g.usize_in(0, 7) + 1); // 8 * odd: group-aligned
                let n = 2 * g.usize_in(0, 80) + 1; // odd 1..161
                let prec = [
                    Precision::Raw,
                    Precision::Q8,
                    Precision::Q4,
                    Precision::Q3,
                    Precision::T2,
                ][g.usize_in(0, 5)];
                let seed = g.rng.next_u64();
                (k, n, prec, seed)
            },
            |&(k, n, prec, seed)| {
                let a = rand_vec(k, seed, 0.8);
                let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, seed ^ 1, 0.5)), prec);
                let serial_pool = Pool::serial();
                let serial_tiles = TilePool::new(&serial_pool);
                let mut expect = vec![f32::NAN; n];
                matmul_qmat(&a, &w, 1, &serial_pool, &serial_tiles, &mut expect);
                for workers in [1usize, 2, 7] {
                    let pool = Pool::new(workers);
                    let tiles = TilePool::new(&pool);
                    let mut out = vec![f32::NAN; n];
                    matvec_qmat(&a, &w, &pool, &tiles, &mut out);
                    for (i, (f, r)) in out.iter().zip(&expect).enumerate() {
                        if f.to_bits() != r.to_bits() {
                            return Err(format!(
                                "{} {k}x{n} w={workers} elem {i}: gemv {f} vs gemm {r}",
                                prec.label()
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn matvec_reuses_parked_workers_and_tiles() {
        // the decode hot path: many GEMV scopes against one pool must spawn
        // helpers exactly once and never allocate tile buffers
        let (k, n) = (32usize, 97usize);
        let a = rand_vec(k, 51, 0.8);
        let w = quantize(&Tensor::new(vec![k, n], rand_vec(k * n, 52, 0.5)), Precision::Q4);
        let pool = Pool::new(3);
        let tiles = TilePool::new(&pool);
        let mut out = vec![0.0f32; n];
        for _ in 0..10 {
            matvec_qmat(&a, &w, &pool, &tiles, &mut out);
        }
        assert_eq!(pool.spawn_events(), 2, "workers - 1 spawns across 10 GEMV calls");
    }

    #[test]
    fn band_cols_covers_all_columns_in_whole_tiles() {
        assert_eq!(band_cols(100, &Pool::serial()), 100);
        for n in [1usize, 63, 64, 65, 257] {
            for workers in [2usize, 3, 7] {
                let b = band_cols(n, &Pool::new(workers));
                assert!(b >= 1);
                assert_eq!(b % TILE_N, 0, "pooled bands align to whole tiles");
            }
        }
    }

    #[test]
    fn tile_pool_matches_pool_width() {
        assert_eq!(TilePool::new(&Pool::serial()).workers(), 1);
        assert_eq!(TilePool::new(&Pool::new(6)).workers(), 6);
        // tile constants cover every packing group size
        for gr in [1usize, 2, 4, 8] {
            assert_eq!(TILE_K % gr, 0);
        }
    }
}
