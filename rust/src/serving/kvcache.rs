//! Paged KV-cache manager with entropy-style precision tiers — the paper's
//! §7 "System Integration / KV cache compression" future-work direction,
//! built as a real substrate: page-granular allocation (vLLM-flavored),
//! per-sequence page tables, and quantized page storage (fp32 / int8 /
//! int4) with the same symmetric per-column scheme as the weight formats.
//!
//! This is the storage half of the incremental decode path (DESIGN.md §10):
//! `refexec::decode_step` appends one token's K/V per block via `append`
//! and reads the attention history back through `read_into`, so generated
//! tokens never recompute the full sequence. The hot-path contract is
//! **allocation-free steady state**: `read_into` writes into a caller
//! buffer, and a sequence whose pages were `reserve`d up front never
//! allocates inside `append`.

use crate::quant::Precision;

/// Typed KV-cache failures. Budget exhaustion is an *admission* signal the
/// serving layer turns into a terminal `Status::KvExhausted` — never a
/// stringly-typed surprise mid-stream. Implements `std::error::Error`, so
/// `?` still lifts it into the executor's `anyhow::Result` plumbing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The allocation/reservation would exceed the configured byte budget.
    BudgetExhausted { needed: usize, allocated: usize, budget: usize },
    /// A KV slice had the wrong number of floats for the cache geometry.
    BadKvLength { got: usize, want: usize },
    /// No page table exists for this sequence id.
    UnknownSequence(u64),
    /// The requested token index has not been appended yet.
    TokenNotWritten { token: usize, have: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::BudgetExhausted { needed, allocated, budget } => write!(
                f,
                "kv-cache budget exhausted ({allocated} + {needed} > {budget})"
            ),
            KvError::BadKvLength { got, want } => {
                write!(f, "kv length {got} != geometry {want}")
            }
            KvError::UnknownSequence(seq) => write!(f, "unknown seq {seq}"),
            KvError::TokenNotWritten { token, have } => {
                write!(f, "token {token} not written yet ({have} in sequence)")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Fixed page geometry: `page_tokens` KV slots of `head_dim * n_heads * 2`
/// (K and V) floats each.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub page_tokens: usize,
    pub n_heads: usize,
    pub head_dim: usize,
}

impl KvGeometry {
    pub fn floats_per_token(&self) -> usize {
        2 * self.n_heads * self.head_dim
    }

    pub fn page_bytes(&self, prec: Precision) -> usize {
        let floats = self.page_tokens * self.floats_per_token();
        match prec {
            Precision::Raw => 4 * floats,
            Precision::Q8 => floats + 4 * self.floats_per_token(), // + scale/token-col
            Precision::Q4 => floats / 2 + 4 * self.floats_per_token(),
            Precision::Q3 | Precision::T2 => floats / 2 + 4 * self.floats_per_token(),
        }
    }
}

#[derive(Clone, Debug)]
struct Page {
    data: Vec<u8>,
    prec: Precision,
    used_tokens: usize,
}

/// One sequence's page table: the pages in token order (possibly reserved
/// ahead of the write cursor) plus the number of tokens appended so far.
#[derive(Clone, Debug, Default)]
struct SeqTable {
    pages: Vec<usize>,
    tokens: usize,
}

/// Page-granular KV cache for many concurrent sequences.
pub struct KvCache {
    geom: KvGeometry,
    budget_bytes: usize,
    allocated_bytes: usize,
    /// High-water mark of `allocated_bytes` (serving telemetry:
    /// `ServingMetrics::kv_bytes`).
    peak_bytes: usize,
    pages: Vec<Option<Page>>,
    free_list: Vec<usize>,
    /// sequence id -> page table
    tables: std::collections::BTreeMap<u64, SeqTable>,
    prec: Precision,
}

impl KvCache {
    pub fn new(geom: KvGeometry, budget_bytes: usize, prec: Precision) -> Self {
        // construction-time guard: the page codec implements exactly these
        // three tiers (serving validates its config against the same set
        // before any shard spawns)
        assert!(
            matches!(prec, Precision::Raw | Precision::Q8 | Precision::Q4),
            "KvCache supports raw/8bit/4bit pages, not {}",
            prec.label()
        );
        Self {
            geom,
            budget_bytes,
            allocated_bytes: 0,
            peak_bytes: 0,
            pages: Vec::new(),
            free_list: Vec::new(),
            tables: std::collections::BTreeMap::new(),
            prec,
        }
    }

    pub fn allocated_bytes(&self) -> usize {
        self.allocated_bytes
    }

    /// High-water mark of `allocated_bytes` over the cache's lifetime.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn live_sequences(&self) -> usize {
        self.tables.len()
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geom
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Tokens appended to `seq` so far (0 for unknown sequences).
    pub fn sequence_tokens(&self, seq: u64) -> usize {
        self.tables.get(&seq).map(|t| t.tokens).unwrap_or(0)
    }

    fn alloc_page(&mut self) -> Result<usize, KvError> {
        let bytes = self.geom.page_bytes(self.prec);
        if self.allocated_bytes + bytes > self.budget_bytes {
            return Err(KvError::BudgetExhausted {
                needed: bytes,
                allocated: self.allocated_bytes,
                budget: self.budget_bytes,
            });
        }
        if let Some(id) = self.free_list.pop() {
            self.pages[id] =
                Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0 });
            self.allocated_bytes += bytes;
            self.peak_bytes = self.peak_bytes.max(self.allocated_bytes);
            return Ok(id);
        }
        self.pages.push(Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0 }));
        self.allocated_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.allocated_bytes);
        Ok(self.pages.len() - 1)
    }

    /// Pre-allocate enough pages for `seq` to hold `tokens` tokens, so the
    /// subsequent `append`s are allocation-free (the decode hot path
    /// reserves a sequence's window up front and then never touches the
    /// allocator mid-generation). Fails — without allocating anything —
    /// when the reservation would exceed the budget.
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        let have = self.tables.get(&seq).map(|t| t.pages.len()).unwrap_or(0);
        let need = tokens.div_ceil(self.geom.page_tokens);
        if need > have {
            let extra = need - have;
            let bytes = self.geom.page_bytes(self.prec);
            if self.allocated_bytes + extra * bytes > self.budget_bytes {
                return Err(KvError::BudgetExhausted {
                    needed: extra * bytes,
                    allocated: self.allocated_bytes,
                    budget: self.budget_bytes,
                });
            }
            for _ in 0..extra {
                let pid = self.alloc_page()?;
                self.tables.entry(seq).or_default().pages.push(pid);
            }
        }
        Ok(())
    }

    /// Append `kv` (one token's K+V floats) to a sequence, allocating pages
    /// on demand (or filling `reserve`d ones). Quantizes into the page
    /// store per the cache precision.
    pub fn append(&mut self, seq: u64, kv: &[f32]) -> Result<(), KvError> {
        if kv.len() != self.geom.floats_per_token() {
            return Err(KvError::BadKvLength {
                got: kv.len(),
                want: self.geom.floats_per_token(),
            });
        }
        let tokens = self.sequence_tokens(seq);
        let page_no = tokens / self.geom.page_tokens;
        let slot = tokens % self.geom.page_tokens;
        if page_no >= self.tables.get(&seq).map(|t| t.pages.len()).unwrap_or(0) {
            let pid = self.alloc_page()?;
            self.tables.entry(seq).or_default().pages.push(pid);
        }
        let table = self.tables.get_mut(&seq).unwrap();
        let pid = table.pages[page_no];
        table.tokens += 1;
        let geom = self.geom;
        let page = self.pages[pid].as_mut().unwrap();
        encode_token(page, slot, kv, &geom);
        page.used_tokens = page.used_tokens.max(slot + 1);
        Ok(())
    }

    /// Read a token's KV back (dequantized) into `out`
    /// (`geometry().floats_per_token()` floats) without allocating — the
    /// decode hot path's history read.
    pub fn read_into(&self, seq: u64, token_idx: usize, out: &mut [f32]) -> Result<(), KvError> {
        if out.len() != self.geom.floats_per_token() {
            return Err(KvError::BadKvLength {
                got: out.len(),
                want: self.geom.floats_per_token(),
            });
        }
        let table = self.tables.get(&seq).ok_or(KvError::UnknownSequence(seq))?;
        if token_idx >= table.tokens {
            return Err(KvError::TokenNotWritten { token: token_idx, have: table.tokens });
        }
        let page_no = token_idx / self.geom.page_tokens;
        let slot = token_idx % self.geom.page_tokens;
        let pid = table.pages[page_no];
        let page = self.pages[pid].as_ref().unwrap();
        decode_token_into(page, slot, &self.geom, out);
        Ok(())
    }

    /// Read a token's KV back (dequantized). Allocating convenience wrapper
    /// over `read_into` (tests/inspection; the hot path uses `read_into`).
    pub fn read(&self, seq: u64, token_idx: usize) -> Result<Vec<f32>, KvError> {
        let mut out = vec![0.0f32; self.geom.floats_per_token()];
        self.read_into(seq, token_idx, &mut out)?;
        Ok(out)
    }

    /// Free all pages of a sequence.
    pub fn release(&mut self, seq: u64) {
        if let Some(table) = self.tables.remove(&seq) {
            for pid in table.pages {
                if let Some(p) = self.pages[pid].take() {
                    self.allocated_bytes -= self.geom.page_bytes(p.prec);
                    self.free_list.push(pid);
                }
            }
        }
    }

    /// Bytes one full sequence of `tokens` costs at this precision.
    pub fn sequence_bytes(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.geom.page_tokens) * self.geom.page_bytes(self.prec)
    }
}

fn encode_token(page: &mut Page, slot: usize, kv: &[f32], geom: &KvGeometry) {
    let f = geom.floats_per_token();
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + 4 * i..base + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        Precision::Q8 => {
            // per-token symmetric scale stored in the page tail
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 127.0;
            let base = slot * f;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + i] = ((v / scale).round().clamp(-127.0, 127.0) as i8) as u8;
            }
            let tail = geom.page_tokens * f + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        Precision::Q4 => {
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 7.0;
            let base = slot * f / 2;
            for i in 0..f / 2 {
                let lo = (kv[2 * i] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                let hi = (kv[2 * i + 1] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                page.data[base + i] = (lo | (hi << 4)) as u8;
            }
            let tail = geom.page_tokens * f / 2 + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        _ => unreachable!(),
    }
}

fn decode_token_into(page: &Page, slot: usize, geom: &KvGeometry, out: &mut [f32]) {
    let f = geom.floats_per_token();
    debug_assert_eq!(out.len(), f);
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            for (i, o) in out.iter_mut().enumerate() {
                *o = f32::from_le_bytes(
                    page.data[base + 4 * i..base + 4 * i + 4].try_into().unwrap(),
                );
            }
        }
        Precision::Q8 => {
            let tail = geom.page_tokens * f + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f;
            for (i, o) in out.iter_mut().enumerate() {
                *o = (page.data[base + i] as i8) as f32 * scale;
            }
        }
        Precision::Q4 => {
            let tail = geom.page_tokens * f / 2 + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f / 2;
            for i in 0..f / 2 {
                let b = page.data[base + i] as i32;
                out[2 * i] = ((b & 0xF) - 8) as f32 * scale;
                out[2 * i + 1] = (((b >> 4) & 0xF) - 8) as f32 * scale;
            }
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::rng::Xoshiro256pp;

    fn geom() -> KvGeometry {
        KvGeometry { page_tokens: 4, n_heads: 2, head_dim: 8 }
    }

    #[test]
    fn roundtrip_raw_exact() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32 * 0.5 - 3.0).collect();
        c.append(1, &kv).unwrap();
        assert_eq!(c.read(1, 0).unwrap(), kv);
        assert_eq!(c.sequence_tokens(1), 1);
        assert_eq!(c.sequence_tokens(99), 0);
    }

    #[test]
    fn roundtrip_q8_bounded_error() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let mut rng = Xoshiro256pp::new(1);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.append(7, &kv).unwrap();
        let back = c.read(7, 0).unwrap();
        let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in kv.iter().zip(&back) {
            assert!((a - b).abs() <= maxabs / 127.0 * 0.51 + 1e-6);
        }
    }

    #[test]
    fn q4_cache_is_smaller_than_q8_than_raw() {
        let g = geom();
        let raw = KvCache::new(g, 1 << 30, Precision::Raw).sequence_bytes(128);
        let q8 = KvCache::new(g, 1 << 30, Precision::Q8).sequence_bytes(128);
        let q4 = KvCache::new(g, 1 << 30, Precision::Q4).sequence_bytes(128);
        assert!(raw > q8 && q8 > q4, "{raw} {q8} {q4}");
    }

    #[test]
    fn pages_allocate_on_demand_and_release() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv = vec![0.5f32; g.floats_per_token()];
        for _ in 0..9 {
            c.append(3, &kv).unwrap(); // 9 tokens -> 3 pages of 4
        }
        assert_eq!(c.allocated_bytes(), 3 * g.page_bytes(Precision::Q8));
        assert_eq!(c.live_sequences(), 1);
        c.release(3);
        assert_eq!(c.allocated_bytes(), 0);
        assert_eq!(c.peak_bytes(), 3 * g.page_bytes(Precision::Q8), "peak survives release");
        assert_eq!(c.live_sequences(), 0);
        assert!(c.read(3, 0).is_err());
    }

    #[test]
    fn budget_is_enforced_and_freed_pages_are_reused() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, 2 * one_page, Precision::Q8);
        let kv = vec![0.1f32; g.floats_per_token()];
        for _ in 0..8 {
            c.append(1, &kv).unwrap(); // fills 2 pages exactly
        }
        assert!(c.append(1, &kv).is_err(), "third page must exceed budget");
        c.release(1);
        for _ in 0..8 {
            c.append(2, &kv).unwrap(); // reuses the freed pages
        }
        assert_eq!(c.allocated_bytes(), 2 * one_page);
        assert_eq!(c.peak_bytes(), 2 * one_page, "reuse never exceeded the budget");
    }

    #[test]
    fn reserve_preallocates_and_appends_fill_reserved_pages() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        c.reserve(5, 10).unwrap(); // 3 pages of 4
        let reserved = c.allocated_bytes();
        assert_eq!(reserved, 3 * g.page_bytes(Precision::Raw));
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32).collect();
        for t in 0..10 {
            c.append(5, &kv).unwrap();
            assert_eq!(c.sequence_tokens(5), t + 1);
            // reserved pages are filled, never re-allocated
            assert_eq!(c.allocated_bytes(), reserved);
        }
        assert_eq!(c.read(5, 9).unwrap(), kv);
        // reserving less than what exists is a no-op
        c.reserve(5, 4).unwrap();
        assert_eq!(c.allocated_bytes(), reserved);
        // tokens 11..12 still fit the 3 reserved pages (12 slots); the 13th
        // goes past the reservation and allocates a fourth page on demand
        c.append(5, &kv).unwrap();
        c.append(5, &kv).unwrap();
        assert_eq!(c.allocated_bytes(), reserved, "12 tokens fill 3 pages exactly");
        c.append(5, &kv).unwrap();
        assert_eq!(c.allocated_bytes(), 4 * g.page_bytes(Precision::Raw));
    }

    #[test]
    fn reserve_past_budget_fails_without_allocating() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, 2 * one_page, Precision::Q8);
        assert!(c.reserve(1, 12).is_err(), "3 pages exceed a 2-page budget");
        assert_eq!(c.allocated_bytes(), 0, "failed reservation must not leak pages");
        assert_eq!(c.live_sequences(), 0);
        // a fitting reservation still works afterwards
        c.reserve(1, 8).unwrap();
        assert_eq!(c.allocated_bytes(), 2 * one_page);
    }

    #[test]
    fn read_into_matches_read_and_rejects_bad_lengths() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q4);
        let mut rng = Xoshiro256pp::new(9);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.append(2, &kv).unwrap();
        let mut buf = vec![0.0f32; g.floats_per_token()];
        c.read_into(2, 0, &mut buf).unwrap();
        assert_eq!(buf, c.read(2, 0).unwrap());
        let mut short = vec![0.0f32; 3];
        assert!(c.read_into(2, 0, &mut short).is_err());
        assert!(c.read_into(2, 1, &mut buf).is_err(), "token 1 not written yet");
        assert!(c.read_into(3, 0, &mut buf).is_err(), "unknown sequence");
    }

    #[test]
    fn release_mid_stream_keeps_other_sequences_intact() {
        // the "page eviction mid-sequence" edge: one sequence is evicted
        // while its neighbours keep appending; the freed pages are recycled
        // into the survivors without clobbering their history
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let tok = |s: u64, t: usize| -> Vec<f32> {
            (0..g.floats_per_token())
                .map(|i| (s as f32) * 100.0 + t as f32 + i as f32 * 0.01)
                .collect()
        };
        for t in 0..6 {
            for s in [1u64, 2, 3] {
                c.append(s, &tok(s, t)).unwrap();
            }
        }
        let before = c.allocated_bytes();
        c.release(2); // evict the middle sequence mid-stream
        assert_eq!(c.live_sequences(), 2);
        assert!(c.allocated_bytes() < before);
        assert!(c.read(2, 0).is_err(), "evicted sequence is gone");
        // survivors keep their full history and can keep appending into
        // the recycled pages
        for t in 6..12 {
            c.append(1, &tok(1, t)).unwrap();
            c.append(3, &tok(3, t)).unwrap();
        }
        for t in 0..12 {
            assert_eq!(c.read(1, t).unwrap(), tok(1, t), "seq 1 tok {t}");
            assert_eq!(c.read(3, t).unwrap(), tok(3, t), "seq 3 tok {t}");
        }
        assert!(c.allocated_bytes() <= before + 2 * g.page_bytes(Precision::Raw));
    }

    #[test]
    fn capacity_exhaustion_mid_sequence_leaves_history_readable() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, one_page, Precision::Q8);
        let kv = vec![0.25f32; g.floats_per_token()];
        for _ in 0..4 {
            c.append(1, &kv).unwrap();
        }
        // the 5th token needs a second page: clean error, nothing corrupted
        assert!(c.append(1, &kv).is_err());
        assert_eq!(c.sequence_tokens(1), 4, "failed append must not advance the cursor");
        for t in 0..4 {
            let back = c.read(1, t).unwrap();
            assert!(back.iter().all(|v| (v - 0.25).abs() < 0.01), "tok {t} readable after error");
        }
        // releasing recovers capacity for the next sequence
        c.release(1);
        for _ in 0..4 {
            c.append(2, &kv).unwrap();
        }
    }

    #[test]
    fn sequence_bytes_is_monotone_in_tokens_and_precision() {
        let g = geom();
        let caches = [
            KvCache::new(g, 1 << 30, Precision::Raw),
            KvCache::new(g, 1 << 30, Precision::Q8),
            KvCache::new(g, 1 << 30, Precision::Q4),
        ];
        for tokens in 0..64usize {
            // monotone (non-decreasing) in sequence length, page-quantized
            for c in &caches {
                assert!(c.sequence_bytes(tokens + 1) >= c.sequence_bytes(tokens));
            }
            // the precision ladder orders byte costs at every length
            if tokens > 0 {
                let raw = caches[0].sequence_bytes(tokens);
                let q8 = caches[1].sequence_bytes(tokens);
                let q4 = caches[2].sequence_bytes(tokens);
                assert!(raw > q8 && q8 > q4, "tokens={tokens}: {raw} {q8} {q4}");
            }
        }
        // page quantization: a page boundary is where the cost steps
        let c = &caches[0];
        assert_eq!(c.sequence_bytes(1), c.sequence_bytes(g.page_tokens));
        assert!(c.sequence_bytes(g.page_tokens + 1) > c.sequence_bytes(g.page_tokens));
    }

    #[test]
    fn batched_retirement_churn_keeps_page_accounting_consistent() {
        // the continuous-batching lifecycle (DESIGN.md §12): sequences join
        // and leave the decode cohort at step boundaries while the
        // survivors keep appending. After every admission/retirement the
        // page accounting must stay exact: allocated_bytes is the live page
        // count times the page size, every non-live page sits on the free
        // list, and steady-state churn recycles pages instead of growing
        // the backing store.
        let g = geom();
        let window = 12usize; // 3 pages of 4 tokens
        let pages_per_seq = window.div_ceil(g.page_tokens);
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv = vec![0.5f32; g.floats_per_token()];
        let check_books = |c: &KvCache| {
            let live_pages = c.pages.iter().filter(|p| p.is_some()).count();
            assert_eq!(c.allocated_bytes(), live_pages * g.page_bytes(Precision::Q8));
            assert_eq!(c.pages.len(), live_pages + c.free_list.len(), "page is live xor free");
        };
        let cohort = 4u64;
        for s in 0..12u64 {
            // admit sequence s with a full reserved window, retire the
            // oldest cohort member (admission before retirement, like a
            // shard gathering the next step's batch)
            c.reserve(s, window).unwrap();
            check_books(&c);
            if s >= cohort {
                c.release(s - cohort);
                check_books(&c);
            }
            // every live sequence appends one token — allocation-free into
            // its reserved pages
            let before = c.allocated_bytes();
            for live in s.saturating_sub(cohort - 1)..=s {
                c.append(live, &kv).unwrap();
            }
            assert_eq!(c.allocated_bytes(), before, "round {s}: appends fill reserved pages");
            // the backing store is bounded by the peak cohort (one extra
            // sequence lives briefly between admission and retirement)
            assert!(
                c.pages.len() <= (cohort as usize + 1) * pages_per_seq,
                "round {s}: churn must recycle pages, got {}",
                c.pages.len()
            );
        }
        assert_eq!(c.live_sequences(), cohort as usize);
        assert_eq!(c.sequence_bytes(window), pages_per_seq * g.page_bytes(Precision::Q8));
        for s in 8..12u64 {
            c.release(s);
            check_books(&c);
        }
        assert_eq!(c.allocated_bytes(), 0, "full retirement returns every byte");
        assert_eq!(c.pages.len(), c.free_list.len(), "and parks every page on the free list");
    }

    #[test]
    fn failed_mid_cohort_reserve_leaks_no_pages_and_is_typed() {
        // batched admission: a cohort of sequences reserves one after
        // another until the budget runs out mid-cohort. The failing reserve
        // must (a) surface a typed BudgetExhausted — the signal the serving
        // layer maps to Status::KvExhausted — and (b) leak nothing: the
        // already-admitted members keep their exact reservations, the page
        // books stay balanced, and releasing the cohort returns every byte.
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let window = 8usize; // 2 pages of 4 tokens per sequence
        let pages_per_seq = window.div_ceil(g.page_tokens);
        // room for exactly 2 full windows plus one stray page: the 3rd
        // cohort member fails part-way through the budget, not at zero
        let mut c = KvCache::new(g, (2 * pages_per_seq + 1) * one_page, Precision::Q8);
        let check_books = |c: &KvCache| {
            let live_pages = c.pages.iter().filter(|p| p.is_some()).count();
            assert_eq!(c.allocated_bytes(), live_pages * one_page);
            assert_eq!(c.pages.len(), live_pages + c.free_list.len(), "page is live xor free");
        };
        c.reserve(0, window).unwrap();
        c.reserve(1, window).unwrap();
        let before = c.allocated_bytes();
        check_books(&c);
        let err = c.reserve(2, window).unwrap_err();
        assert_eq!(
            err,
            KvError::BudgetExhausted {
                needed: pages_per_seq * one_page,
                allocated: before,
                budget: (2 * pages_per_seq + 1) * one_page,
            },
            "mid-cohort exhaustion is a typed admission error"
        );
        assert_eq!(c.allocated_bytes(), before, "failed reserve must not allocate");
        assert_eq!(c.live_sequences(), 2, "the failed sequence seats no page table");
        check_books(&c);
        // the admitted members still own their full allocation-free windows
        let kv = vec![0.5f32; g.floats_per_token()];
        for s in [0u64, 1] {
            for _ in 0..window {
                c.append(s, &kv).unwrap();
            }
        }
        assert_eq!(c.allocated_bytes(), before, "appends fill the reserved pages");
        check_books(&c);
        c.release(0);
        c.release(1);
        check_books(&c);
        assert_eq!(c.allocated_bytes(), 0, "full retirement returns every byte");
    }

    #[test]
    fn batched_history_reads_do_zero_heap_allocation() {
        // the fused decode step re-reads every live sequence's full
        // attention history through read_into each step; the whole sweep
        // must stay off the allocator (same counting-allocator hook as the
        // refexec steady-state tests)
        use crate::model::refexec::alloc_hook;
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32 * 0.1 - 0.8).collect();
        for s in 0..3u64 {
            c.reserve(s, 8).unwrap();
            for _ in 0..8 {
                c.append(s, &kv).unwrap();
            }
        }
        let mut buf = vec![0.0f32; g.floats_per_token()];
        c.read_into(0, 0, &mut buf).unwrap(); // warm any lazy TLS
        let before = alloc_hook::thread_allocs();
        for s in 0..3u64 {
            for t in 0..8 {
                c.read_into(s, t, &mut buf).unwrap();
            }
        }
        let after = alloc_hook::thread_allocs();
        assert_eq!(after - before, 0, "batched read_into must not allocate");
        assert!(buf.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn property_interleaved_sequences_are_isolated() {
        check(
            5,
            25,
            6,
            |gen| {
                let n_seqs = gen.usize_in(1, 4);
                let tokens = gen.usize_in(1, 10);
                let seed = gen.usize_in(0, 1 << 30) as u64;
                (n_seqs, tokens, seed)
            },
            |&(n_seqs, tokens, seed)| {
                let g = geom();
                let mut c = KvCache::new(g, 1 << 22, Precision::Raw);
                let mut rng = Xoshiro256pp::new(seed);
                let mut expect: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_seqs];
                for t in 0..tokens {
                    for s in 0..n_seqs {
                        let kv: Vec<f32> =
                            (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        c.append(s as u64, &kv).map_err(|e| e.to_string())?;
                        expect[s].push(kv);
                        let back = c.read(s as u64, t).map_err(|e| e.to_string())?;
                        if back != expect[s][t] {
                            return Err(format!("seq {s} tok {t} mismatch"));
                        }
                    }
                }
                // re-verify everything at the end (no cross-sequence clobber)
                for s in 0..n_seqs {
                    for t in 0..tokens {
                        if c.read(s as u64, t).map_err(|e| e.to_string())? != expect[s][t] {
                            return Err(format!("late mismatch seq {s} tok {t}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
