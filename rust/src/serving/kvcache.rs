//! Paged KV-cache manager with entropy-style precision tiers — the paper's
//! §7 "System Integration / KV cache compression" future-work direction,
//! built as a real substrate: page-granular allocation (vLLM-flavored),
//! per-sequence page tables, and quantized page storage (fp32 / int8 /
//! int4) with the same symmetric per-column scheme as the weight formats.
//!
//! The demo decode path recomputes full sequences (seq_len 32), so this
//! manager is exercised by the test/bench surface and by the cluster
//! planner's memory accounting rather than the tiny-model hot loop.

use anyhow::{bail, Result};

use crate::quant::Precision;

/// Fixed page geometry: `page_tokens` KV slots of `head_dim * n_heads * 2`
/// (K and V) floats each.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub page_tokens: usize,
    pub n_heads: usize,
    pub head_dim: usize,
}

impl KvGeometry {
    pub fn floats_per_token(&self) -> usize {
        2 * self.n_heads * self.head_dim
    }

    pub fn page_bytes(&self, prec: Precision) -> usize {
        let floats = self.page_tokens * self.floats_per_token();
        match prec {
            Precision::Raw => 4 * floats,
            Precision::Q8 => floats + 4 * self.floats_per_token(), // + scale/token-col
            Precision::Q4 => floats / 2 + 4 * self.floats_per_token(),
            Precision::Q3 | Precision::T2 => floats / 2 + 4 * self.floats_per_token(),
        }
    }
}

#[derive(Clone, Debug)]
struct Page {
    data: Vec<u8>,
    prec: Precision,
    used_tokens: usize,
}

/// Page-granular KV cache for many concurrent sequences.
pub struct KvCache {
    geom: KvGeometry,
    budget_bytes: usize,
    allocated_bytes: usize,
    pages: Vec<Option<Page>>,
    free_list: Vec<usize>,
    /// sequence id -> page ids in order
    tables: std::collections::BTreeMap<u64, Vec<usize>>,
    prec: Precision,
}

impl KvCache {
    pub fn new(geom: KvGeometry, budget_bytes: usize, prec: Precision) -> Self {
        assert!(matches!(prec, Precision::Raw | Precision::Q8 | Precision::Q4));
        Self {
            geom,
            budget_bytes,
            allocated_bytes: 0,
            pages: Vec::new(),
            free_list: Vec::new(),
            tables: std::collections::BTreeMap::new(),
            prec,
        }
    }

    pub fn allocated_bytes(&self) -> usize {
        self.allocated_bytes
    }

    pub fn live_sequences(&self) -> usize {
        self.tables.len()
    }

    fn alloc_page(&mut self) -> Result<usize> {
        let bytes = self.geom.page_bytes(self.prec);
        if let Some(id) = self.free_list.pop() {
            self.pages[id] =
                Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0 });
            self.allocated_bytes += bytes;
            return Ok(id);
        }
        if self.allocated_bytes + bytes > self.budget_bytes {
            bail!("kv-cache budget exhausted ({} + {bytes} > {})", self.allocated_bytes, self.budget_bytes);
        }
        self.pages.push(Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0 }));
        self.allocated_bytes += bytes;
        Ok(self.pages.len() - 1)
    }

    /// Append `kv` (one token's K+V floats) to a sequence, allocating pages
    /// on demand. Quantizes into the page store per the cache precision.
    pub fn append(&mut self, seq: u64, kv: &[f32]) -> Result<()> {
        if kv.len() != self.geom.floats_per_token() {
            bail!("kv length {} != geometry {}", kv.len(), self.geom.floats_per_token());
        }
        let need_new = match self.tables.get(&seq).and_then(|t| t.last()) {
            None => true,
            Some(&pid) => {
                self.pages[pid].as_ref().map(|p| p.used_tokens >= self.geom.page_tokens).unwrap_or(true)
            }
        };
        if need_new {
            let pid = self.alloc_page()?;
            self.tables.entry(seq).or_default().push(pid);
        }
        let pid = *self.tables[&seq].last().unwrap();
        let geom = self.geom;
        let page = self.pages[pid].as_mut().unwrap();
        let slot = page.used_tokens;
        encode_token(page, slot, kv, &geom);
        page.used_tokens += 1;
        Ok(())
    }

    /// Read a token's KV back (dequantized).
    pub fn read(&self, seq: u64, token_idx: usize) -> Result<Vec<f32>> {
        let table = self.tables.get(&seq).ok_or_else(|| anyhow::anyhow!("unknown seq {seq}"))?;
        let page_no = token_idx / self.geom.page_tokens;
        let slot = token_idx % self.geom.page_tokens;
        let pid = *table
            .get(page_no)
            .ok_or_else(|| anyhow::anyhow!("token {token_idx} beyond sequence"))?;
        let page = self.pages[pid].as_ref().unwrap();
        if slot >= page.used_tokens {
            bail!("token {token_idx} not written yet");
        }
        Ok(decode_token(page, slot, &self.geom))
    }

    /// Free all pages of a sequence.
    pub fn release(&mut self, seq: u64) {
        if let Some(table) = self.tables.remove(&seq) {
            for pid in table {
                if let Some(p) = self.pages[pid].take() {
                    self.allocated_bytes -= self.geom.page_bytes(p.prec);
                    self.free_list.push(pid);
                }
            }
        }
    }

    /// Bytes one full sequence of `tokens` costs at this precision.
    pub fn sequence_bytes(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.geom.page_tokens) * self.geom.page_bytes(self.prec)
    }
}

fn encode_token(page: &mut Page, slot: usize, kv: &[f32], geom: &KvGeometry) {
    let f = geom.floats_per_token();
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + 4 * i..base + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        Precision::Q8 => {
            // per-token symmetric scale stored in the page tail
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 127.0;
            let base = slot * f;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + i] = ((v / scale).round().clamp(-127.0, 127.0) as i8) as u8;
            }
            let tail = geom.page_tokens * f + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        Precision::Q4 => {
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 7.0;
            let base = slot * f / 2;
            for i in 0..f / 2 {
                let lo = (kv[2 * i] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                let hi = (kv[2 * i + 1] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                page.data[base + i] = (lo | (hi << 4)) as u8;
            }
            let tail = geom.page_tokens * f / 2 + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        _ => unreachable!(),
    }
}

fn decode_token(page: &Page, slot: usize, geom: &KvGeometry) -> Vec<f32> {
    let f = geom.floats_per_token();
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            (0..f)
                .map(|i| {
                    f32::from_le_bytes(
                        page.data[base + 4 * i..base + 4 * i + 4].try_into().unwrap(),
                    )
                })
                .collect()
        }
        Precision::Q8 => {
            let tail = geom.page_tokens * f + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f;
            (0..f).map(|i| (page.data[base + i] as i8) as f32 * scale).collect()
        }
        Precision::Q4 => {
            let tail = geom.page_tokens * f / 2 + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f / 2;
            let mut out = Vec::with_capacity(f);
            for i in 0..f / 2 {
                let b = page.data[base + i] as i32;
                out.push(((b & 0xF) - 8) as f32 * scale);
                out.push((((b >> 4) & 0xF) - 8) as f32 * scale);
            }
            out
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::rng::Xoshiro256pp;

    fn geom() -> KvGeometry {
        KvGeometry { page_tokens: 4, n_heads: 2, head_dim: 8 }
    }

    #[test]
    fn roundtrip_raw_exact() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32 * 0.5 - 3.0).collect();
        c.append(1, &kv).unwrap();
        assert_eq!(c.read(1, 0).unwrap(), kv);
    }

    #[test]
    fn roundtrip_q8_bounded_error() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let mut rng = Xoshiro256pp::new(1);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.append(7, &kv).unwrap();
        let back = c.read(7, 0).unwrap();
        let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in kv.iter().zip(&back) {
            assert!((a - b).abs() <= maxabs / 127.0 * 0.51 + 1e-6);
        }
    }

    #[test]
    fn q4_cache_is_smaller_than_q8_than_raw() {
        let g = geom();
        let raw = KvCache::new(g, 1 << 30, Precision::Raw).sequence_bytes(128);
        let q8 = KvCache::new(g, 1 << 30, Precision::Q8).sequence_bytes(128);
        let q4 = KvCache::new(g, 1 << 30, Precision::Q4).sequence_bytes(128);
        assert!(raw > q8 && q8 > q4, "{raw} {q8} {q4}");
    }

    #[test]
    fn pages_allocate_on_demand_and_release() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv = vec![0.5f32; g.floats_per_token()];
        for _ in 0..9 {
            c.append(3, &kv).unwrap(); // 9 tokens -> 3 pages of 4
        }
        assert_eq!(c.allocated_bytes(), 3 * g.page_bytes(Precision::Q8));
        assert_eq!(c.live_sequences(), 1);
        c.release(3);
        assert_eq!(c.allocated_bytes(), 0);
        assert_eq!(c.live_sequences(), 0);
        assert!(c.read(3, 0).is_err());
    }

    #[test]
    fn budget_is_enforced_and_freed_pages_are_reused() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, 2 * one_page, Precision::Q8);
        let kv = vec![0.1f32; g.floats_per_token()];
        for _ in 0..8 {
            c.append(1, &kv).unwrap(); // fills 2 pages exactly
        }
        assert!(c.append(1, &kv).is_err(), "third page must exceed budget");
        c.release(1);
        for _ in 0..8 {
            c.append(2, &kv).unwrap(); // reuses the freed pages
        }
        assert_eq!(c.allocated_bytes(), 2 * one_page);
    }

    #[test]
    fn property_interleaved_sequences_are_isolated() {
        check(
            5,
            25,
            6,
            |gen| {
                let n_seqs = gen.usize_in(1, 4);
                let tokens = gen.usize_in(1, 10);
                let seed = gen.usize_in(0, 1 << 30) as u64;
                (n_seqs, tokens, seed)
            },
            |&(n_seqs, tokens, seed)| {
                let g = geom();
                let mut c = KvCache::new(g, 1 << 22, Precision::Raw);
                let mut rng = Xoshiro256pp::new(seed);
                let mut expect: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_seqs];
                for t in 0..tokens {
                    for s in 0..n_seqs {
                        let kv: Vec<f32> =
                            (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        c.append(s as u64, &kv).map_err(|e| e.to_string())?;
                        expect[s].push(kv);
                        let back = c.read(s as u64, t).map_err(|e| e.to_string())?;
                        if back != expect[s][t] {
                            return Err(format!("seq {s} tok {t} mismatch"));
                        }
                    }
                }
                // re-verify everything at the end (no cross-sequence clobber)
                for s in 0..n_seqs {
                    for t in 0..tokens {
                        if c.read(s as u64, t).map_err(|e| e.to_string())? != expect[s][t] {
                            return Err(format!("late mismatch seq {s} tok {t}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
