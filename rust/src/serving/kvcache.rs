//! Paged KV-cache manager with entropy-style precision tiers — the paper's
//! §7 "System Integration / KV cache compression" future-work direction,
//! built as a real substrate: page-granular allocation (vLLM-flavored),
//! per-sequence page tables, and quantized page storage (fp32 / int8 /
//! int4) with the same symmetric per-column scheme as the weight formats.
//!
//! This is the storage half of the incremental decode path (DESIGN.md §10):
//! `refexec::decode_step` appends one token's K/V per block via `append`
//! and reads the attention history back through `read_into`, so generated
//! tokens never recompute the full sequence. The hot-path contract is
//! **allocation-free steady state**: `read_into` writes into a caller
//! buffer, and a sequence whose pages were `reserve`d up front never
//! allocates inside `append`.
//!
//! **Prefix caching** (DESIGN.md §14): every page carries a refcount, and a
//! prefix-hash index maps the token prefix covered by each full page chain
//! (hashed together with the cache's `KvGeometry` × `Precision`, so a key
//! can never cross cache configurations) to the resident pages holding its
//! K/V. [`KvCache::register_prefix`] publishes an ingested context's chains
//! into the index — the index itself holds a reference on each page, so a
//! prefix outlives its donor sequence; [`KvCache::attach_prefix`] seats a
//! *fresh* sequence on the longest indexed prefix of its context copy-free
//! (refcount bumps on the shared full pages, copy-on-write only at the
//! first partially-shared page), leaving just the unshared suffix to
//! ingest. Invariants the property suite holds:
//!
//! - a page frees (returns to the free list, refunds `allocated_bytes`)
//!   exactly when its refcount hits zero — never before, never twice;
//! - `release` of an unknown (or already-released) sequence is rejected
//!   with a typed [`KvError::UnknownSequence`], so double-release is a
//!   caller bug surfaced as data, not silent books corruption;
//! - shared full pages are immutable to attachers: an attached sequence's
//!   write cursor starts past them, and the partially-shared page is
//!   copied before the first divergent append — so a cache hit can never
//!   move a bit of any other sequence's history;
//! - budget pressure evicts index-held prefixes oldest-first before a
//!   `reserve`/`append` is refused, so a cached prefix can never starve
//!   live admission.

use crate::quant::Precision;

/// Typed KV-cache failures. Budget exhaustion is an *admission* signal the
/// serving layer turns into a terminal `Status::KvExhausted` — never a
/// stringly-typed surprise mid-stream. Implements `std::error::Error`, so
/// `?` still lifts it into the executor's `anyhow::Result` plumbing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The allocation/reservation would exceed the configured byte budget.
    BudgetExhausted { needed: usize, allocated: usize, budget: usize },
    /// A KV slice had the wrong number of floats for the cache geometry.
    BadKvLength { got: usize, want: usize },
    /// No page table exists for this sequence id.
    UnknownSequence(u64),
    /// The requested token index has not been appended yet.
    TokenNotWritten { token: usize, have: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::BudgetExhausted { needed, allocated, budget } => write!(
                f,
                "kv-cache budget exhausted ({allocated} + {needed} > {budget})"
            ),
            KvError::BadKvLength { got, want } => {
                write!(f, "kv length {got} != geometry {want}")
            }
            KvError::UnknownSequence(seq) => write!(f, "unknown seq {seq}"),
            KvError::TokenNotWritten { token, have } => {
                write!(f, "token {token} not written yet ({have} in sequence)")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Fixed page geometry: `page_tokens` KV slots of `head_dim * n_heads * 2`
/// (K and V) floats each.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub page_tokens: usize,
    pub n_heads: usize,
    pub head_dim: usize,
}

impl KvGeometry {
    pub fn floats_per_token(&self) -> usize {
        2 * self.n_heads * self.head_dim
    }

    pub fn page_bytes(&self, prec: Precision) -> usize {
        let floats = self.page_tokens * self.floats_per_token();
        match prec {
            Precision::Raw => 4 * floats,
            Precision::Q8 => floats + 4 * self.floats_per_token(), // + scale/token-col
            Precision::Q4 => floats / 2 + 4 * self.floats_per_token(),
            Precision::Q3 | Precision::T2 => floats / 2 + 4 * self.floats_per_token(),
        }
    }
}

#[derive(Clone, Debug)]
struct Page {
    data: Vec<u8>,
    prec: Precision,
    used_tokens: usize,
    /// Holders of this page: one per sequence page-table entry plus one per
    /// prefix-index entry referencing it. The page frees exactly when this
    /// hits zero.
    refs: usize,
}

/// One sequence's page table: the pages in token order (possibly reserved
/// ahead of the write cursor) plus the number of tokens appended so far.
#[derive(Clone, Debug, Default)]
struct SeqTable {
    pages: Vec<usize>,
    tokens: usize,
}

/// One published prefix: the exact token prefix it covers (kept in full so
/// a hash collision can never attach the wrong pages), the per-stream
/// full-page chains holding its K/V, and the donor's *next* page past the
/// aligned prefix — the partially-shared page attachers copy-on-write
/// instead of sharing, so a hit can extend past the last full page
/// boundary (up to the attach limit) without aliasing writable slots.
#[derive(Clone, Debug)]
struct PrefixEntry {
    tokens: Vec<i32>,
    /// `chains[stream][page]` — one refcounted chain per stream (serving
    /// registers one stream per transformer block), all the same length.
    chains: Vec<Vec<usize>>,
    /// Copy-on-write source: per-stream page ids of the donor's page right
    /// after the aligned prefix, plus the tokens it held at registration
    /// (`1..=page_tokens` of them). The entry holds a reference on these
    /// pages too.
    ext: Option<(Vec<usize>, Vec<i32>)>,
}

/// What [`KvCache::attach_prefix`] reused for a fresh sequence: how many
/// context tokens were seated from the index, how many resident bytes were
/// shared copy-free (refcount bumps only), and how many were copied for
/// the partially-shared tail page(s).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixAttach {
    /// Context tokens the fresh sequence starts with (0 = miss).
    pub tokens: usize,
    /// Bytes of already-resident pages shared without copying.
    pub shared_bytes: usize,
    /// Bytes newly allocated and copied for the partially-shared page.
    pub copied_bytes: usize,
}

/// Page-granular KV cache for many concurrent sequences.
pub struct KvCache {
    geom: KvGeometry,
    budget_bytes: usize,
    allocated_bytes: usize,
    /// High-water mark of `allocated_bytes` (serving telemetry:
    /// `ServingMetrics::kv_bytes`).
    peak_bytes: usize,
    pages: Vec<Option<Page>>,
    free_list: Vec<usize>,
    /// sequence id -> page table
    tables: std::collections::BTreeMap<u64, SeqTable>,
    prec: Precision,
    /// prefix hash (geometry × precision × stream count × token prefix)
    /// -> resident page chains covering that prefix
    index: std::collections::HashMap<u64, PrefixEntry>,
    /// Registration order of `index` keys — budget pressure evicts
    /// oldest-first.
    index_order: std::collections::VecDeque<u64>,
}

impl KvCache {
    pub fn new(geom: KvGeometry, budget_bytes: usize, prec: Precision) -> Self {
        // construction-time guard: the page codec implements exactly these
        // three tiers (serving validates its config against the same set
        // before any shard spawns)
        assert!(
            matches!(prec, Precision::Raw | Precision::Q8 | Precision::Q4),
            "KvCache supports raw/8bit/4bit pages, not {}",
            prec.label()
        );
        Self {
            geom,
            budget_bytes,
            allocated_bytes: 0,
            peak_bytes: 0,
            pages: Vec::new(),
            free_list: Vec::new(),
            tables: std::collections::BTreeMap::new(),
            prec,
            index: std::collections::HashMap::new(),
            index_order: std::collections::VecDeque::new(),
        }
    }

    pub fn allocated_bytes(&self) -> usize {
        self.allocated_bytes
    }

    /// High-water mark of `allocated_bytes` over the cache's lifetime.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn live_sequences(&self) -> usize {
        self.tables.len()
    }

    pub fn geometry(&self) -> KvGeometry {
        self.geom
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Tokens appended to `seq` so far (0 for unknown sequences).
    pub fn sequence_tokens(&self, seq: u64) -> usize {
        self.tables.get(&seq).map(|t| t.tokens).unwrap_or(0)
    }

    /// Make room for `extra` more bytes, evicting index-held prefixes
    /// (oldest first) under pressure. Fails — without allocating — when the
    /// budget cannot fit `extra` even with an empty prefix index.
    fn ensure_budget(&mut self, extra: usize) -> Result<(), KvError> {
        while self.allocated_bytes + extra > self.budget_bytes {
            if !self.evict_oldest_prefix() {
                return Err(KvError::BudgetExhausted {
                    needed: extra,
                    allocated: self.allocated_bytes,
                    budget: self.budget_bytes,
                });
            }
        }
        Ok(())
    }

    /// Drop the oldest prefix-index entry, freeing any of its pages whose
    /// last holder it was. Returns false when the index is empty.
    fn evict_oldest_prefix(&mut self) -> bool {
        while let Some(h) = self.index_order.pop_front() {
            if let Some(e) = self.index.remove(&h) {
                for chain in &e.chains {
                    for &pid in chain {
                        self.unref_page(pid);
                    }
                }
                if let Some((pids, _)) = &e.ext {
                    for &pid in pids {
                        self.unref_page(pid);
                    }
                }
                return true;
            }
        }
        false
    }

    /// Drop one holder of `pid`; free the page (refund the budget, park it
    /// on the free list) when that was the last one.
    fn unref_page(&mut self, pid: usize) {
        let page = self.pages[pid].as_mut().expect("unref of a freed page");
        debug_assert!(page.refs > 0, "page {pid} refcount underflow");
        page.refs -= 1;
        if page.refs == 0 {
            let prec = page.prec;
            self.pages[pid] = None;
            self.allocated_bytes -= self.geom.page_bytes(prec);
            self.free_list.push(pid);
        }
    }

    fn alloc_page(&mut self) -> Result<usize, KvError> {
        let bytes = self.geom.page_bytes(self.prec);
        self.ensure_budget(bytes)?;
        if let Some(id) = self.free_list.pop() {
            self.pages[id] =
                Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0, refs: 1 });
            self.allocated_bytes += bytes;
            self.peak_bytes = self.peak_bytes.max(self.allocated_bytes);
            return Ok(id);
        }
        self.pages
            .push(Some(Page { data: vec![0; bytes], prec: self.prec, used_tokens: 0, refs: 1 }));
        self.allocated_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.allocated_bytes);
        Ok(self.pages.len() - 1)
    }

    /// Pre-allocate enough pages for `seq` to hold `tokens` tokens, so the
    /// subsequent `append`s are allocation-free (the decode hot path
    /// reserves a sequence's window up front and then never touches the
    /// allocator mid-generation). Fails — without allocating anything —
    /// when the reservation would exceed the budget even after evicting
    /// cached prefixes.
    ///
    /// ```
    /// use ewq::quant::Precision;
    /// use ewq::serving::kvcache::{KvCache, KvGeometry};
    ///
    /// let geom = KvGeometry { page_tokens: 4, n_heads: 2, head_dim: 8 };
    /// let mut cache = KvCache::new(geom, 1 << 20, Precision::Raw);
    ///
    /// // reserve a 6-token window for sequence 7 (2 pages of 4 slots) ...
    /// cache.reserve(7, 6).unwrap();
    /// let reserved = cache.allocated_bytes();
    ///
    /// // ... so appends fill the reserved pages without allocating,
    /// let kv: Vec<f32> = (0..geom.floats_per_token()).map(|i| i as f32).collect();
    /// cache.append(7, &kv).unwrap();
    /// assert_eq!(cache.allocated_bytes(), reserved);
    ///
    /// // and the history reads back exactly (raw pages are lossless).
    /// let mut out = vec![0.0f32; geom.floats_per_token()];
    /// cache.read_into(7, 0, &mut out).unwrap();
    /// assert_eq!(out, kv);
    ///
    /// cache.release(7).unwrap();
    /// assert_eq!(cache.allocated_bytes(), 0);
    /// ```
    pub fn reserve(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        let have = self.tables.get(&seq).map(|t| t.pages.len()).unwrap_or(0);
        let need = tokens.div_ceil(self.geom.page_tokens);
        if need > have {
            let extra = need - have;
            let bytes = self.geom.page_bytes(self.prec);
            self.ensure_budget(extra * bytes)?;
            for _ in 0..extra {
                let pid = self.alloc_page()?;
                self.tables.entry(seq).or_default().pages.push(pid);
            }
        }
        Ok(())
    }

    /// Append `kv` (one token's K+V floats) to a sequence, allocating pages
    /// on demand (or filling `reserve`d ones). Quantizes into the page
    /// store per the cache precision.
    pub fn append(&mut self, seq: u64, kv: &[f32]) -> Result<(), KvError> {
        if kv.len() != self.geom.floats_per_token() {
            return Err(KvError::BadKvLength {
                got: kv.len(),
                want: self.geom.floats_per_token(),
            });
        }
        let tokens = self.sequence_tokens(seq);
        let page_no = tokens / self.geom.page_tokens;
        let slot = tokens % self.geom.page_tokens;
        if page_no >= self.tables.get(&seq).map(|t| t.pages.len()).unwrap_or(0) {
            let pid = self.alloc_page()?;
            self.tables.entry(seq).or_default().pages.push(pid);
        }
        let table = self.tables.get_mut(&seq).unwrap();
        let pid = table.pages[page_no];
        table.tokens += 1;
        let geom = self.geom;
        let page = self.pages[pid].as_mut().unwrap();
        encode_token(page, slot, kv, &geom);
        page.used_tokens = page.used_tokens.max(slot + 1);
        Ok(())
    }

    /// Read a token's KV back (dequantized) into `out`
    /// (`geometry().floats_per_token()` floats) without allocating — the
    /// decode hot path's history read.
    pub fn read_into(&self, seq: u64, token_idx: usize, out: &mut [f32]) -> Result<(), KvError> {
        if out.len() != self.geom.floats_per_token() {
            return Err(KvError::BadKvLength {
                got: out.len(),
                want: self.geom.floats_per_token(),
            });
        }
        let table = self.tables.get(&seq).ok_or(KvError::UnknownSequence(seq))?;
        if token_idx >= table.tokens {
            return Err(KvError::TokenNotWritten { token: token_idx, have: table.tokens });
        }
        let page_no = token_idx / self.geom.page_tokens;
        let slot = token_idx % self.geom.page_tokens;
        let pid = table.pages[page_no];
        let page = self.pages[pid].as_ref().unwrap();
        decode_token_into(page, slot, &self.geom, out);
        Ok(())
    }

    /// Read a token's KV back (dequantized). Allocating convenience wrapper
    /// over `read_into` (tests/inspection; the hot path uses `read_into`).
    pub fn read(&self, seq: u64, token_idx: usize) -> Result<Vec<f32>, KvError> {
        let mut out = vec![0.0f32; self.geom.floats_per_token()];
        self.read_into(seq, token_idx, &mut out)?;
        Ok(out)
    }

    /// Retire a sequence: drop its hold on every page of its table. Pages
    /// free only when this was their last holder — pages shared with other
    /// sequences or pinned by the prefix index stay resident. Releasing an
    /// unknown (or already-released) sequence is rejected as
    /// [`KvError::UnknownSequence`]: double-release is a caller bug and
    /// must never unbalance the page books.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let table = self.tables.remove(&seq).ok_or(KvError::UnknownSequence(seq))?;
        for pid in table.pages {
            self.unref_page(pid);
        }
        Ok(())
    }

    /// Bytes one full sequence of `tokens` costs at this precision.
    pub fn sequence_bytes(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.geom.page_tokens) * self.geom.page_bytes(self.prec)
    }

    /// Prefix-index key for `ctx` under this cache's configuration: FNV-1a
    /// over the geometry, the page precision, the stream count, and the
    /// tokens themselves — so a key can never match across caches with a
    /// different `KvGeometry` × `Precision`, and single-stream callers can
    /// never collide with multi-stream (per-block) registrations.
    fn prefix_hash(&self, ctx: &[i32], n_streams: usize) -> u64 {
        fn eat(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        eat(&mut h, &(self.geom.page_tokens as u64).to_le_bytes());
        eat(&mut h, &(self.geom.n_heads as u64).to_le_bytes());
        eat(&mut h, &(self.geom.head_dim as u64).to_le_bytes());
        eat(&mut h, self.prec.label().as_bytes());
        eat(&mut h, &(n_streams as u64).to_le_bytes());
        for &t in ctx {
            eat(&mut h, &t.to_le_bytes());
        }
        h
    }

    /// Publish the ingested context `ctx` of a donor into the prefix index:
    /// one entry per full-page-aligned prefix length, each holding its own
    /// reference on the per-stream page chains (so the prefix outlives the
    /// donor), plus a copy-on-write pointer to the first partial page on
    /// the longest entry. `streams` are the donor's cache streams (serving
    /// passes one per transformer block); all must have ingested at least
    /// `ctx.len()` tokens. Idempotent: already-indexed prefixes are left
    /// untouched.
    pub fn register_prefix(&mut self, ctx: &[i32], streams: &[u64]) {
        let pt = self.geom.page_tokens;
        let k_max = ctx.len() / pt;
        if k_max == 0 || streams.is_empty() {
            return;
        }
        for s in streams {
            match self.tables.get(s) {
                Some(t) if t.tokens >= ctx.len() => {}
                _ => return, // donor hasn't ingested this context: nothing to publish
            }
        }
        for k in 1..=k_max {
            let h = self.prefix_hash(&ctx[..k * pt], streams.len());
            if self.index.contains_key(&h) {
                continue; // first registration wins
            }
            let chains: Vec<Vec<usize>> =
                streams.iter().map(|s| self.tables[s].pages[..k].to_vec()).collect();
            for chain in &chains {
                for &pid in chain {
                    self.pages[pid].as_mut().unwrap().refs += 1;
                }
            }
            let ext = if ctx.len() > k * pt {
                let pids: Vec<usize> = streams.iter().map(|s| self.tables[s].pages[k]).collect();
                for &pid in &pids {
                    self.pages[pid].as_mut().unwrap().refs += 1;
                }
                Some((pids, ctx[k * pt..ctx.len().min((k + 1) * pt)].to_vec()))
            } else {
                None
            };
            self.index.insert(h, PrefixEntry { tokens: ctx[..k * pt].to_vec(), chains, ext });
            self.index_order.push_back(h);
        }
    }

    /// Context tokens [`KvCache::attach_prefix`] would reuse for `ctx`
    /// (capped at `limit`), without mutating anything.
    pub fn lookup_prefix(&self, ctx: &[i32], n_streams: usize, limit: usize) -> usize {
        match self.find_prefix(ctx, n_streams, limit) {
            Some((_, k, r)) => k * self.geom.page_tokens + r,
            None => 0,
        }
    }

    /// Longest indexed match for `ctx`: `(hash, full pages, CoW tail
    /// tokens)` with `k*page_tokens + r <= limit`.
    fn find_prefix(
        &self,
        ctx: &[i32],
        n_streams: usize,
        limit: usize,
    ) -> Option<(u64, usize, usize)> {
        let pt = self.geom.page_tokens;
        let limit = limit.min(ctx.len());
        for k in (1..=limit / pt).rev() {
            let h = self.prefix_hash(&ctx[..k * pt], n_streams);
            if let Some(e) = self.index.get(&h) {
                if e.chains.len() == n_streams && e.tokens == ctx[..k * pt] {
                    let mut r = 0;
                    if let Some((_, ext_toks)) = &e.ext {
                        let avail = &ctx[k * pt..limit];
                        r = ext_toks.iter().zip(avail).take_while(|(a, b)| a == b).count();
                    }
                    return Some((h, k, r));
                }
            }
        }
        None
    }

    /// Seat the *fresh* sequences `streams` on the longest indexed prefix
    /// of `ctx` (at most `limit` tokens — callers pass `ctx.len()-1` so at
    /// least one context token is always left to ingest, which is what
    /// produces the first logits). Shared full pages are attached by
    /// refcount bump only; the first partially-shared page is copied
    /// (copy-on-write) so the new sequence's appends can never touch
    /// another holder's bytes. Degrades instead of failing: a budget miss
    /// on the CoW copy falls back to the aligned prefix, and a cold index
    /// returns a zero [`PrefixAttach`].
    pub fn attach_prefix(&mut self, ctx: &[i32], streams: &[u64], limit: usize) -> PrefixAttach {
        let out = PrefixAttach::default();
        if streams.is_empty() || streams.iter().any(|s| self.tables.contains_key(s)) {
            return out;
        }
        let Some((h, k, mut r)) = self.find_prefix(ctx, streams.len(), limit) else {
            return out;
        };
        let pt = self.geom.page_tokens;
        let page_bytes = self.geom.page_bytes(self.prec);
        let e = &self.index[&h];
        let chains = e.chains.clone();
        let ext_pids = e.ext.as_ref().map(|(pids, _)| pids.clone());
        // the new holders' references on the shared full-page chains
        for chain in &chains {
            for &pid in chain {
                self.pages[pid].as_mut().unwrap().refs += 1;
            }
        }
        // copy-on-write tail: guard the source pages (CoW allocation may
        // evict the very entry that owns them), allocate one private page
        // per stream, copy, and fall back to the aligned prefix if the
        // budget refuses
        let mut cow_pages: Vec<usize> = Vec::new();
        if r > 0 {
            let srcs = ext_pids.as_ref().expect("find_prefix returned a tail without ext pages");
            for &pid in srcs {
                self.pages[pid].as_mut().unwrap().refs += 1;
            }
            for _ in 0..streams.len() {
                match self.alloc_page() {
                    Ok(pid) => cow_pages.push(pid),
                    Err(_) => break,
                }
            }
            if cow_pages.len() == streams.len() {
                for (i, &src) in srcs.iter().enumerate() {
                    let data = self.pages[src].as_ref().unwrap().data.clone();
                    let dst = self.pages[cow_pages[i]].as_mut().unwrap();
                    dst.data.copy_from_slice(&data);
                    dst.used_tokens = r;
                }
            } else {
                for &pid in &cow_pages {
                    self.unref_page(pid);
                }
                cow_pages.clear();
                r = 0;
            }
            for &pid in srcs {
                self.unref_page(pid); // drop the guards
            }
        }
        let tokens = k * pt + r;
        for (i, &s) in streams.iter().enumerate() {
            let mut pages = chains[i].clone();
            if r > 0 {
                pages.push(cow_pages[i]);
            }
            self.tables.insert(s, SeqTable { pages, tokens });
        }
        PrefixAttach {
            tokens,
            shared_bytes: streams.len() * k * page_bytes,
            copied_bytes: if r > 0 { streams.len() * page_bytes } else { 0 },
        }
    }

    /// Number of live prefix-index entries (one per registered aligned
    /// prefix length).
    pub fn prefix_entries(&self) -> usize {
        self.index.len()
    }

    /// Drop every prefix-index entry, freeing pages whose last holder was
    /// the index. Live sequences are unaffected.
    pub fn clear_prefix_index(&mut self) {
        while self.evict_oldest_prefix() {}
    }

    /// Verify the page books: every live page's refcount equals its holder
    /// count (sequence tables + index entries), `allocated_bytes` is
    /// exactly the live pages' bytes, and every page is live xor free.
    /// Cheap enough to run at shard exit; the property suites call it
    /// after every interleaving step.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut holds = vec![0usize; self.pages.len()];
        for t in self.tables.values() {
            for &pid in &t.pages {
                holds[pid] += 1;
            }
        }
        for e in self.index.values() {
            for chain in &e.chains {
                for &pid in chain {
                    holds[pid] += 1;
                }
            }
            if let Some((pids, _)) = &e.ext {
                for &pid in pids {
                    holds[pid] += 1;
                }
            }
        }
        let mut live_bytes = 0usize;
        let mut live = 0usize;
        for (pid, p) in self.pages.iter().enumerate() {
            match p {
                Some(p) => {
                    live += 1;
                    live_bytes += self.geom.page_bytes(p.prec);
                    if p.refs == 0 {
                        return Err(format!("page {pid}: live with zero refs"));
                    }
                    if p.refs != holds[pid] {
                        return Err(format!(
                            "page {pid}: refs {} != holders {}",
                            p.refs, holds[pid]
                        ));
                    }
                }
                None => {
                    if holds[pid] != 0 {
                        return Err(format!("page {pid}: freed but {} holders", holds[pid]));
                    }
                }
            }
        }
        if live_bytes != self.allocated_bytes {
            return Err(format!(
                "allocated_bytes {} != live page bytes {live_bytes}",
                self.allocated_bytes
            ));
        }
        if self.pages.len() != live + self.free_list.len() {
            return Err(format!(
                "page live-xor-free violated: {} pages, {live} live, {} free",
                self.pages.len(),
                self.free_list.len()
            ));
        }
        Ok(())
    }
}

fn encode_token(page: &mut Page, slot: usize, kv: &[f32], geom: &KvGeometry) {
    let f = geom.floats_per_token();
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + 4 * i..base + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        Precision::Q8 => {
            // per-token symmetric scale stored in the page tail
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 127.0;
            let base = slot * f;
            for (i, v) in kv.iter().enumerate() {
                page.data[base + i] = ((v / scale).round().clamp(-127.0, 127.0) as i8) as u8;
            }
            let tail = geom.page_tokens * f + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        Precision::Q4 => {
            let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = maxabs / 7.0;
            let base = slot * f / 2;
            for i in 0..f / 2 {
                let lo = (kv[2 * i] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                let hi = (kv[2 * i + 1] / scale).round().clamp(-7.0, 7.0) as i32 + 8;
                page.data[base + i] = (lo | (hi << 4)) as u8;
            }
            let tail = geom.page_tokens * f / 2 + slot * 4;
            page.data[tail..tail + 4].copy_from_slice(&scale.to_le_bytes());
        }
        _ => unreachable!(),
    }
}

fn decode_token_into(page: &Page, slot: usize, geom: &KvGeometry, out: &mut [f32]) {
    let f = geom.floats_per_token();
    debug_assert_eq!(out.len(), f);
    match page.prec {
        Precision::Raw => {
            let base = slot * f * 4;
            for (i, o) in out.iter_mut().enumerate() {
                *o = f32::from_le_bytes(
                    page.data[base + 4 * i..base + 4 * i + 4].try_into().unwrap(),
                );
            }
        }
        Precision::Q8 => {
            let tail = geom.page_tokens * f + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f;
            for (i, o) in out.iter_mut().enumerate() {
                *o = (page.data[base + i] as i8) as f32 * scale;
            }
        }
        Precision::Q4 => {
            let tail = geom.page_tokens * f / 2 + slot * 4;
            let scale = f32::from_le_bytes(page.data[tail..tail + 4].try_into().unwrap());
            let base = slot * f / 2;
            for i in 0..f / 2 {
                let b = page.data[base + i] as i32;
                out[2 * i] = ((b & 0xF) - 8) as f32 * scale;
                out[2 * i + 1] = (((b >> 4) & 0xF) - 8) as f32 * scale;
            }
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::check;
    use crate::rng::Xoshiro256pp;

    fn geom() -> KvGeometry {
        KvGeometry { page_tokens: 4, n_heads: 2, head_dim: 8 }
    }

    #[test]
    fn roundtrip_raw_exact() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32 * 0.5 - 3.0).collect();
        c.append(1, &kv).unwrap();
        assert_eq!(c.read(1, 0).unwrap(), kv);
        assert_eq!(c.sequence_tokens(1), 1);
        assert_eq!(c.sequence_tokens(99), 0);
    }

    #[test]
    fn roundtrip_q8_bounded_error() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let mut rng = Xoshiro256pp::new(1);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.append(7, &kv).unwrap();
        let back = c.read(7, 0).unwrap();
        let maxabs = kv.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (a, b) in kv.iter().zip(&back) {
            assert!((a - b).abs() <= maxabs / 127.0 * 0.51 + 1e-6);
        }
    }

    #[test]
    fn q4_cache_is_smaller_than_q8_than_raw() {
        let g = geom();
        let raw = KvCache::new(g, 1 << 30, Precision::Raw).sequence_bytes(128);
        let q8 = KvCache::new(g, 1 << 30, Precision::Q8).sequence_bytes(128);
        let q4 = KvCache::new(g, 1 << 30, Precision::Q4).sequence_bytes(128);
        assert!(raw > q8 && q8 > q4, "{raw} {q8} {q4}");
    }

    #[test]
    fn pages_allocate_on_demand_and_release() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv = vec![0.5f32; g.floats_per_token()];
        for _ in 0..9 {
            c.append(3, &kv).unwrap(); // 9 tokens -> 3 pages of 4
        }
        assert_eq!(c.allocated_bytes(), 3 * g.page_bytes(Precision::Q8));
        assert_eq!(c.live_sequences(), 1);
        c.release(3).unwrap();
        assert_eq!(c.allocated_bytes(), 0);
        assert_eq!(c.peak_bytes(), 3 * g.page_bytes(Precision::Q8), "peak survives release");
        assert_eq!(c.live_sequences(), 0);
        assert!(c.read(3, 0).is_err());
    }

    #[test]
    fn budget_is_enforced_and_freed_pages_are_reused() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, 2 * one_page, Precision::Q8);
        let kv = vec![0.1f32; g.floats_per_token()];
        for _ in 0..8 {
            c.append(1, &kv).unwrap(); // fills 2 pages exactly
        }
        assert!(c.append(1, &kv).is_err(), "third page must exceed budget");
        c.release(1).unwrap();
        for _ in 0..8 {
            c.append(2, &kv).unwrap(); // reuses the freed pages
        }
        assert_eq!(c.allocated_bytes(), 2 * one_page);
        assert_eq!(c.peak_bytes(), 2 * one_page, "reuse never exceeded the budget");
    }

    #[test]
    fn reserve_preallocates_and_appends_fill_reserved_pages() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        c.reserve(5, 10).unwrap(); // 3 pages of 4
        let reserved = c.allocated_bytes();
        assert_eq!(reserved, 3 * g.page_bytes(Precision::Raw));
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32).collect();
        for t in 0..10 {
            c.append(5, &kv).unwrap();
            assert_eq!(c.sequence_tokens(5), t + 1);
            // reserved pages are filled, never re-allocated
            assert_eq!(c.allocated_bytes(), reserved);
        }
        assert_eq!(c.read(5, 9).unwrap(), kv);
        // reserving less than what exists is a no-op
        c.reserve(5, 4).unwrap();
        assert_eq!(c.allocated_bytes(), reserved);
        // tokens 11..12 still fit the 3 reserved pages (12 slots); the 13th
        // goes past the reservation and allocates a fourth page on demand
        c.append(5, &kv).unwrap();
        c.append(5, &kv).unwrap();
        assert_eq!(c.allocated_bytes(), reserved, "12 tokens fill 3 pages exactly");
        c.append(5, &kv).unwrap();
        assert_eq!(c.allocated_bytes(), 4 * g.page_bytes(Precision::Raw));
    }

    #[test]
    fn reserve_past_budget_fails_without_allocating() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, 2 * one_page, Precision::Q8);
        assert!(c.reserve(1, 12).is_err(), "3 pages exceed a 2-page budget");
        assert_eq!(c.allocated_bytes(), 0, "failed reservation must not leak pages");
        assert_eq!(c.live_sequences(), 0);
        // a fitting reservation still works afterwards
        c.reserve(1, 8).unwrap();
        assert_eq!(c.allocated_bytes(), 2 * one_page);
    }

    #[test]
    fn read_into_matches_read_and_rejects_bad_lengths() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q4);
        let mut rng = Xoshiro256pp::new(9);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        c.append(2, &kv).unwrap();
        let mut buf = vec![0.0f32; g.floats_per_token()];
        c.read_into(2, 0, &mut buf).unwrap();
        assert_eq!(buf, c.read(2, 0).unwrap());
        let mut short = vec![0.0f32; 3];
        assert!(c.read_into(2, 0, &mut short).is_err());
        assert!(c.read_into(2, 1, &mut buf).is_err(), "token 1 not written yet");
        assert!(c.read_into(3, 0, &mut buf).is_err(), "unknown sequence");
    }

    #[test]
    fn release_mid_stream_keeps_other_sequences_intact() {
        // the "page eviction mid-sequence" edge: one sequence is evicted
        // while its neighbours keep appending; the freed pages are recycled
        // into the survivors without clobbering their history
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let tok = |s: u64, t: usize| -> Vec<f32> {
            (0..g.floats_per_token())
                .map(|i| (s as f32) * 100.0 + t as f32 + i as f32 * 0.01)
                .collect()
        };
        for t in 0..6 {
            for s in [1u64, 2, 3] {
                c.append(s, &tok(s, t)).unwrap();
            }
        }
        let before = c.allocated_bytes();
        c.release(2).unwrap(); // evict the middle sequence mid-stream
        assert_eq!(c.live_sequences(), 2);
        assert!(c.allocated_bytes() < before);
        assert!(c.read(2, 0).is_err(), "evicted sequence is gone");
        // survivors keep their full history and can keep appending into
        // the recycled pages
        for t in 6..12 {
            c.append(1, &tok(1, t)).unwrap();
            c.append(3, &tok(3, t)).unwrap();
        }
        for t in 0..12 {
            assert_eq!(c.read(1, t).unwrap(), tok(1, t), "seq 1 tok {t}");
            assert_eq!(c.read(3, t).unwrap(), tok(3, t), "seq 3 tok {t}");
        }
        assert!(c.allocated_bytes() <= before + 2 * g.page_bytes(Precision::Raw));
    }

    #[test]
    fn capacity_exhaustion_mid_sequence_leaves_history_readable() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let mut c = KvCache::new(g, one_page, Precision::Q8);
        let kv = vec![0.25f32; g.floats_per_token()];
        for _ in 0..4 {
            c.append(1, &kv).unwrap();
        }
        // the 5th token needs a second page: clean error, nothing corrupted
        assert!(c.append(1, &kv).is_err());
        assert_eq!(c.sequence_tokens(1), 4, "failed append must not advance the cursor");
        for t in 0..4 {
            let back = c.read(1, t).unwrap();
            assert!(back.iter().all(|v| (v - 0.25).abs() < 0.01), "tok {t} readable after error");
        }
        // releasing recovers capacity for the next sequence
        c.release(1).unwrap();
        for _ in 0..4 {
            c.append(2, &kv).unwrap();
        }
    }

    #[test]
    fn sequence_bytes_is_monotone_in_tokens_and_precision() {
        let g = geom();
        let caches = [
            KvCache::new(g, 1 << 30, Precision::Raw),
            KvCache::new(g, 1 << 30, Precision::Q8),
            KvCache::new(g, 1 << 30, Precision::Q4),
        ];
        for tokens in 0..64usize {
            // monotone (non-decreasing) in sequence length, page-quantized
            for c in &caches {
                assert!(c.sequence_bytes(tokens + 1) >= c.sequence_bytes(tokens));
            }
            // the precision ladder orders byte costs at every length
            if tokens > 0 {
                let raw = caches[0].sequence_bytes(tokens);
                let q8 = caches[1].sequence_bytes(tokens);
                let q4 = caches[2].sequence_bytes(tokens);
                assert!(raw > q8 && q8 > q4, "tokens={tokens}: {raw} {q8} {q4}");
            }
        }
        // page quantization: a page boundary is where the cost steps
        let c = &caches[0];
        assert_eq!(c.sequence_bytes(1), c.sequence_bytes(g.page_tokens));
        assert!(c.sequence_bytes(g.page_tokens + 1) > c.sequence_bytes(g.page_tokens));
    }

    #[test]
    fn batched_retirement_churn_keeps_page_accounting_consistent() {
        // the continuous-batching lifecycle (DESIGN.md §12): sequences join
        // and leave the decode cohort at step boundaries while the
        // survivors keep appending. After every admission/retirement the
        // page accounting must stay exact: allocated_bytes is the live page
        // count times the page size, every non-live page sits on the free
        // list, and steady-state churn recycles pages instead of growing
        // the backing store.
        let g = geom();
        let window = 12usize; // 3 pages of 4 tokens
        let pages_per_seq = window.div_ceil(g.page_tokens);
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv = vec![0.5f32; g.floats_per_token()];
        let check_books = |c: &KvCache| {
            let live_pages = c.pages.iter().filter(|p| p.is_some()).count();
            assert_eq!(c.allocated_bytes(), live_pages * g.page_bytes(Precision::Q8));
            assert_eq!(c.pages.len(), live_pages + c.free_list.len(), "page is live xor free");
        };
        let cohort = 4u64;
        for s in 0..12u64 {
            // admit sequence s with a full reserved window, retire the
            // oldest cohort member (admission before retirement, like a
            // shard gathering the next step's batch)
            c.reserve(s, window).unwrap();
            check_books(&c);
            if s >= cohort {
                c.release(s - cohort).unwrap();
                check_books(&c);
            }
            // every live sequence appends one token — allocation-free into
            // its reserved pages
            let before = c.allocated_bytes();
            for live in s.saturating_sub(cohort - 1)..=s {
                c.append(live, &kv).unwrap();
            }
            assert_eq!(c.allocated_bytes(), before, "round {s}: appends fill reserved pages");
            // the backing store is bounded by the peak cohort (one extra
            // sequence lives briefly between admission and retirement)
            assert!(
                c.pages.len() <= (cohort as usize + 1) * pages_per_seq,
                "round {s}: churn must recycle pages, got {}",
                c.pages.len()
            );
        }
        assert_eq!(c.live_sequences(), cohort as usize);
        assert_eq!(c.sequence_bytes(window), pages_per_seq * g.page_bytes(Precision::Q8));
        for s in 8..12u64 {
            c.release(s).unwrap();
            check_books(&c);
        }
        assert_eq!(c.allocated_bytes(), 0, "full retirement returns every byte");
        assert_eq!(c.pages.len(), c.free_list.len(), "and parks every page on the free list");
    }

    #[test]
    fn failed_mid_cohort_reserve_leaks_no_pages_and_is_typed() {
        // batched admission: a cohort of sequences reserves one after
        // another until the budget runs out mid-cohort. The failing reserve
        // must (a) surface a typed BudgetExhausted — the signal the serving
        // layer maps to Status::KvExhausted — and (b) leak nothing: the
        // already-admitted members keep their exact reservations, the page
        // books stay balanced, and releasing the cohort returns every byte.
        let g = geom();
        let one_page = g.page_bytes(Precision::Q8);
        let window = 8usize; // 2 pages of 4 tokens per sequence
        let pages_per_seq = window.div_ceil(g.page_tokens);
        // room for exactly 2 full windows plus one stray page: the 3rd
        // cohort member fails part-way through the budget, not at zero
        let mut c = KvCache::new(g, (2 * pages_per_seq + 1) * one_page, Precision::Q8);
        let check_books = |c: &KvCache| {
            let live_pages = c.pages.iter().filter(|p| p.is_some()).count();
            assert_eq!(c.allocated_bytes(), live_pages * one_page);
            assert_eq!(c.pages.len(), live_pages + c.free_list.len(), "page is live xor free");
        };
        c.reserve(0, window).unwrap();
        c.reserve(1, window).unwrap();
        let before = c.allocated_bytes();
        check_books(&c);
        let err = c.reserve(2, window).unwrap_err();
        assert_eq!(
            err,
            KvError::BudgetExhausted {
                needed: pages_per_seq * one_page,
                allocated: before,
                budget: (2 * pages_per_seq + 1) * one_page,
            },
            "mid-cohort exhaustion is a typed admission error"
        );
        assert_eq!(c.allocated_bytes(), before, "failed reserve must not allocate");
        assert_eq!(c.live_sequences(), 2, "the failed sequence seats no page table");
        check_books(&c);
        // the admitted members still own their full allocation-free windows
        let kv = vec![0.5f32; g.floats_per_token()];
        for s in [0u64, 1] {
            for _ in 0..window {
                c.append(s, &kv).unwrap();
            }
        }
        assert_eq!(c.allocated_bytes(), before, "appends fill the reserved pages");
        check_books(&c);
        c.release(0).unwrap();
        c.release(1).unwrap();
        check_books(&c);
        assert_eq!(c.allocated_bytes(), 0, "full retirement returns every byte");
    }

    #[test]
    fn batched_history_reads_do_zero_heap_allocation() {
        // the fused decode step re-reads every live sequence's full
        // attention history through read_into each step; the whole sweep
        // must stay off the allocator (same counting-allocator hook as the
        // refexec steady-state tests)
        use crate::model::refexec::alloc_hook;
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let kv: Vec<f32> = (0..g.floats_per_token()).map(|i| i as f32 * 0.1 - 0.8).collect();
        for s in 0..3u64 {
            c.reserve(s, 8).unwrap();
            for _ in 0..8 {
                c.append(s, &kv).unwrap();
            }
        }
        let mut buf = vec![0.0f32; g.floats_per_token()];
        c.read_into(0, 0, &mut buf).unwrap(); // warm any lazy TLS
        let before = alloc_hook::thread_allocs();
        for s in 0..3u64 {
            for t in 0..8 {
                c.read_into(s, t, &mut buf).unwrap();
            }
        }
        let after = alloc_hook::thread_allocs();
        assert_eq!(after - before, 0, "batched read_into must not allocate");
        assert!(buf.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn property_interleaved_sequences_are_isolated() {
        check(
            5,
            25,
            6,
            |gen| {
                let n_seqs = gen.usize_in(1, 4);
                let tokens = gen.usize_in(1, 10);
                let seed = gen.usize_in(0, 1 << 30) as u64;
                (n_seqs, tokens, seed)
            },
            |&(n_seqs, tokens, seed)| {
                let g = geom();
                let mut c = KvCache::new(g, 1 << 22, Precision::Raw);
                let mut rng = Xoshiro256pp::new(seed);
                let mut expect: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_seqs];
                for t in 0..tokens {
                    for s in 0..n_seqs {
                        let kv: Vec<f32> =
                            (0..g.floats_per_token()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        c.append(s as u64, &kv).map_err(|e| e.to_string())?;
                        expect[s].push(kv);
                        let back = c.read(s as u64, t).map_err(|e| e.to_string())?;
                        if back != expect[s][t] {
                            return Err(format!("seq {s} tok {t} mismatch"));
                        }
                    }
                }
                // re-verify everything at the end (no cross-sequence clobber)
                for s in 0..n_seqs {
                    for t in 0..tokens {
                        if c.read(s as u64, t).map_err(|e| e.to_string())? != expect[s][t] {
                            return Err(format!("late mismatch seq {s} tok {t}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    // ---- prefix caching: refcounted pages + prefix-hash index ----

    fn tok(g: &KvGeometry, s: u64, t: usize) -> Vec<f32> {
        (0..g.floats_per_token())
            .map(|i| (s as f32) * 100.0 + t as f32 + i as f32 * 0.01)
            .collect()
    }

    /// Ingest `ctx` as donor sequence `seq` and publish it into the index.
    fn ingest_and_register(c: &mut KvCache, seq: u64, ctx: &[i32]) {
        let g = c.geometry();
        for (t, _) in ctx.iter().enumerate() {
            c.append(seq, &tok(&g, seq, t)).unwrap();
        }
        c.register_prefix(ctx, &[seq]);
    }

    #[test]
    fn double_release_is_rejected() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        c.reserve(1, 4).unwrap();
        c.release(1).unwrap();
        assert_eq!(c.release(1), Err(KvError::UnknownSequence(1)), "double release is typed");
        assert_eq!(c.release(99), Err(KvError::UnknownSequence(99)), "unknown seq is typed");
        assert_eq!(c.allocated_bytes(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn attach_shares_pages_copy_free_and_frees_only_at_last_holder() {
        let g = geom(); // 4-token pages
        let mut c = KvCache::new(g, 1 << 20, Precision::Raw);
        let ctx: Vec<i32> = (0..8).collect(); // exactly 2 full pages
        ingest_and_register(&mut c, 1, &ctx);
        let donor_bytes = c.allocated_bytes();
        c.check_invariants().unwrap();
        assert_eq!(c.prefix_entries(), 2, "one entry per aligned prefix length");

        // a fresh sequence with the same context attaches 7 of 8 tokens
        // (the last context token is always left to ingest) without
        // allocating a single new full page — only the CoW copy of the
        // partially-shared page
        let at = c.attach_prefix(&ctx, &[2], ctx.len() - 1);
        assert_eq!(at.tokens, 7, "1 full shared page + 3 CoW tokens");
        assert_eq!(at.shared_bytes, g.page_bytes(Precision::Raw));
        assert_eq!(at.copied_bytes, g.page_bytes(Precision::Raw));
        assert_eq!(
            c.allocated_bytes(),
            donor_bytes + g.page_bytes(Precision::Raw),
            "attach allocates only the copy-on-write page"
        );
        c.check_invariants().unwrap();

        // the attached history reads back bit-identically to the donor's
        for t in 0..7 {
            assert_eq!(c.read(2, t).unwrap(), c.read(1, t).unwrap(), "tok {t}");
        }

        // the attacher's appends diverge without touching the donor
        c.append(2, &tok(&g, 2, 7)).unwrap();
        assert_eq!(c.read(1, 7).unwrap(), tok(&g, 1, 7), "donor tok 7 untouched");
        assert_eq!(c.read(2, 7).unwrap(), tok(&g, 2, 7));
        c.check_invariants().unwrap();

        // donor retires: every donor page stays resident (attacher + index
        // still hold them) — nothing frees before its last holder retires
        let before = c.allocated_bytes();
        c.release(1).unwrap();
        c.check_invariants().unwrap();
        assert!(c.read(2, 0).is_ok(), "attacher survives donor retirement");
        assert_eq!(c.allocated_bytes(), before, "index + attacher pin the donor's pages");

        // attacher retires: the index still pins the prefix
        c.release(2).unwrap();
        c.check_invariants().unwrap();
        assert!(c.allocated_bytes() > 0, "index keeps the prefix resident");

        // dropping the index returns every byte and parks every page
        c.clear_prefix_index();
        c.check_invariants().unwrap();
        assert_eq!(c.allocated_bytes(), 0, "last holder frees the pages");
        assert_eq!(c.pages.len(), c.free_list.len());

        // and the freed pages recycle into the next sequence
        c.reserve(3, 8).unwrap();
        assert_eq!(c.pages.len(), c.free_list.len() + 2, "recycled, not grown");
        c.release(3).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn attach_hits_longest_indexed_prefix_and_verifies_tokens() {
        let g = geom();
        let mut c = KvCache::new(g, 1 << 20, Precision::Q8);
        let ctx: Vec<i32> = (0..12).collect(); // 3 full pages
        ingest_and_register(&mut c, 1, &ctx);

        // same first page, diverging mid-second-page: share page 1, CoW the
        // still-matching first token of page 2
        let mut fork = ctx.clone();
        fork[5] = 99;
        let at = c.attach_prefix(&fork, &[2], fork.len() - 1);
        assert_eq!(at.tokens, g.page_tokens + 1, "divergence caps the match mid-page");
        c.release(2).unwrap();

        // full match attaches 2 pages + CoW tail capped at len-1
        let at = c.attach_prefix(&ctx, &[3], ctx.len() - 1);
        assert_eq!(at.tokens, 11);
        c.release(3).unwrap();

        // a shorter context reuses the longest prefix that fits its limit
        let short = &ctx[..6];
        let at = c.attach_prefix(short, &[4], short.len() - 1);
        assert_eq!(at.tokens, 5, "1 full page + 1 CoW token under the 5-token limit");
        c.release(4).unwrap();

        // completely different tokens: miss
        let other: Vec<i32> = (100..112).collect();
        assert_eq!(c.attach_prefix(&other, &[5], other.len() - 1), PrefixAttach::default());
        c.check_invariants().unwrap();
    }

    #[test]
    fn budget_pressure_evicts_cached_prefixes_before_refusing_admission() {
        let g = geom();
        let one_page = g.page_bytes(Precision::Raw);
        let mut c = KvCache::new(g, 4 * one_page, Precision::Raw);
        let ctx: Vec<i32> = (0..8).collect();
        ingest_and_register(&mut c, 1, &ctx); // 2 pages, index-pinned
        c.release(1).unwrap();
        assert_eq!(c.allocated_bytes(), 2 * one_page, "index keeps the prefix warm");

        // a 4-page reservation only fits if the cached prefix is evicted
        c.reserve(2, 16).unwrap();
        assert_eq!(c.allocated_bytes(), 4 * one_page);
        assert_eq!(c.prefix_entries(), 0, "eviction emptied the index");
        c.check_invariants().unwrap();

        // with the budget truly full, admission fails typed — and without
        // having allocated anything
        let err = c.reserve(3, 4).unwrap_err();
        assert!(matches!(err, KvError::BudgetExhausted { .. }));
        assert_eq!(c.live_sequences(), 1);
        c.release(2).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn property_interleaved_attach_retire_keeps_books_exact() {
        // interleaved donors/attachers over shared chains: after every
        // operation the refcount books must balance exactly, attached
        // histories must read back bit-identical to a donor's, and full
        // retirement plus index clearing must return every byte
        check(
            11,
            20,
            6,
            |gen| {
                let n_ctx = gen.usize_in(1, 3); // distinct shared prefixes
                let ops = gen.usize_in(4, 24);
                let seed = gen.usize_in(0, 1 << 30) as u64;
                (n_ctx, ops, seed)
            },
            |&(n_ctx, ops, seed)| {
                let g = geom();
                let mut c = KvCache::new(g, 1 << 22, Precision::Raw);
                let mut rng = Xoshiro256pp::new(seed);
                let mut next_seq = 0u64;
                // live: (seq, ctx_id, tokens_valid)
                let mut live: Vec<(u64, usize, usize)> = Vec::new();
                let ctxs: Vec<Vec<i32>> = (0..n_ctx)
                    .map(|i| (0..10).map(|t| (i * 50 + t) as i32).collect())
                    .collect();
                let expect = |ctx_id: usize, t: usize| tok(&g, ctx_id as u64 * 1000, t);
                for _ in 0..ops {
                    let op = rng.next_u64() % 3;
                    if op < 2 || live.is_empty() {
                        // admit: attach what the index has, ingest the rest
                        let ctx_id = (rng.next_u64() % n_ctx as u64) as usize;
                        let ctx = &ctxs[ctx_id];
                        let seq = next_seq;
                        next_seq += 1;
                        let at = c.attach_prefix(ctx, &[seq], ctx.len() - 1);
                        c.check_invariants()?;
                        for t in at.tokens..ctx.len() {
                            c.append(seq, &expect(ctx_id, t)).map_err(|e| e.to_string())?;
                        }
                        c.register_prefix(ctx, &[seq]);
                        c.check_invariants()?;
                        live.push((seq, ctx_id, ctx.len()));
                    } else {
                        // retire a random live sequence; double release must
                        // stay rejected and books must stay balanced
                        let i = (rng.next_u64() % live.len() as u64) as usize;
                        let (seq, _, _) = live.swap_remove(i);
                        c.release(seq).map_err(|e| e.to_string())?;
                        if c.release(seq) != Err(KvError::UnknownSequence(seq)) {
                            return Err("double release not rejected".into());
                        }
                        c.check_invariants()?;
                    }
                    // every live history stays bit-identical to fresh writes
                    for &(seq, ctx_id, tokens) in &live {
                        for t in 0..tokens {
                            if c.read(seq, t).map_err(|e| e.to_string())? != expect(ctx_id, t) {
                                return Err(format!("seq {seq} tok {t} corrupted"));
                            }
                        }
                    }
                }
                for (seq, _, _) in live {
                    c.release(seq).map_err(|e| e.to_string())?;
                }
                c.check_invariants()?;
                c.clear_prefix_index();
                c.check_invariants()?;
                if c.allocated_bytes() != 0 {
                    return Err(format!("{} bytes leaked", c.allocated_bytes()));
                }
                Ok(())
            },
        );
    }
}
