//! Shared per-shard window queues — the event-driven transport between the
//! batcher and the shard workers (DESIGN.md §9).
//!
//! The batcher **pushes** each closed batching window onto one shard's
//! deque; shard workers **pop** their own deque front-first and, when idle,
//! either *steal* the deepest live peer queue's oldest window (WorkSteal
//! policy) or *rescue* windows stranded on a dead shard's queue (every
//! policy — the queue-level form of the old dead-shard reroute). All pops
//! happen under one mutex, so a window leaves its queue exactly once no
//! matter how many idle workers race for it; an idle worker parks on the
//! condvar and is woken by pushes, deaths, and the stop signal.
//!
//! The structure is generic over the window type so the steal/rescue/stop
//! protocol is unit-testable without spinning up model replicas.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::par::lock;

/// Steal eligibility of a queued window. **Pinned** windows reference
/// shard-local state (a decoding sequence's KV pages live in its shard's
/// cache), so live peers must not steal them — the work would execute
/// against the wrong cache. Dead-shard rescue still removes pinned
/// windows: the rescuer cannot continue them, but it can fail them cleanly
/// (INVALID_TOKEN semantics), exactly once, instead of leaving callers
/// waiting forever on a channel nobody will ever close.
pub(crate) trait Pinnable {
    fn pinned(&self) -> bool {
        false
    }
}

struct QueueState<W> {
    queues: Vec<VecDeque<W>>,
    /// Shards that died (worker unwound); peers drain their queues.
    dead: Vec<bool>,
    /// Set once the batcher will push no more windows.
    stopping: bool,
}

/// What a shard worker's blocking pop resolved to.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum Popped<W> {
    /// The front window of the worker's own queue.
    Own(W),
    /// A window taken from shard `.1`'s queue (steal or dead-shard rescue).
    Stolen(W, usize),
    /// Stop signal observed with nothing left to drain: exit the loop.
    Stop,
}

pub(crate) struct ShardQueues<W> {
    state: Mutex<QueueState<W>>,
    /// Idle shard workers park here; pushes, deaths, and stop wake them.
    cv: Condvar,
    /// Queued + in-flight windows per shard (the shortest-queue dispatch
    /// signal; a steal transfers one count from victim to thief).
    depths: Vec<AtomicUsize>,
    /// Park → wake transitions per shard (occupancy telemetry).
    wakes: Vec<AtomicUsize>,
    /// High-water mark of each shard's depth counter — the deepest a queue
    /// ever got. Bounded-admission proof: under a `max_queued_windows` cap
    /// of C, every shard's HWM stays ≤ C no matter the offered load.
    hwm: Vec<AtomicUsize>,
}

impl<W: Pinnable> ShardQueues<W> {
    pub(crate) fn new(n_shards: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                queues: (0..n_shards).map(|_| VecDeque::new()).collect(),
                dead: vec![false; n_shards],
                stopping: false,
            }),
            cv: Condvar::new(),
            depths: (0..n_shards).map(|_| AtomicUsize::new(0)).collect(),
            wakes: (0..n_shards).map(|_| AtomicUsize::new(0)).collect(),
            hwm: (0..n_shards).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    /// Enqueue a window on `shard` and wake any parked workers. The depth
    /// counter is bumped under the same lock as the insert, so a worker can
    /// never observe the window without its depth.
    pub(crate) fn push(&self, shard: usize, window: W) {
        let mut st = lock(&self.state);
        let d = self.depths[shard].fetch_add(1, Ordering::SeqCst) + 1;
        self.hwm[shard].fetch_max(d, Ordering::SeqCst);
        st.queues[shard].push_back(window);
        drop(st);
        self.cv.notify_all();
    }

    /// Queued + in-flight windows per shard.
    pub(crate) fn depth_snapshot(&self) -> Vec<usize> {
        self.depths.iter().map(|d| d.load(Ordering::SeqCst)).collect()
    }

    /// Which shards have died so far.
    pub(crate) fn dead_snapshot(&self) -> Vec<bool> {
        lock(&self.state).dead.clone()
    }

    /// A shard finished (or abandoned) one window: release its depth slot.
    pub(crate) fn complete(&self, shard: usize) {
        self.depths[shard].fetch_sub(1, Ordering::SeqCst);
    }

    /// Mark `shard` dead and wake everyone so its queued windows get
    /// rescued (and parked peers can re-check the stop condition).
    pub(crate) fn mark_dead(&self, shard: usize) {
        let mut st = lock(&self.state);
        st.dead[shard] = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Signal that no more windows will be pushed; parked workers drain
    /// what is left and then observe `Popped::Stop`.
    pub(crate) fn stop(&self) {
        let mut st = lock(&self.state);
        st.stopping = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Park → wake transitions shard `shard` has been through.
    pub(crate) fn wake_count(&self, shard: usize) -> usize {
        self.wakes[shard].load(Ordering::Relaxed)
    }

    /// Deepest shard `shard`'s queue (queued + in-flight) has ever been.
    pub(crate) fn depth_hwm(&self, shard: usize) -> usize {
        self.hwm[shard].load(Ordering::SeqCst)
    }

    /// Per-shard depth high-water marks (diagnostics / timeout dumps).
    pub(crate) fn hwm_snapshot(&self) -> Vec<usize> {
        self.hwm.iter().map(|h| h.load(Ordering::SeqCst)).collect()
    }

    /// Blocking pop for shard `me`. Resolution order: own queue front →
    /// steal/rescue (deepest eligible peer queue's oldest window; dead
    /// peers are always eligible — any window — while live peers are
    /// eligible only when `steal` and only for their oldest **non-pinned**
    /// window: pinned windows are welded to their shard's local state) →
    /// stop → park. A returned `Own`/`Stolen` window occupies one depth
    /// slot on `me` until `complete(me)`. Pushes broadcast on one shared
    /// condvar — at fleet scale (a handful of shards) the futile wakes are
    /// cheaper than per-shard condvars, and they are NOT counted: a wake is
    /// recorded only when a worker that actually parked comes back with
    /// work, so the occupancy telemetry stays honest.
    pub(crate) fn pop(&self, me: usize, steal: bool) -> Popped<W> {
        let mut st = lock(&self.state);
        let mut parked = false;
        loop {
            if let Some(w) = st.queues[me].pop_front() {
                if parked {
                    self.wakes[me].fetch_add(1, Ordering::Relaxed);
                }
                return Popped::Own(w);
            }
            let victim = st
                .queues
                .iter()
                .enumerate()
                .filter(|&(j, q)| {
                    j != me
                        && if st.dead[j] {
                            !q.is_empty()
                        } else {
                            steal && q.iter().any(|w| !w.pinned())
                        }
                })
                .max_by_key(|&(j, q)| (q.len(), std::cmp::Reverse(j)))
                .map(|(j, _)| j);
            if let Some(j) = victim {
                let w = if st.dead[j] {
                    st.queues[j].pop_front().expect("victim queue non-empty under lock")
                } else {
                    let idx = st.queues[j]
                        .iter()
                        .position(|w| !w.pinned())
                        .expect("live victim has a stealable window under lock");
                    st.queues[j].remove(idx).expect("index in bounds under lock")
                };
                // the window's depth slot moves with it
                self.depths[j].fetch_sub(1, Ordering::SeqCst);
                let d = self.depths[me].fetch_add(1, Ordering::SeqCst) + 1;
                self.hwm[me].fetch_max(d, Ordering::SeqCst);
                if parked {
                    self.wakes[me].fetch_add(1, Ordering::Relaxed);
                }
                return Popped::Stolen(w, j);
            }
            if st.stopping {
                // the final stop wake hands no work: not counted
                return Popped::Stop;
            }
            parked = true;
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking drain of up to `max` **pinned** windows from shard
    /// `me`'s own queue, oldest first — the continuous-batching gather: a
    /// shard that just popped one decode job collects the rest of its
    /// queued decode work so the whole cohort advances through one fused
    /// batched step. Non-pinned windows (prefills) are left in place and
    /// keep their relative order, so classic windows are not starved or
    /// reordered. Each drained window still occupies one depth slot on
    /// `me`; the caller owes one `complete(me)` per window, exactly as if
    /// it had been popped — the shortest-queue signal keeps counting
    /// in-flight batch members until their step retires them.
    pub(crate) fn drain_pinned(&self, me: usize, max: usize) -> Vec<W> {
        let mut st = lock(&self.state);
        let mut out = Vec::new();
        let mut i = 0;
        while out.len() < max && i < st.queues[me].len() {
            if st.queues[me][i].pinned() {
                out.push(st.queues[me].remove(i).expect("index in bounds under lock"));
            } else {
                i += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    impl Pinnable for u32 {}

    /// Test window with an explicit pin bit.
    #[derive(Debug, PartialEq, Eq)]
    enum TW {
        Free(u32),
        Pinned(u32),
    }

    impl Pinnable for TW {
        fn pinned(&self) -> bool {
            matches!(self, TW::Pinned(_))
        }
    }

    #[test]
    fn pinned_windows_resist_live_steal_but_drain_at_home() {
        let q: ShardQueues<TW> = ShardQueues::new(2);
        q.push(0, TW::Pinned(1));
        q.push(0, TW::Free(2));
        q.push(0, TW::Pinned(3));
        // a live steal skips the pinned front and takes the oldest free window
        assert_eq!(q.pop(1, true), Popped::Stolen(TW::Free(2), 0));
        assert_eq!(q.depth_snapshot(), vec![2, 1], "depth slot moved with the steal");
        q.stop();
        // only pinned windows remain on the live peer: nothing to steal
        assert_eq!(q.pop(1, true), Popped::Stop);
        // the owner drains its pinned windows normally, in order
        assert_eq!(q.pop(0, true), Popped::Own(TW::Pinned(1)));
        assert_eq!(q.pop(0, true), Popped::Own(TW::Pinned(3)));
        assert_eq!(q.pop(0, true), Popped::Stop);
    }

    #[test]
    fn pinned_windows_are_rescued_from_dead_shards_exactly_once() {
        let q: ShardQueues<TW> = ShardQueues::new(3);
        q.push(0, TW::Pinned(7));
        q.push(0, TW::Free(8));
        q.mark_dead(0);
        // dead-shard rescue takes everything, oldest first, pinned included
        // (the serving layer fails rescued pinned windows cleanly)
        assert_eq!(q.pop(1, false), Popped::Stolen(TW::Pinned(7), 0));
        assert_eq!(q.pop(2, false), Popped::Stolen(TW::Free(8), 0));
        q.stop();
        assert_eq!(q.pop(1, false), Popped::Stop);
        assert_eq!(q.pop(2, true), Popped::Stop);
    }

    #[test]
    fn drain_pinned_gathers_fifo_and_leaves_free_windows_in_place() {
        let q: ShardQueues<TW> = ShardQueues::new(2);
        q.push(0, TW::Free(1));
        q.push(0, TW::Pinned(2));
        q.push(0, TW::Free(3));
        q.push(0, TW::Pinned(4));
        q.push(0, TW::Pinned(5));
        // capped drain takes the oldest pinned windows only
        assert_eq!(q.drain_pinned(0, 2), vec![TW::Pinned(2), TW::Pinned(4)]);
        // depth slots stay with the drained windows until completed
        assert_eq!(q.depth_snapshot(), vec![5, 0]);
        q.complete(0);
        q.complete(0);
        assert_eq!(q.depth_snapshot(), vec![3, 0]);
        // free windows kept their order; the remaining pinned one drains next
        assert_eq!(q.drain_pinned(0, 8), vec![TW::Pinned(5)]);
        q.complete(0);
        q.stop();
        assert_eq!(q.pop(0, false), Popped::Own(TW::Free(1)));
        assert_eq!(q.pop(0, false), Popped::Own(TW::Free(3)));
        q.complete(0);
        q.complete(0);
        assert_eq!(q.pop(0, false), Popped::Stop);
        assert_eq!(q.depth_snapshot(), vec![0, 0]);
        // an empty or foreign drain takes nothing
        assert_eq!(q.drain_pinned(0, 4), vec![]);
        assert_eq!(q.drain_pinned(1, 4), vec![]);
    }

    #[test]
    fn depth_hwm_records_the_deepest_queue_including_steal_transfers() {
        let q: ShardQueues<u32> = ShardQueues::new(2);
        q.push(0, 1);
        q.push(0, 2);
        q.push(0, 3);
        assert_eq!(q.depth_hwm(0), 3);
        assert_eq!(q.hwm_snapshot(), vec![3, 0]);
        assert_eq!(q.pop(0, false), Popped::Own(1));
        q.complete(0);
        // the HWM is sticky: draining does not lower it
        assert_eq!(q.depth_hwm(0), 3);
        // a steal transfers the depth slot and can raise the thief's HWM
        assert_eq!(q.pop(1, true), Popped::Stolen(2, 0));
        assert_eq!(q.depth_hwm(1), 1);
        assert_eq!(q.hwm_snapshot(), vec![3, 1]);
    }

    #[test]
    fn own_queue_drains_fifo() {
        let q: ShardQueues<u32> = ShardQueues::new(2);
        q.push(0, 10);
        q.push(0, 11);
        assert_eq!(q.depth_snapshot(), vec![2, 0]);
        assert_eq!(q.pop(0, false), Popped::Own(10));
        assert_eq!(q.pop(0, false), Popped::Own(11));
        q.complete(0);
        q.complete(0);
        assert_eq!(q.depth_snapshot(), vec![0, 0]);
        q.stop();
        assert_eq!(q.pop(0, false), Popped::Stop);
        assert_eq!(q.pop(1, true), Popped::Stop);
    }

    #[test]
    fn steal_takes_deepest_peers_oldest_window() {
        let q: ShardQueues<u32> = ShardQueues::new(3);
        q.push(1, 100);
        q.push(2, 200);
        q.push(2, 201);
        // shard 0 idles: steals from shard 2 (deepest), oldest first
        assert_eq!(q.pop(0, true), Popped::Stolen(200, 2));
        assert_eq!(q.depth_snapshot(), vec![1, 1, 1], "depth slot moved with the steal");
        // depth tie now: lowest shard id wins
        assert_eq!(q.pop(0, true), Popped::Stolen(100, 1));
        assert_eq!(q.pop(0, true), Popped::Stolen(201, 2));
        q.stop();
        assert_eq!(q.pop(0, true), Popped::Stop);
    }

    #[test]
    fn non_steal_policies_do_not_touch_live_peers() {
        let q: ShardQueues<u32> = ShardQueues::new(2);
        q.push(0, 1);
        q.stop();
        // shard 1 may not steal shard 0's live window: it sees Stop
        assert_eq!(q.pop(1, false), Popped::Stop);
        // shard 0 still drains it
        assert_eq!(q.pop(0, false), Popped::Own(1));
    }

    #[test]
    fn dead_shard_windows_are_rescued_exactly_once_under_any_policy() {
        let q: ShardQueues<u32> = ShardQueues::new(3);
        q.push(0, 7);
        q.push(0, 8);
        q.mark_dead(0);
        // even a non-stealing policy rescues orphaned windows, oldest first
        assert_eq!(q.pop(1, false), Popped::Stolen(7, 0));
        assert_eq!(q.pop(2, false), Popped::Stolen(8, 0));
        q.stop();
        // exactly once: nothing left to rescue afterwards
        assert_eq!(q.pop(1, false), Popped::Stop);
        assert_eq!(q.pop(2, true), Popped::Stop);
        assert!(q.dead_snapshot()[0]);
    }

    #[test]
    fn parked_worker_wakes_on_push_and_counts_the_transition() {
        let q: Arc<ShardQueues<u32>> = Arc::new(ShardQueues::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop(1, false));
        // generous margin so the worker has parked even on a loaded CI host
        std::thread::sleep(std::time::Duration::from_millis(200));
        q.push(1, 42);
        assert_eq!(h.join().unwrap(), Popped::Own(42));
        assert!(q.wake_count(1) >= 1, "the park -> wake transition is counted");
        assert_eq!(q.wake_count(0), 0);
    }

    #[test]
    fn stop_wakes_parked_workers() {
        let q: Arc<ShardQueues<u32>> = Arc::new(ShardQueues::new(1));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop(0, true));
        std::thread::sleep(std::time::Duration::from_millis(30));
        q.stop();
        assert_eq!(h.join().unwrap(), Popped::Stop);
    }
}
