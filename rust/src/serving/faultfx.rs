//! Deterministic fault injection for the serving fleet (DESIGN.md §13).
//!
//! Generalizes the old cfg(test) poison pill into a first-class chaos
//! harness: a `ChaosSchedule` describes, per shard, *when* that shard
//! crashes (panic before popping its Nth work item — the shard completed
//! its previous item fully, so queued work is rescued and every request
//! still resolves to exactly one terminal `Status`), *how slow* it runs
//! (a fixed stall before each work item), and *when* its KV-cache
//! admission is forced to fail (typed `KvExhausted`, never a mid-stream
//! corruption). Schedules are plain data derived from a seed, so a chaos
//! run is reproducible bit-for-bit: the injection points are logical work
//! -item ordinals, not wall-clock timers.
//!
//! Compiled under `cfg(test)` for the in-crate suites and under the
//! `chaos` cargo feature for the integration harness
//! (`rust/tests/chaos.rs`, `make test-chaos`). Production builds carry
//! none of this code.

use crate::rng::Xoshiro256pp;

/// Fault plan for one shard worker.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardFaults {
    /// Panic (simulated crash) immediately before taking the Nth work item
    /// (0-based): the previous item was fully answered, nothing is in
    /// flight, and the shard's queued windows are rescued by live peers.
    pub die_before_item: Option<usize>,
    /// Stall this long before handling every work item — the slow-shard /
    /// overload injection (drives load shedding and deadline expiry).
    pub stall_us: u64,
    /// Force every KV-cache admission from this ordinal on (0-based count
    /// of decode admissions on this shard) to fail as budget-exhausted.
    pub deny_kv_from: Option<usize>,
}

impl ShardFaults {
    pub fn is_noop(&self) -> bool {
        *self == ShardFaults::default()
    }
}

/// A whole fleet's injection schedule: one `ShardFaults` per shard.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ChaosSchedule {
    pub shards: Vec<ShardFaults>,
}

impl ChaosSchedule {
    /// Deterministic schedule for `n_shards` shards from one seed. One
    /// shard is always kept crash-free: an all-dead fleet cannot answer
    /// anything, and the harness property under test is that every
    /// submitted request still gets exactly one terminal response.
    pub fn seeded(seed: u64, n_shards: usize) -> Self {
        let mut rng = Xoshiro256pp::new(seed ^ 0x4348414f53); // "CHAOS"
        let survivor = rng.below(n_shards.max(1));
        let shards = (0..n_shards)
            .map(|i| {
                let mut f = ShardFaults::default();
                if i != survivor && rng.below(2) == 0 {
                    f.die_before_item = Some(rng.below(6));
                }
                if rng.below(3) == 0 {
                    f.stall_us = 200 + rng.below(2_000) as u64;
                }
                if rng.below(4) == 0 {
                    f.deny_kv_from = Some(rng.below(4));
                }
                f
            })
            .collect();
        Self { shards }
    }

    /// The fault plan for `shard` (no-fault default past the vector's end,
    /// so a schedule built for fewer shards degrades gracefully).
    pub fn for_shard(&self, shard: usize) -> ShardFaults {
        self.shards.get(shard).cloned().unwrap_or_default()
    }

    /// Does any shard carry any fault at all?
    pub fn is_noop(&self) -> bool {
        self.shards.iter().all(|f| f.is_noop())
    }
}

/// Per-worker runtime state driving a `ShardFaults` plan: counts work
/// items and KV admissions, firing each injection at its scheduled
/// ordinal.
pub(crate) struct FaultState {
    faults: ShardFaults,
    item: usize,
    kv_admissions: usize,
}

impl FaultState {
    pub(crate) fn new(faults: ShardFaults) -> Self {
        Self { faults, item: 0, kv_admissions: 0 }
    }

    /// Called at the top of every worker loop iteration, BEFORE popping:
    /// fires the scheduled crash (nothing is in flight, so rescue
    /// semantics answer everything exactly once) and the slow-shard stall.
    pub(crate) fn before_item(&mut self, shard: usize) {
        if self.faults.die_before_item == Some(self.item) {
            panic!("shard {shard}: chaos — scheduled crash before work item {}", self.item);
        }
        if self.faults.stall_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.faults.stall_us));
        }
        self.item += 1;
    }

    /// One KV admission decision: `true` forces this reservation to fail
    /// (the serving layer answers the request with `Status::KvExhausted`).
    pub(crate) fn deny_kv(&mut self) -> bool {
        let ordinal = self.kv_admissions;
        self.kv_admissions += 1;
        self.faults.deny_kv_from.is_some_and(|n| ordinal >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_schedules_are_deterministic_and_keep_a_survivor() {
        for seed in 0..64u64 {
            let a = ChaosSchedule::seeded(seed, 3);
            let b = ChaosSchedule::seeded(seed, 3);
            assert_eq!(a, b, "seed {seed}: schedule must be a pure function of the seed");
            assert_eq!(a.shards.len(), 3);
            let deaths = a.shards.iter().filter(|f| f.die_before_item.is_some()).count();
            assert!(deaths < 3, "seed {seed}: at least one shard must survive");
        }
        assert_ne!(
            ChaosSchedule::seeded(1, 3),
            ChaosSchedule::seeded(2, 3),
            "different seeds should explore different schedules"
        );
    }

    #[test]
    fn fault_state_fires_at_the_scheduled_ordinals() {
        let mut fs = FaultState::new(ShardFaults {
            die_before_item: None,
            stall_us: 0,
            deny_kv_from: Some(2),
        });
        assert!(!fs.deny_kv(), "admission 0 allowed");
        assert!(!fs.deny_kv(), "admission 1 allowed");
        assert!(fs.deny_kv(), "admission 2 denied");
        assert!(fs.deny_kv(), "everything after the threshold is denied");
        // item counting advances without firing when no death is scheduled
        fs.before_item(0);
        fs.before_item(0);
    }

    #[test]
    #[should_panic(expected = "chaos — scheduled crash")]
    fn scheduled_death_panics_at_its_item() {
        let mut fs = FaultState::new(ShardFaults {
            die_before_item: Some(1),
            stall_us: 0,
            deny_kv_from: None,
        });
        fs.before_item(7); // item 0: survives
        fs.before_item(7); // item 1: dies
    }
}
