//! Online precision controller (DESIGN.md §15): per-replica adaptive
//! requantization under live load.
//!
//! Each shard holds a `Controller` and calls it once per queue turn, at the
//! step boundary right after dequeue — the popped item has not started and
//! nothing else is in flight on that shard, so a swap committed here can
//! never tear a decode step. The controller compares the replica's memory
//! pressure (`QuantizedModel::resident_bytes` + live KV bytes) against the
//! configured watermarks:
//!
//! - **above `high_bytes`**: demote the lowest-entropy eligible block one
//!   rung down the Q8 → Q4 → Q3 ladder (the paper's layer-entropy result:
//!   low-entropy blocks tolerate aggressive quantization best, and the
//!   FastEWQ classifier confirms per-block eligibility in O(1) without
//!   touching weights);
//! - **below `low_bytes` with an idle queue**: promote the highest-entropy
//!   demoted block one rung back toward its plan-assigned ceiling.
//!
//! One rung per boundary keeps the off-hot-path repack cost bounded and
//! lets pressure re-evaluate between moves. The swap itself is
//! `QuantizedModel::requantize_block`: re-pack on the controller's thread,
//! publish via Arc swap — in-flight snapshots keep the old generation alive
//! until their step finishes, so streams spanning a swap stay well-formed
//! (the forced-swap properties in `tests/decode_equivalence.rs` pin this).
//!
//! Promotion has an information floor: a demoted block re-packs from its
//! current lattice, so Q8 → Q4 → Q8 restores the *bytes* but carries Q4
//! fidelity until a fresh build (`quant::repack`). That is the right
//! trade-off for a live replica — the alternative is keeping an f32 shadow
//! copy resident, which is exactly the footprint this controller exists to
//! shed.

use std::sync::Arc;

use crate::config::{ForcedSwap, ServeConfig};
use crate::ewq::QuantPlan;
use crate::fastewq::FastEwq;
use crate::model::QuantizedModel;
use crate::quant::Precision;
use crate::zoo::Schema;

/// One rung down the online ladder (Raw and T2 blocks are never touched:
/// Raw is a deliberate full-precision assignment, T2 has no lower rung and
/// promoting it would misrepresent its ternary lattice as Q3).
fn demote_rung(p: Precision) -> Option<Precision> {
    match p {
        Precision::Q8 => Some(Precision::Q4),
        Precision::Q4 => Some(Precision::Q3),
        _ => None,
    }
}

/// One rung back up the ladder.
fn promote_rung(p: Precision) -> Option<Precision> {
    match p {
        Precision::Q3 => Some(Precision::Q4),
        Precision::Q4 => Some(Precision::Q8),
        _ => None,
    }
}

/// Fleet-shared requant policy, built once at coordinator startup and
/// shared `Arc`-wise with every shard: which blocks may move, in what
/// entropy order, toward which ceilings, between which watermarks.
pub struct RequantPlan {
    /// Whether block `b` may be touched at all: its plan precision is on
    /// the Q8/Q4/Q3 ladder AND the FastEWQ classifier (when provided)
    /// marks it safe to quantize.
    pub eligible: Vec<bool>,
    /// Block indices in ascending entropy order (`QuantPlan::priority`):
    /// demotions walk it front-to-back (lowest entropy first), promotions
    /// back-to-front.
    pub order: Vec<usize>,
    /// Per-block promotion ceiling — the plan's assigned precision.
    pub ceiling: Vec<Precision>,
    /// Promote below this pressure (bytes), when the queue is idle.
    pub low_bytes: usize,
    /// Demote above this pressure (bytes).
    pub high_bytes: usize,
    /// Whether pressure-driven stepping is on (`ServeConfig::requant`).
    /// Scripted `ForcedSwap`s apply regardless, so equivalence tests can
    /// pin swap timing without enabling the pressure policy.
    pub auto: bool,
}

impl RequantPlan {
    pub fn build(
        cfg: &ServeConfig,
        schema: &Schema,
        plan: &QuantPlan,
        classifier: Option<&FastEwq>,
    ) -> Self {
        let n = schema.n_blocks;
        assert_eq!(plan.assignments.len(), n);
        // Every block matrix packs along k ∈ {d_model, d_ff}; Q3 (the
        // ladder's strictest rung) needs k % 8 == 0, so a model whose dims
        // break that must never enter the demotion path — `quant::repack`
        // would assert mid-serve. Gate it here, once, for the whole fleet.
        let dims_ok = schema.d_model % 8 == 0 && schema.d_ff % 8 == 0;
        let eligible: Vec<bool> = (0..n)
            .map(|b| {
                let on_ladder = matches!(
                    plan.assignments[b],
                    Precision::Q8 | Precision::Q4 | Precision::Q3
                );
                dims_ok && on_ladder && classifier.map_or(true, |c| c.classify_block(schema, b))
            })
            .collect();
        // plans built without entropy analysis (uniform) carry an identity
        // priority; tolerate a malformed one rather than panic a shard
        let order: Vec<usize> = if plan.priority.len() == n
            && plan.priority.iter().all(|&b| b < n)
        {
            plan.priority.clone()
        } else {
            (0..n).collect()
        };
        Self {
            eligible,
            order,
            ceiling: plan.assignments.clone(),
            low_bytes: (cfg.requant_low_mb.max(0.0) * 1e6) as usize,
            high_bytes: (cfg.requant_high_mb.max(0.0) * 1e6) as usize,
            auto: cfg.requant,
        }
    }
}

/// Per-shard controller state: the shared policy, this shard's progress
/// through the scripted swap schedule, and its swap accounting (surfaced
/// as `ServingMetrics::requant_*` at shard exit).
pub struct Controller {
    plan: Arc<RequantPlan>,
    /// Scripted swaps sorted by `after_item`; `forced_idx` is the cursor.
    forced: Vec<ForcedSwap>,
    forced_idx: usize,
    /// Swaps committed (forced + pressure-driven; same-rung no-ops excluded).
    pub swaps: usize,
    /// Bytes released by demotions.
    pub bytes_freed: usize,
    /// Bytes re-acquired by promotions.
    pub bytes_regrown: usize,
}

impl Controller {
    pub fn new(plan: Arc<RequantPlan>, mut forced: Vec<ForcedSwap>) -> Self {
        forced.sort_by_key(|f| f.after_item);
        Self { plan, forced, forced_idx: 0, swaps: 0, bytes_freed: 0, bytes_regrown: 0 }
    }

    /// Commit one swap and book its bytes. Returns false (and commits
    /// nothing) when the block is already at `target`.
    fn swap(&mut self, qm: &QuantizedModel, block: usize, target: Precision) -> bool {
        if qm.blocks[block].prec() == target {
            return false;
        }
        let (old, new) = qm.requantize_block(block, target);
        self.swaps += 1;
        if new < old {
            self.bytes_freed += old - new;
        } else {
            self.bytes_regrown += new - old;
        }
        true
    }

    /// Fire every scripted swap whose `after_item <= item_ord`, in schedule
    /// order. `item_ord` is how many work items this shard dequeued
    /// *before* the current one, so `after_item: k` lands at the boundary
    /// between the shard's k-th and (k+1)-th item.
    pub fn force(&mut self, qm: &QuantizedModel, item_ord: usize) {
        while self.forced_idx < self.forced.len()
            && self.forced[self.forced_idx].after_item <= item_ord
        {
            let f = self.forced[self.forced_idx].clone();
            self.forced_idx += 1;
            self.swap(qm, f.block, f.prec);
        }
    }

    /// One pressure evaluation at a step boundary. At most one rung moves
    /// per call. Returns whether a swap was committed.
    pub fn step(&mut self, qm: &QuantizedModel, kv_bytes: usize, queue_idle: bool) -> bool {
        if !self.plan.auto {
            return false;
        }
        let pressure = qm.resident_bytes() + kv_bytes;
        if pressure > self.plan.high_bytes {
            for &b in &self.plan.order {
                if !self.plan.eligible[b] {
                    continue;
                }
                if let Some(t) = demote_rung(qm.blocks[b].prec()) {
                    return self.swap(qm, b, t);
                }
            }
        } else if pressure < self.plan.low_bytes && queue_idle {
            for &b in self.plan.order.iter().rev() {
                if !self.plan.eligible[b] {
                    continue;
                }
                let cur = qm.blocks[b].prec();
                if cur < self.plan.ceiling[b] {
                    if let Some(t) = promote_rung(cur) {
                        return self.swap(qm, b, t);
                    }
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};

    fn arch(n_blocks: usize) -> SyntheticArch {
        SyntheticArch {
            schema: Schema {
                name: "requant-ctl".into(),
                n_blocks,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 256,
                seq_len: 16,
                eval_batch: 4,
            },
            profile: Profile::RampUp,
            seed: 31,
        }
    }

    fn model_and_plan(n: usize, prec: Precision) -> (QuantizedModel, QuantPlan) {
        let model = synthetic_model_dir(&arch(n));
        let plan = QuantPlan::uniform("m", n, prec);
        (QuantizedModel::build(&model, &plan).unwrap(), plan)
    }

    fn cfg(low_mb: f64, high_mb: f64, auto: bool) -> ServeConfig {
        ServeConfig {
            requant: auto,
            requant_low_mb: low_mb,
            requant_high_mb: high_mb,
            ..Default::default()
        }
    }

    #[test]
    fn plan_eligibility_excludes_off_ladder_blocks_and_respects_priority() {
        let model = synthetic_model_dir(&arch(4));
        let mut plan = QuantPlan::uniform("m", 4, Precision::Q8);
        plan.assignments[1] = Precision::Raw;
        plan.assignments[2] = Precision::T2;
        plan.priority = vec![3, 0, 2, 1];
        let qm = QuantizedModel::build(&model, &plan).unwrap();
        let rp = RequantPlan::build(&cfg(1.0, 2.0, true), &qm.schema, &plan, None);
        assert_eq!(rp.eligible, vec![true, false, false, true]);
        assert_eq!(rp.order, vec![3, 0, 2, 1]);
        assert_eq!(rp.ceiling, plan.assignments);
        assert_eq!(rp.low_bytes, 1_000_000);
        assert_eq!(rp.high_bytes, 2_000_000);
        // a malformed priority falls back to identity order
        let mut bad = plan.clone();
        bad.priority = vec![9, 9];
        let rp = RequantPlan::build(&cfg(1.0, 2.0, true), &qm.schema, &bad, None);
        assert_eq!(rp.order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn pressure_demotes_in_entropy_order_down_the_ladder() {
        let (qm, plan) = model_and_plan(4, Precision::Q8);
        // high watermark of 0 bytes is unreachable-low: always over pressure
        let rp = Arc::new(RequantPlan::build(&cfg(0.0, 1e-9, true), &qm.schema, &plan, None));
        let mut ctl = Controller::new(rp, Vec::new());
        let start = qm.resident_bytes();
        // priority is ascending entropy; demotions must follow it
        let order = plan.priority.clone();
        assert!(ctl.step(&qm, 0, false));
        assert_eq!(qm.blocks[order[0]].prec(), Precision::Q4, "lowest entropy demotes first");
        assert!(ctl.step(&qm, 0, false));
        assert_eq!(qm.blocks[order[0]].prec(), Precision::Q3, "same block takes the next rung");
        assert!(ctl.step(&qm, 0, false));
        assert_eq!(qm.blocks[order[1]].prec(), Precision::Q4, "then the next-lowest block");
        assert_eq!(ctl.swaps, 3);
        assert_eq!(ctl.bytes_regrown, 0);
        assert_eq!(start - qm.resident_bytes(), ctl.bytes_freed, "books reconcile");
        // exhaust the ladder: every eligible block bottoms out at Q3, then
        // pressure steps become no-ops instead of thrashing
        while ctl.step(&qm, 0, false) {}
        assert!(qm.blocks.iter().all(|b| b.prec() == Precision::Q3));
        assert!(!ctl.step(&qm, 0, false));
    }

    #[test]
    fn idle_promotion_returns_to_ceiling_and_books_reconcile() {
        let (qm, plan) = model_and_plan(3, Precision::Q8);
        let start = qm.resident_bytes();
        // huge watermarks: always under the low mark
        let rp = Arc::new(RequantPlan::build(&cfg(1e6, 2e6, true), &qm.schema, &plan, None));
        let mut ctl = Controller::new(rp, Vec::new());
        // pre-demote two blocks via the forced path
        ctl.force_swap_for_test(&qm, 0, Precision::Q3);
        ctl.force_swap_for_test(&qm, 2, Precision::Q4);
        assert_eq!(ctl.swaps, 2);
        // busy queue blocks promotion
        assert!(!ctl.step(&qm, 0, false));
        // idle: promote one rung per boundary until every block is back at
        // its plan ceiling
        let mut guard = 0;
        while ctl.step(&qm, 0, true) {
            guard += 1;
            assert!(guard < 10, "promotion must terminate");
        }
        assert!(qm.blocks.iter().all(|b| b.prec() == Precision::Q8));
        assert_eq!(qm.resident_bytes(), start, "byte accounting returns to the ceiling");
        assert_eq!(
            ctl.bytes_freed, ctl.bytes_regrown,
            "freed and regrown reconcile after a full round trip"
        );
        // at ceiling + idle: no-op, never promotes past the plan
        assert!(!ctl.step(&qm, 0, true));
    }

    #[test]
    fn kv_bytes_count_toward_pressure() {
        let (qm, plan) = model_and_plan(2, Precision::Q8);
        let resident = qm.resident_bytes();
        // high watermark just above the weights alone: weights-only is calm,
        // weights + KV is over
        let high_mb = (resident + 1) as f64 / 1e6;
        let rp =
            Arc::new(RequantPlan::build(&cfg(high_mb / 2.0, high_mb, true), &qm.schema, &plan, None));
        let mut ctl = Controller::new(rp, Vec::new());
        assert!(!ctl.step(&qm, 0, false), "no KV pressure: no swap");
        assert!(ctl.step(&qm, 4096, false), "KV bytes push pressure over the mark");
    }

    #[test]
    fn forced_schedule_fires_in_item_order_and_skips_noops() {
        let (qm, _plan) = model_and_plan(2, Precision::Q8);
        let plan = QuantPlan::uniform("m", 2, Precision::Q8);
        let rp = Arc::new(RequantPlan::build(&cfg(1.0, 2.0, false), &qm.schema, &plan, None));
        let forced = vec![
            ForcedSwap { after_item: 3, block: 0, prec: Precision::Q8 }, // no-op rung
            ForcedSwap { after_item: 1, block: 0, prec: Precision::Q4 },
            ForcedSwap { after_item: 3, block: 1, prec: Precision::Q3 },
        ];
        let mut ctl = Controller::new(rp, forced);
        ctl.force(&qm, 0);
        assert_eq!(ctl.swaps, 0, "nothing due before item 1");
        assert_eq!(qm.blocks[0].prec(), Precision::Q8);
        ctl.force(&qm, 1);
        assert_eq!(qm.blocks[0].prec(), Precision::Q4);
        assert_eq!(ctl.swaps, 1);
        ctl.force(&qm, 5);
        assert_eq!(qm.blocks[1].prec(), Precision::Q3, "late swaps catch up");
        assert_eq!(ctl.swaps, 2, "the same-rung scripted swap is not counted");
        // auto is off: pressure stepping never fires even over the mark
        assert!(!ctl.step(&qm, usize::MAX / 2, false));
    }

    #[test]
    fn ladder_incompatible_dims_disable_every_block() {
        // d_model = 96 is ladder-safe; a schema with d_ff not divisible by 8
        // must come back fully ineligible so the controller never demotes
        // into a rung `quant::repack` would reject.
        let (qm, plan) = model_and_plan(3, Precision::Q8);
        let mut bad = qm.schema.clone();
        bad.d_ff = 100; // % 8 != 0
        let rp = RequantPlan::build(&cfg(0.0, 1e-9, true), &bad, &plan, None);
        assert!(rp.eligible.iter().all(|&e| !e));
        let mut ctl = Controller::new(Arc::new(rp), Vec::new());
        assert!(!ctl.step(&qm, usize::MAX / 2, false), "no eligible block: no swap under pressure");
        assert_eq!(ctl.swaps, 0);
    }

    #[test]
    fn classifier_gates_eligibility() {
        use crate::ewq::EwqConfig;
        use crate::fastewq::{build_dataset, FastEwq};
        let (qm, plan) = model_and_plan(3, Precision::Q8);
        let rows = build_dataset(150, 9, &[], &EwqConfig::default());
        let fe = FastEwq::train(&rows, 12, 5, 3);
        let rp = RequantPlan::build(&cfg(1.0, 2.0, true), &qm.schema, &plan, Some(&fe));
        // the classifier's verdict — whatever it is for this tiny synthetic
        // schema — must be what gates eligibility block-for-block
        let verdicts = fe.classify_model(&qm.schema);
        assert_eq!(rp.eligible, verdicts);
    }

    impl Controller {
        /// Test seam: commit one swap outside a schedule.
        fn force_swap_for_test(&mut self, qm: &QuantizedModel, block: usize, prec: Precision) {
            self.swap(qm, block, prec);
        }
    }
}
