//! Serving coordinator — the L3 request path, sharded across N workers.
//!
//! Topology: the front end submits requests over a channel to a **batcher**
//! thread; a dynamic batching window groups up to `max_batch` requests or
//! waits at most `max_wait`, then places the whole batch on one of
//! `ServeConfig::workers` per-shard **queues** (`serving::queues`). Shard
//! workers drain their own queue front-first and run event-driven: an idle
//! shard parks on the queue condvar, is woken by pushes, and — under
//! `DispatchPolicy::WorkSteal`, the default — steals the deepest peer
//! queue's oldest window instead of idling while a neighbour is backed up.
//! `ShortestQueue` (producer-side balancing by queued + in-flight depth)
//! and `RoundRobin` (blind rotation) are kept as comparison policies. Each
//! shard owns a full model replica (its own `Runtime` — the PJRT client is
//! not `Send`, so it is created inside the shard thread — plus its own
//! `QuantizedModel`, resident at **packed** size: the native executor
//! serves straight from the `QMat` payloads through the fused kernels) and
//! answers every request in the batch.
//!
//! Responses are batching-, shard-, and policy-invariant: attention never
//! mixes batch rows, padding rows are zeros, and every replica is built
//! from the same plan — so a request's `next_token` is identical whether it
//! is served by 1 worker or N, under any dispatch policy. Shard-level
//! `ShardOccupancy` (including steal and park/wake counts) is folded into
//! the aggregate metrics via `ServingMetrics::merge` at shutdown.
//!
//! Fault containment: a shard that unwinds marks itself dead on the shared
//! queues and its stranded windows are **rescued** — popped exactly once —
//! by live peers under every policy (see `queues::ShardQueues::pop`).
//!
//! Cross-machine block placement (from `cluster::Distribution`) is simulated:
//! each batch is charged `hops × link_latency` of virtual network time,
//! reported separately from wall-clock latency.

pub mod kvcache;
mod queues;
pub mod trace;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DispatchPolicy, ServeConfig};
use crate::ewq::QuantPlan;
use crate::model::{ModelExecutor, QuantizedModel};
use crate::par::Pool;
use crate::runtime::Runtime;
use crate::serving::queues::{Popped, ShardQueues};
use crate::zoo::ModelDir;

/// One generation request: a token context, answered with the next token.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub context: Vec<i32>,
    submitted: Instant,
    resp: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// wall-clock queue+compute latency
    pub latency: Duration,
    /// simulated cross-machine network time for the batch
    pub network_latency_us: u64,
    pub batch_size: usize,
    /// which shard worker executed the batch
    pub shard: usize,
}

/// Sentinel `next_token` for requests whose context contains tokens outside
/// the model vocabulary — answered immediately, never executed.
pub const INVALID_TOKEN: i32 = -1;

/// Test-only: a context whose first token is this sentinel panics the shard
/// that picks its window up — the deterministic "shard dies mid-flight"
/// trigger for the dead-shard rescue tests.
#[cfg(test)]
pub(crate) const POISON_CONTEXT: i32 = i32::MIN;

enum Msg {
    Req(Request),
    Stop(Sender<ServingMetrics>),
}

/// A closed batching window en route to (or parked on) a shard queue.
type Window = Vec<Request>;

/// Per-shard execution accounting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardOccupancy {
    pub shard: usize,
    pub completed: usize,
    pub batches: usize,
    /// time spent executing batches (excludes idle waiting)
    pub busy_us: u64,
    /// windows this shard took from peers' queues (work stealing under
    /// `DispatchPolicy::WorkSteal`, dead-shard rescues under every policy)
    pub steals: usize,
    /// park → wake transitions on the shared queue condvar (how often the
    /// worker went idle and was handed new work)
    pub wakes: usize,
}

impl ShardOccupancy {
    /// Fraction of the serving wall-clock this shard spent executing.
    pub fn occupancy(&self, wall: Duration) -> f64 {
        let wall_us = wall.as_micros() as f64;
        if wall_us <= 0.0 {
            return 0.0;
        }
        (self.busy_us as f64 / wall_us).min(1.0)
    }
}

/// Aggregate serving metrics (single shard, or merged across shards).
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub completed: usize,
    /// Requests answered with `INVALID_TOKEN` without executing (counted in
    /// `completed`, excluded from latency/batch aggregates).
    pub rejected: usize,
    pub batches: usize,
    pub latencies_us: Vec<u64>,
    pub wall_time: Duration,
    pub max_batch_observed: usize,
    pub virtual_network_us: u64,
    /// Resident weight bytes across all replicas (each shard reports its
    /// `QuantizedModel::resident_bytes`; `merge` sums them) — the packed
    /// footprint the memory-reduction claim is measured by.
    pub resident_weight_bytes: usize,
    /// Windows taken from peer queues across all shards (steals + rescues).
    pub steals: usize,
    /// Shard-worker park → wake transitions across all shards.
    pub wakes: usize,
    /// One entry per shard worker (sorted by shard id after `merge`).
    pub shards: Vec<ShardOccupancy>,
}

impl ServingMetrics {
    /// Nearest-rank percentile: index ceil(p·n) − 1, clamped to the sample
    /// range (so p=0 is the min and p=1 the max).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = (p * v.len() as f64).ceil() as usize;
        v[rank.saturating_sub(1).min(v.len() - 1)]
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_time.as_secs_f64().max(1e-9)
    }

    /// Mean EXECUTED requests per batch (rejects never enter a batch).
    pub fn mean_batch(&self) -> f64 {
        (self.completed - self.rejected) as f64 / self.batches.max(1) as f64
    }

    /// Fold another shard's (or coordinator's) metrics into this aggregate:
    /// counters add, latencies concatenate, wall-clock takes the max, shard
    /// occupancy records append.
    pub fn merge(&mut self, other: ServingMetrics) {
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.batches += other.batches;
        self.latencies_us.extend(other.latencies_us);
        self.wall_time = self.wall_time.max(other.wall_time);
        self.max_batch_observed = self.max_batch_observed.max(other.max_batch_observed);
        self.virtual_network_us += other.virtual_network_us;
        self.resident_weight_bytes += other.resident_weight_bytes;
        self.steals += other.steals;
        self.wakes += other.wakes;
        self.shards.extend(other.shards);
        self.shards.sort_by_key(|s| s.shard);
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs in {:?} ({:.1} req/s), batches {} (mean {:.2}, max {}), \
             p50 {}us p95 {}us p99 {}us, virtual-net {}us",
            self.completed,
            self.wall_time,
            self.throughput_rps(),
            self.batches,
            self.mean_batch(),
            self.max_batch_observed,
            self.percentile_us(0.50),
            self.percentile_us(0.95),
            self.percentile_us(0.99),
            self.virtual_network_us,
        );
        if self.rejected > 0 {
            s.push_str(&format!(", rejected {}", self.rejected));
        }
        if self.steals > 0 {
            s.push_str(&format!(", steals {}", self.steals));
        }
        if self.resident_weight_bytes > 0 {
            s.push_str(&format!(
                ", resident {}",
                crate::report::bytes_human(self.resident_weight_bytes)
            ));
        }
        if self.shards.len() > 1 {
            let occ: Vec<String> = self
                .shards
                .iter()
                .map(|sh| {
                    format!(
                        "s{}:{}r/{:.0}%",
                        sh.shard,
                        sh.completed,
                        100.0 * sh.occupancy(self.wall_time)
                    )
                })
                .collect();
            s.push_str(&format!(", shards [{}]", occ.join(" ")));
        }
        s
    }
}

/// Handle to a running sharded coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Load the model from disk and start the shard workers + batcher.
    /// `network_hops` is the placement's hop count (0 = single machine);
    /// `link_latency_us` is charged per hop per batch.
    pub fn start(
        model_path: std::path::PathBuf,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let model = ModelDir::load(&model_path)?;
        Self::start_with_model(model, plan, cfg, network_hops, link_latency_us)
    }

    /// Start from an already-loaded (possibly synthetic, artifact-less)
    /// model: each of `cfg.workers` shards gets its own replica clone.
    pub fn start_with_model(
        model: ModelDir,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let n_shards = cfg.workers.max(1);
        let net_us = network_hops as u64 * link_latency_us;
        let batch_cap = cfg.max_batch.min(model.schema.eval_batch).max(1);
        let policy = cfg.dispatch;
        let fwd_workers = cfg.forward_workers.max(1);

        // the shared per-shard window queues the whole fleet drains
        let queues: Arc<ShardQueues<Window>> = Arc::new(ShardQueues::new(n_shards));

        // spawn shard workers, each owning a replica
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let (res_tx, res_rx) = channel::<ServingMetrics>();
        let mut shard_handles = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let replica = model.clone();
            let plan = plan.clone();
            let ready = ready_tx.clone();
            let results = res_tx.clone();
            let q = queues.clone();
            let ctx = ShardCtx { shard, net_us, fwd_workers, steal: policy.steals() };
            let handle = std::thread::Builder::new()
                .name(format!("ewq-shard-{shard}"))
                .spawn(move || {
                    if let Err(e) = shard_worker(ctx, replica, plan, q, ready, results) {
                        eprintln!("shard {shard} failed: {e:#}");
                    }
                })
                .context("spawn shard worker")?;
            shard_handles.push(handle);
        }
        drop(ready_tx);
        drop(res_tx);
        // block until every shard has loaded + compiled + warmed its replica
        // so request latencies never include one-off startup cost
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    queues.stop(); // release the shards that did come up
                    anyhow::bail!("shard startup failed: {msg}");
                }
                Err(_) => {
                    queues.stop();
                    anyhow::bail!("a shard died during startup");
                }
            }
        }

        // batcher thread: groups requests into windows, places them under
        // `cfg.dispatch`; idle shards drain/steal without its involvement
        let (tx, rx) = channel::<Msg>();
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let fleet = Fleet { queues, handles: shard_handles, results: res_rx, policy };
        let handle = std::thread::Builder::new()
            .name("ewq-batcher".into())
            .spawn(move || batcher(rx, fleet, batch_cap, max_wait))
            .context("spawn batcher")?;
        Ok(Self { tx, handle: Some(handle), next_id: 0.into() })
    }

    /// Submit a context; returns the response receiver.
    pub fn submit(&self, context: Vec<i32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = self.tx.send(Msg::Req(Request {
            id,
            context,
            submitted: Instant::now(),
            resp: rtx,
        }));
        rrx
    }

    /// Stop batcher + shards and collect the merged metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Stop(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// The batcher's handle on the shard fleet: the shared queues, the worker
/// join handles, the metrics return channel, and the dispatch policy.
struct Fleet {
    queues: Arc<ShardQueues<Window>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    results: Receiver<ServingMetrics>,
    policy: DispatchPolicy,
}

/// Candidate order for shortest-queue dispatch: shard indices sorted by
/// (queue depth, shard id). The head is the dispatch target; the tail is
/// the fallback order when the head shard is dead.
fn shortest_queue_order(depths: &[usize]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..depths.len()).collect();
    idx.sort_by_key(|&i| (depths[i], i));
    idx
}

/// Place one closed window on a shard queue under `policy`, skipping dead
/// shards. Windows that land on a shard that dies before draining them are
/// rescued by live peers inside `ShardQueues::pop`, so placement is only a
/// heuristic — never a correctness concern.
fn place_window(queues: &ShardQueues<Window>, policy: DispatchPolicy, rr: &mut usize, w: Window) {
    let dead = queues.dead_snapshot();
    let alive: Vec<usize> = (0..dead.len()).filter(|&i| !dead[i]).collect();
    if alive.is_empty() {
        // responders drop with the window; callers observe closed channels
        eprintln!("batcher: all shards dead; dropping batch of {}", w.len());
        return;
    }
    let target = match policy {
        // WorkSteal places blindly — consumers repair imbalance themselves
        DispatchPolicy::RoundRobin | DispatchPolicy::WorkSteal => {
            let t = alive[*rr % alive.len()];
            *rr += 1;
            t
        }
        DispatchPolicy::ShortestQueue => {
            let depths = queues.depth_snapshot();
            *shortest_queue_order(&depths)
                .iter()
                .find(|&&i| !dead[i])
                .expect("alive is non-empty")
        }
    };
    queues.push(target, w);
}

/// The shared dynamic batcher: owns the request queue, closes batching
/// windows, and places them on the per-shard queues.
fn batcher(rx: Receiver<Msg>, fleet: Fleet, batch_cap: usize, max_wait: Duration) {
    let started = Instant::now();
    let mut rr = 0usize;
    let mut pending: Vec<Request> = Vec::new();
    let Fleet { queues, handles, results, policy } = fleet;

    // Stop the fleet: after `queues.stop()` the shard workers drain every
    // remaining window (their own, stolen, or rescued) and report metrics
    // before exiting, so joining the handles drains all work.
    let finalize = |mtx: Option<Sender<ServingMetrics>>,
                    handles: Vec<std::thread::JoinHandle<()>>| {
        queues.stop();
        for h in handles {
            let _ = h.join();
        }
        if let Some(mtx) = mtx {
            let mut agg = ServingMetrics::default();
            while let Ok(m) = results.try_recv() {
                agg.merge(m);
            }
            agg.wall_time = started.elapsed();
            let _ = mtx.send(agg);
        }
    };

    loop {
        // blocking wait for the first request (or stop)
        if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    finalize(Some(mtx), handles);
                    return;
                }
                Err(_) => {
                    // front end dropped without shutdown: stop shards quietly
                    finalize(None, handles);
                    return;
                }
            }
        }
        // dynamic batching window
        let window_start = Instant::now();
        let mut stop: Option<Sender<ServingMetrics>> = None;
        while pending.len() < batch_cap && window_start.elapsed() < max_wait {
            match rx.recv_timeout(max_wait.saturating_sub(window_start.elapsed())) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    stop = Some(mtx);
                    break;
                }
                Err(_) => break,
            }
        }
        let batch: Vec<Request> = pending.drain(..).collect();
        if !batch.is_empty() {
            place_window(&queues, policy, &mut rr, batch);
        }
        if let Some(mtx) = stop {
            finalize(Some(mtx), handles);
            return;
        }
    }
}

/// Per-shard wiring passed into the worker thread.
struct ShardCtx {
    shard: usize,
    net_us: u64,
    /// pool workers inside the replica's native forward pass
    fwd_workers: usize,
    /// whether this worker may steal queued windows from live peers
    steal: bool,
}

/// Marks the shard dead on every non-clean exit (panic mid-batch, setup
/// failure) so peers rescue its queued windows and parked workers re-check
/// the stop condition.
struct DeathGuard {
    shard: usize,
    queues: Arc<ShardQueues<Window>>,
    armed: bool,
}

impl Drop for DeathGuard {
    fn drop(&mut self) {
        if self.armed {
            self.queues.mark_dead(self.shard);
        }
    }
}

/// One shard worker: owns a model replica and drains the shared queues.
fn shard_worker(
    ctx: ShardCtx,
    model: ModelDir,
    plan: QuantPlan,
    queues: Arc<ShardQueues<Window>>,
    ready: Sender<std::result::Result<(), String>>,
    results: Sender<ServingMetrics>,
) -> Result<()> {
    let ShardCtx { shard, net_us, fwd_workers, steal } = ctx;
    let mut guard = DeathGuard { shard, queues: queues.clone(), armed: true };
    // Runtime lives entirely inside this thread (PJRT client is not Send).
    let setup = (|| -> Result<_> {
        let rt = Runtime::cpu()?;
        let qm = QuantizedModel::build(&model, &plan)?;
        Ok((rt, qm))
    })();
    let (rt, qm) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    let ex = ModelExecutor::with_pool(&rt, &model, Pool::new(fwd_workers));
    let (b, s) = (model.schema.eval_batch, model.schema.seq_len);
    let v = model.schema.vocab;
    // the executor keeps its own schema/dir copies and the quantized replica
    // is self-contained — drop the fp32 weights instead of pinning a second
    // full-precision copy of the model per shard for the thread's lifetime.
    // (The replica itself is resident at *packed* size: the fused kernels
    // consume the QMat payloads directly, no f32 shadow copies.)
    drop(model);
    if let Err(e) = ex.warmup() {
        let _ = ready.send(Err(format!("{e:#}")));
        return Err(e);
    }
    let _ = ready.send(Ok(()));

    let mut metrics = ServingMetrics {
        resident_weight_bytes: qm.resident_bytes(),
        ..Default::default()
    };
    let mut occ = ShardOccupancy { shard, ..Default::default() };
    let started = Instant::now();

    loop {
        let (batch, stolen) = match queues.pop(shard, steal) {
            Popped::Own(w) => (w, false),
            Popped::Stolen(w, _from) => (w, true),
            Popped::Stop => break,
        };
        #[cfg(test)]
        if batch.iter().any(|r| r.context.first() == Some(&POISON_CONTEXT)) {
            panic!("shard {shard}: poison request — simulated mid-flight crash");
        }
        if stolen {
            occ.steals += 1;
        }
        execute_batch(batch, &ex, &qm, (b, s, v), (shard, net_us), &mut metrics, &mut occ);
        // done (or rejected/failed): release the window's depth slot so the
        // shortest-queue heuristic sees this shard as free again
        queues.complete(shard);
    }
    guard.armed = false;
    occ.wakes = queues.wake_count(shard);
    metrics.steals = occ.steals;
    metrics.wakes = occ.wakes;
    metrics.wall_time = started.elapsed();
    metrics.shards = vec![occ];
    let _ = results.send(metrics);
    Ok(())
}

/// Execute one dispatched batch on a shard's replica: reject out-of-vocab
/// contexts, pad, forward, answer. Split out of `shard_worker` so every
/// early exit still falls through to the queue-depth release.
fn execute_batch(
    batch: Vec<Request>,
    ex: &ModelExecutor<'_>,
    qm: &QuantizedModel,
    (b, s, v): (usize, usize, usize),
    (shard, net_us): (usize, u64),
    metrics: &mut ServingMetrics,
    occ: &mut ShardOccupancy,
) {
    let exec_start = Instant::now();
    // reject out-of-vocab contexts up front: the executor validates token
    // range, and one malformed request must never kill the shard (and with
    // it 1/N of all traffic). Only the seq_len prefix is validated — the
    // tail beyond it is truncated away and never executed.
    let (batch, rejected): (Vec<Request>, Vec<Request>) = batch.into_iter().partition(|r| {
        r.context[..r.context.len().min(s)].iter().all(|&t| t >= 0 && (t as usize) < v)
    });
    for r in rejected {
        // answered but never executed: counted separately and excluded
        // from the latency/batch aggregates
        metrics.completed += 1;
        metrics.rejected += 1;
        occ.completed += 1;
        let _ = r.resp.send(Response {
            id: r.id,
            next_token: INVALID_TOKEN,
            latency: r.submitted.elapsed(),
            network_latency_us: 0,
            batch_size: 0,
            shard,
        });
    }
    if batch.is_empty() {
        return;
    }
    // execute one padded batch
    let mut toks = vec![0i32; b * s];
    let mut pos = vec![0usize; batch.len()];
    for (row, r) in batch.iter().enumerate() {
        let ctx = &r.context[..r.context.len().min(s)];
        toks[row * s..row * s + ctx.len()].copy_from_slice(ctx);
        pos[row] = ctx.len().saturating_sub(1);
    }
    let logits = match ex.forward(qm, &toks) {
        Ok(l) => l,
        Err(e) => {
            // drop this batch's responses (callers see a closed channel)
            // but keep the shard alive for future work
            eprintln!("shard {shard}: batch of {} failed: {e:#}", batch.len());
            return;
        }
    };
    metrics.batches += 1;
    metrics.max_batch_observed = metrics.max_batch_observed.max(batch.len());
    metrics.virtual_network_us += net_us;
    for (row, r) in batch.iter().enumerate() {
        let base = (row * s + pos[row]) * v;
        // total_cmp: a NaN logit must not panic the shard thread
        let next = logits[base..base + v]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap();
        let latency = r.submitted.elapsed();
        metrics.completed += 1;
        metrics.latencies_us.push(latency.as_micros() as u64);
        let _ = r.resp.send(Response {
            id: r.id,
            next_token: next,
            latency,
            network_latency_us: net_us,
            batch_size: batch.len(),
            shard,
        });
    }
    occ.batches += 1;
    occ.completed += batch.len();
    occ.busy_us += exec_start.elapsed().as_micros() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::quant::Precision;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
    use crate::zoo::Schema;

    const ALL_POLICIES: [DispatchPolicy; 3] = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::ShortestQueue,
        DispatchPolicy::WorkSteal,
    ];

    fn model_path() -> Option<std::path::PathBuf> {
        let p = crate::artifacts_dir().join("models/tl-phi");
        if p.join("weights.ets").exists() {
            Some(p)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    /// Small synthetic model: serving runs offline through the native
    /// reference executor, no artifacts needed.
    fn tiny_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "tiny-serve".into(),
                n_blocks: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                vocab: 64,
                seq_len: 8,
                eval_batch: 4,
            },
            profile: Profile::RampUp,
            seed: 91,
        })
    }

    fn collect_tokens_with(
        model: &ModelDir,
        workers: usize,
        requests: usize,
        dispatch: DispatchPolicy,
    ) -> (Vec<i32>, ServingMetrics) {
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg =
            ServeConfig { max_batch: 4, max_wait_us: 500, workers, dispatch, ..Default::default() };
        let coord =
            Coordinator::start_with_model(model.clone(), plan, cfg, 1, 50).unwrap();
        let mut rxs = Vec::with_capacity(requests);
        for i in 0..requests {
            rxs.push(coord.submit(vec![
                (i % 64) as i32,
                ((i * 7) % 64) as i32,
                ((i * 13) % 64) as i32,
            ]));
        }
        let toks: Vec<i32> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
            .collect();
        (toks, coord.shutdown())
    }

    fn collect_tokens(model: &ModelDir, workers: usize, requests: usize) -> (Vec<i32>, ServingMetrics) {
        collect_tokens_with(model, workers, requests, DispatchPolicy::default())
    }

    #[test]
    fn sharded_serving_answers_everything_offline() {
        let model = tiny_model();
        let (toks, m) = collect_tokens(&model, 3, 20);
        assert_eq!(toks.len(), 20);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(m.completed, 20);
        assert!(m.batches >= 1);
        assert_eq!(m.shards.len(), 3, "one occupancy record per shard");
        assert_eq!(m.shards.iter().map(|s| s.completed).sum::<usize>(), 20);
        assert_eq!(m.shards.iter().map(|s| s.batches).sum::<usize>(), m.batches);
        assert_eq!(m.steals, m.shards.iter().map(|s| s.steals).sum::<usize>());
        for (i, s) in m.shards.iter().enumerate() {
            assert_eq!(s.shard, i);
            let o = s.occupancy(m.wall_time);
            assert!((0.0..=1.0).contains(&o));
        }
    }

    #[test]
    fn shortest_queue_order_is_depth_then_id() {
        assert_eq!(shortest_queue_order(&[]), Vec::<usize>::new());
        assert_eq!(shortest_queue_order(&[5]), vec![0]);
        assert_eq!(shortest_queue_order(&[2, 0, 1]), vec![1, 2, 0]);
        // ties break on shard id, so the order is total and deterministic
        assert_eq!(shortest_queue_order(&[1, 1, 0, 1]), vec![2, 0, 1, 3]);
        crate::proptest_lite::check(
            0x5105,
            100,
            16,
            |g| {
                let n = g.usize_in(1, 12);
                (0..n).map(|_| g.usize_in(0, 8)).collect::<Vec<usize>>()
            },
            |depths| {
                let order = shortest_queue_order(depths);
                let mut seen = order.clone();
                seen.sort_unstable();
                if seen != (0..depths.len()).collect::<Vec<_>>() {
                    return Err("not a permutation".into());
                }
                for w in order.windows(2) {
                    if (depths[w[0]], w[0]) > (depths[w[1]], w[1]) {
                        return Err(format!("order violated at {w:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Big enough that one forward takes real time (~100ms-class on a CI
    /// host): the balance tests need execution to outlast dispatch by a
    /// wide margin, so queues are non-empty whenever the batcher (or an
    /// idle thief) routes the next expensive window.
    fn balance_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "balance".into(),
                n_blocks: 4,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 64,
                seq_len: 32,
                eval_batch: 8,
            },
            profile: Profile::UShape,
            seed: 1717,
        })
    }

    fn run_skewed(dispatch: crate::config::DispatchPolicy) -> ServingMetrics {
        let model = balance_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1, // every request is its own window
            max_wait_us: 100,
            workers: 2,
            dispatch,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // skewed batch costs: even windows are expensive (a full forward),
        // odd windows are cheap (all-reject, answered without executing)
        let mut rxs = Vec::new();
        for i in 0..24 {
            let ctx = if i % 2 == 0 { vec![1, 2, 3] } else { vec![-1] };
            rxs.push(coord.submit(ctx));
        }
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        }
        coord.shutdown()
    }

    #[test]
    fn shortest_queue_balances_skewed_batch_costs() {
        use crate::config::DispatchPolicy;
        // Round-robin alternates blindly: with alternating expensive/cheap
        // windows and two shards, every expensive window lands on shard 0 —
        // shard 1 never executes a batch.
        let rr = run_skewed(DispatchPolicy::RoundRobin);
        assert_eq!(rr.completed, 24);
        let rr_batches: Vec<usize> = rr.shards.iter().map(|s| s.batches).collect();
        assert_eq!(rr_batches.iter().sum::<usize>(), 12);
        assert_eq!(
            rr_batches.iter().filter(|&&b| b == 0).count(),
            1,
            "round-robin starves one shard of executed work: {rr_batches:?}"
        );
        assert_eq!(rr.steals, 0, "round-robin never steals");
        // Shortest-queue routes around the busy shard: both shards execute
        // expensive windows. (All 24 requests are queued before the first
        // ~100ms forward finishes, so the starved-shard outcome would need
        // the batcher to stall ~100ms between every pair of windows — the
        // assertion is kept to >= 1 per shard so scheduler noise on loaded
        // CI hosts cannot flake it.)
        let sq = run_skewed(DispatchPolicy::ShortestQueue);
        assert_eq!(sq.completed, 24);
        let sq_batches: Vec<usize> = sq.shards.iter().map(|s| s.batches).collect();
        assert_eq!(sq_batches.iter().sum::<usize>(), 12);
        assert!(
            sq_batches.iter().all(|&b| b >= 1),
            "shortest-queue must spread executed batches: {sq_batches:?}"
        );
        let rr_min = *rr_batches.iter().min().unwrap();
        let sq_min = *sq_batches.iter().min().unwrap();
        assert!(sq_min > rr_min, "balance must improve: rr {rr_batches:?} vs sq {sq_batches:?}");
    }

    #[test]
    fn work_steal_balances_skewed_batch_costs() {
        use crate::config::DispatchPolicy;
        // WorkSteal places like round-robin (all expensive windows on shard
        // 0), but the idle shard pulls from the backed-up queue: both shards
        // end up executing, and steals are observed and accounted.
        let ws = run_skewed(DispatchPolicy::WorkSteal);
        assert_eq!(ws.completed, 24);
        let ws_batches: Vec<usize> = ws.shards.iter().map(|s| s.batches).collect();
        assert_eq!(ws_batches.iter().sum::<usize>(), 12);
        assert!(
            ws_batches.iter().all(|&b| b >= 1),
            "work stealing must spread executed batches: {ws_batches:?}"
        );
        assert!(ws.steals >= 1, "the idle shard must have stolen queued work");
        assert_eq!(ws.steals, ws.shards.iter().map(|s| s.steals).sum::<usize>());
        assert!(ws.wakes >= 1, "idle shards park and are woken");
    }

    #[test]
    fn metrics_report_packed_resident_bytes_per_replica() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q4);
        let expected = QuantizedModel::build(&model, &plan).unwrap().resident_bytes();
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 500, workers: 3, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let _ = coord.submit(vec![1, 2, 3]).recv_timeout(Duration::from_secs(120)).unwrap();
        let m = coord.shutdown();
        assert_eq!(
            m.resident_weight_bytes,
            3 * expected,
            "every shard pins exactly one packed replica"
        );
        assert!(m.summary().contains("resident"));
    }

    #[test]
    fn forward_workers_do_not_change_responses() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let run = |forward_workers: usize| -> Vec<i32> {
            let cfg = ServeConfig {
                max_batch: 4,
                max_wait_us: 500,
                workers: 2,
                forward_workers,
                ..Default::default()
            };
            let coord =
                Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0).unwrap();
            let rxs: Vec<_> = (0..10)
                .map(|i| coord.submit(vec![i % 64, (i * 5 + 1) % 64]))
                .collect();
            let toks = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
                .collect();
            coord.shutdown();
            toks
        };
        let serial = run(1);
        assert_eq!(serial, run(4), "intra-forward parallelism is response-invariant");
        assert_eq!(
            serial,
            run(ParallelConfig::test_workers(3)),
            "invariant at the CI matrix worker count too"
        );
    }

    #[test]
    fn responses_are_invariant_to_worker_count_and_policy() {
        // the acceptance invariant: identical per-request responses whether
        // one worker or many serve the trace, under every dispatch policy
        let model = tiny_model();
        let (serial, _) = collect_tokens(&model, 1, 16);
        for policy in ALL_POLICIES {
            for workers in [1usize, 2, 7, ParallelConfig::test_workers(4)] {
                let (toks, m) = collect_tokens_with(&model, workers, 16, policy);
                assert_eq!(
                    serial,
                    toks,
                    "workers={workers} policy={}",
                    policy.label()
                );
                assert_eq!(m.completed, 16);
            }
        }
    }

    #[test]
    fn invalid_tokens_get_sentinel_and_shard_survives() {
        // exercised under every policy so the event-driven loop (parking,
        // stealing) sees rejects too — the work-steal coverage the rescue
        // protocol requires
        for policy in ALL_POLICIES {
            let model = tiny_model();
            let plan =
                QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
            let cfg = ServeConfig {
                max_batch: 4,
                max_wait_us: 500,
                workers: 2,
                dispatch: policy,
                ..Default::default()
            };
            let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
            let bad_high = coord.submit(vec![1, 9999, 2]); // out of vocab
            let bad_neg = coord.submit(vec![-7]);
            let good = coord.submit(vec![1, 2, 3]);
            assert_eq!(
                bad_high.recv_timeout(Duration::from_secs(120)).unwrap().next_token,
                INVALID_TOKEN,
                "policy={}",
                policy.label()
            );
            assert_eq!(
                bad_neg.recv_timeout(Duration::from_secs(120)).unwrap().next_token,
                INVALID_TOKEN
            );
            // the shards must still execute valid work afterwards
            let resp = good.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!((0..64).contains(&resp.next_token));
            // bad token BEYOND the seq_len truncation point: executed normally
            let mut long_ctx = vec![3i32; 8];
            long_ctx.extend([9999, 9999]);
            let truncated = coord.submit(long_ctx);
            assert!((0..64).contains(
                &truncated.recv_timeout(Duration::from_secs(120)).unwrap().next_token
            ));
            let late = coord.submit(vec![4, 5]);
            assert!(
                (0..64).contains(&late.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
            );
            let m = coord.shutdown();
            assert_eq!(m.completed, 5, "policy={}", policy.label());
            assert_eq!(m.rejected, 2);
            // rejects are excluded from the latency/batch aggregates
            assert_eq!(m.latencies_us.len(), 3);
        }
    }

    #[test]
    fn poisoned_shard_dies_and_peers_answer_every_other_request_once() {
        // "a stolen window from a shard that dies mid-flight must be
        // re-dispatched exactly once": the poisoned window kills whichever
        // shard picks it up; every window stranded on the dead shard's
        // queue is rescued by the survivor, and no request is ever answered
        // twice. (The queue-level exactly-once property is unit-tested in
        // `queues::tests`; this exercises it end-to-end.)
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait_us: 200,
            workers: 2,
            dispatch: DispatchPolicy::WorkSteal,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let poisoned = coord.submit(vec![POISON_CONTEXT]);
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(coord.submit(vec![(i % 64) as i32, 1, 2]));
        }
        // the poisoned window dies with its shard: closed channel, no answer
        assert!(
            poisoned.recv_timeout(Duration::from_secs(120)).is_err(),
            "poisoned request must never be answered"
        );
        // every other request is answered exactly once — dispatched to the
        // live shard directly or rescued off the dead one's queue
        for (i, rx) in rxs.into_iter().enumerate() {
            let responses: Vec<Response> = rx.iter().collect();
            assert_eq!(responses.len(), 1, "request {i} answered exactly once");
            assert!((0..64).contains(&responses[0].next_token), "request {i}");
        }
        let m = coord.shutdown();
        // only the survivor reports; the dead shard's metrics die with it
        assert!(m.shards.len() < 2, "dead shard must not report occupancy");
        assert!(m.completed <= 10);
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let Some(path) = model_path() else { return };
        let plan = QuantPlan::uniform("tl-phi", 8, Precision::Q8);
        let cfg =
            ServeConfig { max_batch: 8, max_wait_us: 3_000, workers: 2, ..Default::default() };
        let coord = Coordinator::start(path, plan, cfg, 1, 200).unwrap();

        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(coord.submit(vec![1, 160 + (i % 16), 100 + (i % 57), 2]));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!((0..512).contains(&resp.next_token));
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert_eq!(resp.network_latency_us, 200);
            assert!(resp.shard < 2);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 20);
        assert!(m.batches <= 20);
        assert!(m.max_batch_observed <= 8);
        assert!(m.throughput_rps() > 0.0);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.99));
    }

    #[test]
    fn shutdown_without_requests_is_clean() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny-serve", 2, Precision::Raw);
        let coord = Coordinator::start_with_model(
            model,
            plan,
            ServeConfig { workers: 2, ..Default::default() },
            0,
            0,
        )
        .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.completed, 0);
        assert_eq!(m.virtual_network_us, 0);
        assert_eq!(m.shards.len(), 2);
        assert!(m.shards.iter().all(|s| s.completed == 0 && s.busy_us == 0));
        assert_eq!(m.steals, 0);
    }

    #[test]
    fn metrics_percentiles_ordered() {
        let m = ServingMetrics {
            completed: 5,
            rejected: 0,
            batches: 2,
            latencies_us: vec![10, 50, 20, 90, 30],
            wall_time: Duration::from_millis(10),
            max_batch_observed: 3,
            virtual_network_us: 0,
            resident_weight_bytes: 0,
            steals: 0,
            wakes: 0,
            shards: Vec::new(),
        };
        assert_eq!(m.percentile_us(0.0), 10);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.95));
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_nearest_rank_on_small_samples() {
        // the old (len*p) truncation read p50 of [1,2] as index 1
        let m = |lats: Vec<u64>| ServingMetrics { latencies_us: lats, ..Default::default() };
        let two = m(vec![2, 1]);
        assert_eq!(two.percentile_us(0.5), 1, "p50 of [1,2] is the first sample");
        assert_eq!(two.percentile_us(0.51), 2);
        assert_eq!(two.percentile_us(1.0), 2);
        let three = m(vec![3, 1, 2]);
        assert_eq!(three.percentile_us(0.5), 2);
        assert_eq!(three.percentile_us(0.0), 1);
        let hundred = m((1..=100).collect());
        assert_eq!(hundred.percentile_us(0.99), 99, "p99 of 1..=100 is 99, not 100");
        assert_eq!(hundred.percentile_us(0.50), 50);
        assert_eq!(hundred.percentile_us(1.0), 100);
        let one = m(vec![42]);
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile_us(p), 42);
        }
        assert_eq!(m(vec![]).percentile_us(0.5), 0);
    }

    #[test]
    fn merge_aggregates_shards() {
        let mut a = ServingMetrics {
            completed: 3,
            rejected: 1,
            batches: 2,
            latencies_us: vec![10, 20, 30],
            wall_time: Duration::from_millis(5),
            max_batch_observed: 2,
            virtual_network_us: 100,
            resident_weight_bytes: 1000,
            steals: 2,
            wakes: 5,
            shards: vec![ShardOccupancy {
                shard: 1,
                completed: 3,
                batches: 2,
                busy_us: 4000,
                steals: 2,
                wakes: 5,
            }],
        };
        let b = ServingMetrics {
            completed: 2,
            rejected: 0,
            batches: 1,
            latencies_us: vec![40, 50],
            wall_time: Duration::from_millis(9),
            max_batch_observed: 3,
            virtual_network_us: 50,
            resident_weight_bytes: 1000,
            steals: 1,
            wakes: 3,
            shards: vec![ShardOccupancy {
                shard: 0,
                completed: 2,
                batches: 1,
                busy_us: 1000,
                steals: 1,
                wakes: 3,
            }],
        };
        a.merge(b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.wall_time, Duration::from_millis(9));
        assert_eq!(a.max_batch_observed, 3);
        assert_eq!(a.virtual_network_us, 150);
        assert_eq!(a.resident_weight_bytes, 2000, "replica footprints sum across shards");
        assert_eq!(a.steals, 3, "steal counts sum across shards");
        assert_eq!(a.wakes, 8, "park/wake transitions sum across shards");
        assert_eq!(a.latencies_us.len(), 5);
        // shards sorted by id after merge
        assert_eq!(a.shards.iter().map(|s| s.shard).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(a.percentile_us(1.0), 50);
        let occ = a.shards[1].occupancy(a.wall_time);
        assert!((occ - 4000.0 / 9000.0).abs() < 1e-9);
        assert!(a.summary().contains("steals 3"));
    }
}
