//! Serving coordinator — the L3 request path, sharded across N workers.
//!
//! Topology: the front end submits requests over a channel to a **batcher**
//! thread; a dynamic batching window groups up to `max_batch` requests or
//! waits at most `max_wait`, then places the whole batch on one of
//! `ServeConfig::workers` per-shard **queues** (`serving::queues`). Shard
//! workers drain their own queue front-first and run event-driven: an idle
//! shard parks on the queue condvar, is woken by pushes, and — under
//! `DispatchPolicy::WorkSteal`, the default — steals the deepest peer
//! queue's oldest window instead of idling while a neighbour is backed up.
//! `ShortestQueue` (producer-side balancing by queued + in-flight depth)
//! and `RoundRobin` (blind rotation) are kept as comparison policies. Each
//! shard owns a full model replica (its own `Runtime` — the PJRT client is
//! not `Send`, so it is created inside the shard thread — plus its own
//! `QuantizedModel`, resident at **packed** size: the native executor
//! serves straight from the `QMat` payloads through the fused kernels) and
//! answers every request in the batch.
//!
//! Responses are batching-, shard-, and policy-invariant: attention never
//! mixes batch rows, padding rows are zeros, and every replica is built
//! from the same plan — so a request's `next_token` is identical whether it
//! is served by 1 worker or N, under any dispatch policy. Shard-level
//! `ShardOccupancy` (including steal and park/wake counts) is folded into
//! the aggregate metrics via `ServingMetrics::merge` at shutdown.
//!
//! **Incremental decoding** (DESIGN.md §10): a request submitted with
//! `max_new_tokens > 1` (`Coordinator::submit_gen`, `ewq serve
//! --decode-tokens N`) becomes a **decode job** on the shard that picks up
//! its window. The job ingests the context through
//! `ForwardPass::decode_step` once — populating per-sequence K/V pages in
//! the shard's `KvCache` at the configured precision (`--kv-precision`
//! raw/8bit/4bit) — and then generates one token per queue turn, re-queued
//! behind whatever prefill windows arrived in between, streaming one
//! `Response` per token. Sequences are **pinned** to their shard's cache:
//! live peers never steal decode jobs (`queues::Pinnable`), while
//! dead-shard rescue fails them with a single terminal
//! `Status::ShardLost` response instead of leaving callers hanging.
//!
//! **Continuous batching** (DESIGN.md §12): with `max_decode_batch > 1`
//! (the default), a shard that pops one decode turn *gathers* the rest of
//! its queued decode work (`queues::drain_pinned`) and advances the whole
//! cohort through one fused `decode_step_batched` — one `matmul_qmat` per
//! weight matrix per block per step, every packed tile unpacked once per
//! *step* instead of once per sequence. Newly prefilled sequences join the
//! batch at the next step boundary (their context ingest runs per-sequence
//! first, at ragged lengths); finished, failed, or abandoned sequences
//! retire mid-batch without stalling the rest — the survivors are simply
//! re-queued and re-gathered next turn. `max_decode_batch = 1` keeps the
//! per-sequence GEMV path, which the batched path is bit-identical to
//! (`decode_equivalence` proves response streams match across both paths,
//! 1/2/7 workers, all three policies, scalar and SIMD kernels).
//!
//! Fault containment: a shard that unwinds marks itself dead on the shared
//! queues and its stranded windows are **rescued** — popped exactly once —
//! by live peers under every policy (see `queues::ShardQueues::pop`).
//!
//! **Overload safety** (DESIGN.md §13): every submitted request resolves to
//! exactly one terminal `Status` — `Ok` for a served request (or fully
//! streamed generation), else one typed failure (`Busy`, `InvalidContext`,
//! `Expired`, `ShardLost`, `KvExhausted`); `INVALID_TOKEN` survives only as
//! the placeholder `next_token` on failure responses, never as the carrier
//! of meaning. Admission is bounded: `ServeConfig::max_queued_windows` caps
//! every shard queue and the batcher **sheds** whole windows with `Busy`
//! when all live shards are at the cap; `max_live_sequences` bounds decode
//! admission per shard; and per-request deadlines
//! (`Coordinator::submit_with_deadline`, `ServeConfig::default_deadline_ms`)
//! expire waiting work at dequeue and in-flight generations at the next
//! step boundary, each with one terminal `Expired`. The `chaos` feature
//! (`serving::faultfx`) injects shard death, stalls, and KV exhaustion from
//! seeded schedules to prove the exactly-one-terminal-status property under
//! fire (`tests/chaos.rs`, `make test-chaos`).
//!
//! **Prefix caching** (DESIGN.md §14): with `--prefix-cache on` (the
//! default), generation admission consults the shard cache's prefix-hash
//! index before charging the KV budget. A hit attaches the new sequence to
//! already-resident shared-prefix pages copy-free — pages are refcounted
//! and free only when the last holder retires — with copy-on-write at the
//! first partially-shared page; the first decode turn then ingests only the
//! unshared suffix, and publishes the full context back into the index.
//! Because encoded page bytes are a deterministic function of the token
//! prefix, a hit never moves a logit bit versus fresh ingest
//! (`decode_equivalence` proves on == off across precisions, worker counts,
//! dispatch policies, and `max_decode_batch`); `--prefix-cache off` is the
//! always-ingest oracle. Hits surface as `ServingMetrics::prefix_hits` /
//! `prefix_tokens_reused` / `kv_shared_bytes`, and every shard audits its
//! refcount books at exit (`KvCache::check_invariants`), reporting
//! violations via `kv_leaked_seqs`.
//!
//! **Online requantization** (DESIGN.md §15): with `--requant on`, each
//! shard runs a precision controller (`serving::requant`) at its queue-turn
//! boundaries — the only points where nothing is in flight on that shard.
//! Under memory pressure (resident weight bytes + live KV bytes above
//! `--requant-high-mb`) it re-packs the lowest-entropy eligible block one
//! rung down Q8 → Q4 → Q3, guided by entropy rank and, when a trained
//! FastEWQ classifier is supplied, per-block eligibility; when pressure
//! falls below `--requant-low-mb` and the queue is idle, demoted blocks
//! promote back toward their plan precision. Swaps publish atomically
//! (Arc swap per block, `model::BlockMats`), so in-flight batched decode
//! streams are never torn — `tests/decode_equivalence.rs` forces scripted
//! swap schedules under live decode to prove streams stay well-formed and
//! schedule-deterministic, and the chaos suite crosses swaps with shard
//! death to prove neither pages nor old payloads leak. Residency and swap
//! traffic surface as `ServingMetrics::block_residency` / `requant_*`.
//!
//! Cross-machine block placement (from `cluster::Distribution`) is simulated:
//! each batch is charged `hops × link_latency` of virtual network time,
//! reported separately from wall-clock latency.

#[cfg(any(test, feature = "chaos"))]
pub mod faultfx;
pub mod kvcache;
mod queues;
pub mod requant;
pub mod trace;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DispatchPolicy, ServeConfig};
use crate::ewq::QuantPlan;
use crate::model::{DecodeState, ModelExecutor, QuantizedModel};
use crate::par::Pool;
use crate::quant::Precision;
use crate::runtime::Runtime;
use crate::serving::kvcache::{KvCache, KvGeometry};
use crate::serving::queues::{Pinnable, Popped, ShardQueues};
use crate::zoo::ModelDir;

/// KV-cache page granularity for serving shards (tokens per page).
const KV_PAGE_TOKENS: usize = 16;

/// One request: a token context, answered with the next token (classic) or
/// with a stream of `max_new_tokens` generated tokens (decode path).
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub context: Vec<i32>,
    /// `<= 1`: classic single next-token prediction through the batched
    /// prefill path. `N > 1`: streaming generation — the caller receives up
    /// to `N` `Response`s on the same channel (fewer when the context
    /// window fills first; a failed/rescued sequence ends with a single
    /// terminal non-`Ok` `Status`). The channel closes after the last
    /// token.
    pub max_new_tokens: usize,
    submitted: Instant,
    /// Absolute deadline; a request past it is answered `Status::Expired`
    /// at the next dequeue or decode-step boundary instead of executing.
    deadline: Option<Instant>,
    resp: Sender<Response>,
}

/// Has this request's deadline passed? Checked at the scheduling
/// boundaries (window dequeue, decode-step gather) — never mid-forward.
fn expired(req: &Request) -> bool {
    req.deadline.is_some_and(|d| Instant::now() >= d)
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// Why (or that) this response exists: `Ok` for a served token, a typed
    /// failure otherwise. A request receives exactly one terminal status —
    /// its last response (streamed generations emit `Ok` per token and end
    /// with either the final `Ok` token or one failure marker).
    pub status: Status,
    /// wall-clock queue+compute latency
    pub latency: Duration,
    /// simulated cross-machine network time for the batch
    pub network_latency_us: u64,
    pub batch_size: usize,
    /// which shard worker executed the batch (`NO_SHARD` for responses the
    /// coordinator answered itself: shed or pre-dispatch expiry)
    pub shard: usize,
}

/// Terminal disposition of a request — the typed failure taxonomy
/// (DESIGN.md §13). Every submitted request resolves to exactly one of
/// these; `INVALID_TOKEN` is only the placeholder `next_token` on non-`Ok`
/// responses, not a status in itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Status {
    /// Served: a real token (or, for generations, the whole stream).
    Ok = 0,
    /// Shed under overload: every live shard queue was at
    /// `max_queued_windows`, or the shard's decode admission was at
    /// `max_live_sequences`. Back off and retry.
    Busy = 1,
    /// The context failed validation (empty for generation, or tokens
    /// outside the model vocabulary). Retrying is pointless.
    InvalidContext = 2,
    /// The request's deadline passed before it finished; dropped at a
    /// dequeue or step boundary.
    Expired = 3,
    /// The executing shard died (or its replica failed mid-batch); the
    /// request was rescued and failed cleanly. Safe to retry.
    ShardLost = 4,
    /// KV-cache admission failed: the sequence's reserved window would
    /// exceed the shard's `kv_budget_mb`. Retry later or elsewhere.
    KvExhausted = 5,
}

impl Status {
    /// Number of variants (the per-status counter array width).
    pub const COUNT: usize = 6;

    /// Every variant, in counter-index order.
    pub const ALL: [Status; Status::COUNT] = [
        Status::Ok,
        Status::Busy,
        Status::InvalidContext,
        Status::Expired,
        Status::ShardLost,
        Status::KvExhausted,
    ];

    /// Index into per-status counter arrays (`ServingMetrics::statuses`).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Busy => "busy",
            Status::InvalidContext => "invalid_context",
            Status::Expired => "expired",
            Status::ShardLost => "shard_lost",
            Status::KvExhausted => "kv_exhausted",
        }
    }
}

/// Placeholder `next_token` on non-`Ok` responses (kept so callers indexing
/// logits by token can never mistake a failure for a vocabulary entry; the
/// *meaning* of a failure lives in `Response::status`).
pub const INVALID_TOKEN: i32 = -1;

/// `Response::shard` for responses answered by the coordinator itself
/// (load shedding, pre-dispatch expiry) — no shard ever saw the request.
pub const NO_SHARD: usize = usize::MAX;

/// Test-only: a context whose first token is this sentinel panics the shard
/// that picks its window up — the deterministic "shard dies mid-flight"
/// trigger for the dead-shard rescue tests.
#[cfg(test)]
pub(crate) const POISON_CONTEXT: i32 = i32::MIN;

enum Msg {
    Req(Request),
    Stop(Sender<ServingMetrics>),
}

/// A closed batching window en route to (or parked on) a shard queue.
type Window = Vec<Request>;

/// One decoding sequence between queue turns: the request being answered,
/// its KV-cache cursor, and the generation progress. Lives on its owning
/// shard's queue (pinned — the KV pages are in that shard's cache).
struct DecodeJob {
    req: Request,
    state: DecodeState,
    /// Tokens streamed back so far (each one was a `Response`).
    produced: usize,
    /// The next token to feed through `decode_step` (the previously
    /// generated one; meaningless until `produced > 0`).
    next_input: i32,
}

/// One unit of shard work: a closed prefill window, or one decoding
/// sequence's next turn (re-queued between turns so generation interleaves
/// with prefill through the same work-steal deques).
enum Work {
    Prefill(Window),
    Decode(DecodeJob),
}

impl Pinnable for Work {
    /// Decode jobs reference their shard's KV cache and must not migrate
    /// to live peers; prefill windows are freely stealable.
    fn pinned(&self) -> bool {
        matches!(self, Work::Decode(_))
    }
}

/// Per-shard execution accounting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardOccupancy {
    pub shard: usize,
    pub completed: usize,
    pub batches: usize,
    /// time spent executing batches (excludes idle waiting)
    pub busy_us: u64,
    /// windows this shard took from peers' queues (work stealing under
    /// `DispatchPolicy::WorkSteal`, dead-shard rescues under every policy)
    pub steals: usize,
    /// park → wake transitions on the shared queue condvar (how often the
    /// worker went idle and was handed new work)
    pub wakes: usize,
}

impl ShardOccupancy {
    /// Fraction of the serving wall-clock this shard spent executing.
    pub fn occupancy(&self, wall: Duration) -> f64 {
        let wall_us = wall.as_micros() as f64;
        if wall_us <= 0.0 {
            return 0.0;
        }
        (self.busy_us as f64 / wall_us).min(1.0)
    }
}

/// Aggregate serving metrics (single shard, or merged across shards).
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub completed: usize,
    /// Requests answered with `INVALID_TOKEN` without executing (counted in
    /// `completed`, excluded from latency/batch aggregates).
    pub rejected: usize,
    pub batches: usize,
    pub latencies_us: Vec<u64>,
    pub wall_time: Duration,
    pub max_batch_observed: usize,
    pub virtual_network_us: u64,
    /// Resident weight bytes across all replicas — the **fleet** total
    /// (each shard reports its `QuantizedModel::resident_bytes`; `merge`
    /// sums them) — the packed footprint the memory-reduction claim is
    /// measured by. Requant swaps update the reporting shard's value live,
    /// so at shutdown this reflects post-swap packing, and
    /// `requant_bytes_freed - requant_bytes_regrown` reconciles against the
    /// drop from the build-time footprint.
    pub resident_weight_bytes: usize,
    /// Resident weight bytes of ONE replica (`merge` takes the max, so a
    /// fleet of identical replicas reports the per-replica figure the
    /// summary reads like — the fleet total above is `n_shards ×` this,
    /// modulo requant divergence between shards).
    pub resident_weight_bytes_per_replica: usize,
    /// Requant swaps committed across all shards (forced + pressure).
    pub requant_swaps: usize,
    /// Bytes released by requant demotions across all shards.
    pub requant_bytes_freed: usize,
    /// Bytes re-acquired by requant promotions across all shards.
    pub requant_bytes_regrown: usize,
    /// Blocks resident per precision rung at shard exit, indexed by
    /// `Precision::tag()` and summed across shards (`merge` adds
    /// element-wise): the per-precision block-residency histogram. A fleet
    /// without requant reports every block at its plan precision.
    pub block_residency: [usize; 5],
    /// Windows taken from peer queues across all shards (steals + rescues).
    pub steals: usize,
    /// Shard-worker park → wake transitions across all shards.
    pub wakes: usize,
    /// Incremental decode steps executed across all shards (context ingest
    /// plus generated tokens — the generation workload's volume metric; a
    /// fused batched step advancing M sequences counts M).
    pub decode_steps: usize,
    /// Fused `decode_step_batched` calls across all shards (continuous
    /// batching; stays 0 when `max_decode_batch <= 1` keeps the
    /// per-sequence GEMV path).
    pub batched_steps: usize,
    /// Sequence-rows advanced by those fused steps; the mean decode-batch
    /// occupancy is `decode_batch_rows / batched_steps`.
    pub decode_batch_rows: usize,
    /// Peak KV-cache residency per shard, summed across shards.
    pub kv_bytes: usize,
    /// Generation admissions that attached to already-resident
    /// shared-prefix KV pages via the prefix index (DESIGN.md §14).
    pub prefix_hits: usize,
    /// Context tokens those hits seated without re-ingesting (each one is
    /// a decode step the shard never executed).
    pub prefix_tokens_reused: usize,
    /// Already-resident KV page bytes attached copy-free (refcount bumps
    /// only — excludes the copied partially-shared pages).
    pub kv_shared_bytes: usize,
    /// Sequences still holding KV pages when a shard worker exited, plus
    /// page-accounting violations caught by `KvCache::check_invariants` at
    /// exit. Always 0 on a healthy fleet; the chaos and equivalence suites
    /// assert it.
    pub kv_leaked_seqs: usize,
    /// Terminal statuses per request, indexed by `Status::index()` (sums to
    /// `completed`; `merge` adds element-wise). `rejected` stays the total
    /// of the non-`Ok` entries.
    pub statuses: [usize; Status::COUNT],
    /// High-water mark of queued + in-flight windows on any single shard
    /// queue (`merge` takes the max) — with `max_queued_windows` set, this
    /// stays bounded by the cap no matter the offered load.
    pub queue_depth_hwm: usize,
    /// One entry per shard worker (sorted by shard id after `merge`).
    pub shards: Vec<ShardOccupancy>,
}

impl ServingMetrics {
    /// Nearest-rank percentile: index ceil(p·n) − 1, clamped to the sample
    /// range (so p=0 is the min and p=1 the max).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = (p * v.len() as f64).ceil() as usize;
        v[rank.saturating_sub(1).min(v.len() - 1)]
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_time.as_secs_f64().max(1e-9)
    }

    /// Mean EXECUTED requests per batch (rejects never enter a batch).
    pub fn mean_batch(&self) -> f64 {
        (self.completed - self.rejected) as f64 / self.batches.max(1) as f64
    }

    /// Mean live sequences per fused decode step (0.0 when the
    /// per-sequence path served all decode traffic).
    pub fn decode_batch_occupancy(&self) -> f64 {
        self.decode_batch_rows as f64 / self.batched_steps.max(1) as f64
    }

    /// Requests shed with `Status::Busy` (queue cap or live-sequence cap).
    pub fn shed(&self) -> usize {
        self.statuses[Status::Busy.index()]
    }

    /// Requests that ran out their deadline (`Status::Expired`).
    pub fn expired(&self) -> usize {
        self.statuses[Status::Expired.index()]
    }

    /// Fold another shard's (or coordinator's) metrics into this aggregate:
    /// counters add, latencies concatenate, wall-clock takes the max, shard
    /// occupancy records append.
    pub fn merge(&mut self, other: ServingMetrics) {
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.batches += other.batches;
        self.latencies_us.extend(other.latencies_us);
        self.wall_time = self.wall_time.max(other.wall_time);
        self.max_batch_observed = self.max_batch_observed.max(other.max_batch_observed);
        self.virtual_network_us += other.virtual_network_us;
        // fleet bytes sum; the per-replica figure takes the max so merging
        // N identical replicas still reads as one replica's footprint
        self.resident_weight_bytes += other.resident_weight_bytes;
        self.resident_weight_bytes_per_replica = self
            .resident_weight_bytes_per_replica
            .max(other.resident_weight_bytes_per_replica);
        self.requant_swaps += other.requant_swaps;
        self.requant_bytes_freed += other.requant_bytes_freed;
        self.requant_bytes_regrown += other.requant_bytes_regrown;
        for (mine, theirs) in self.block_residency.iter_mut().zip(other.block_residency) {
            *mine += theirs;
        }
        self.steals += other.steals;
        self.wakes += other.wakes;
        self.decode_steps += other.decode_steps;
        self.batched_steps += other.batched_steps;
        self.decode_batch_rows += other.decode_batch_rows;
        self.kv_bytes += other.kv_bytes;
        self.prefix_hits += other.prefix_hits;
        self.prefix_tokens_reused += other.prefix_tokens_reused;
        self.kv_shared_bytes += other.kv_shared_bytes;
        self.kv_leaked_seqs += other.kv_leaked_seqs;
        for (mine, theirs) in self.statuses.iter_mut().zip(other.statuses) {
            *mine += theirs;
        }
        self.queue_depth_hwm = self.queue_depth_hwm.max(other.queue_depth_hwm);
        self.shards.extend(other.shards);
        self.shards.sort_by_key(|s| s.shard);
    }

    pub fn summary(&self) -> String {
        // an empty sample set (everything shed/expired) renders n/a — a
        // literal "0us" would read as an impossibly fast server
        let pct = |p: f64| -> String {
            if self.latencies_us.is_empty() {
                "n/a".into()
            } else {
                format!("{}us", self.percentile_us(p))
            }
        };
        let mut s = format!(
            "{} reqs in {:?} ({:.1} req/s), batches {} (mean {:.2}, max {}), \
             p50 {} p95 {} p99 {}, virtual-net {}us",
            self.completed,
            self.wall_time,
            self.throughput_rps(),
            self.batches,
            self.mean_batch(),
            self.max_batch_observed,
            pct(0.50),
            pct(0.95),
            pct(0.99),
            self.virtual_network_us,
        );
        if self.rejected > 0 {
            s.push_str(&format!(", rejected {}", self.rejected));
        }
        if self.shed() > 0 {
            s.push_str(&format!(", shed {}", self.shed()));
        }
        if self.expired() > 0 {
            s.push_str(&format!(", expired {}", self.expired()));
        }
        if self.queue_depth_hwm > 0 {
            s.push_str(&format!(", q-hwm {}", self.queue_depth_hwm));
        }
        if self.steals > 0 {
            s.push_str(&format!(", steals {}", self.steals));
        }
        if self.decode_steps > 0 {
            s.push_str(&format!(
                ", decode {} steps, kv peak {}",
                self.decode_steps,
                crate::report::bytes_human(self.kv_bytes)
            ));
        }
        if self.batched_steps > 0 {
            s.push_str(&format!(
                ", batched {} steps (mean occupancy {:.2})",
                self.batched_steps,
                self.decode_batch_occupancy()
            ));
        }
        if self.prefix_hits > 0 {
            s.push_str(&format!(
                ", prefix hits {} ({} toks reused, {} shared)",
                self.prefix_hits,
                self.prefix_tokens_reused,
                crate::report::bytes_human(self.kv_shared_bytes)
            ));
        }
        if self.kv_leaked_seqs > 0 {
            s.push_str(&format!(", KV LEAKS {}", self.kv_leaked_seqs));
        }
        if self.resident_weight_bytes > 0 {
            s.push_str(&format!(
                ", resident {} ({}/replica)",
                crate::report::bytes_human(self.resident_weight_bytes),
                crate::report::bytes_human(self.resident_weight_bytes_per_replica)
            ));
        }
        if self.requant_swaps > 0 {
            s.push_str(&format!(
                ", requant {} swaps (freed {}, regrown {})",
                self.requant_swaps,
                crate::report::bytes_human(self.requant_bytes_freed),
                crate::report::bytes_human(self.requant_bytes_regrown)
            ));
        }
        if self.block_residency.iter().any(|&c| c > 0) {
            s.push_str(&format!(
                ", blocks [{}]",
                crate::report::residency_compact(&self.block_residency)
            ));
        }
        if self.shards.len() > 1 {
            let occ: Vec<String> = self
                .shards
                .iter()
                .map(|sh| {
                    format!(
                        "s{}:{}r/{:.0}%",
                        sh.shard,
                        sh.completed,
                        100.0 * sh.occupancy(self.wall_time)
                    )
                })
                .collect();
            s.push_str(&format!(", shards [{}]", occ.join(" ")));
        }
        s
    }
}

/// Fleet-shared live per-status counters (every terminal resolution notes
/// its status here, from any thread). Powers `Coordinator::debug_state` —
/// a hang diagnosis needs the counts *now*, not at shutdown-merge time.
struct StatusBoard {
    counts: [std::sync::atomic::AtomicUsize; Status::COUNT],
}

impl StatusBoard {
    fn new() -> Self {
        Self { counts: std::array::from_fn(|_| std::sync::atomic::AtomicUsize::new(0)) }
    }

    fn note(&self, st: Status) {
        self.counts[st.index()].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    fn snapshot(&self) -> [usize; Status::COUNT] {
        std::array::from_fn(|i| self.counts[i].load(std::sync::atomic::Ordering::Relaxed))
    }
}

/// One responder's accounting bundle: its shard id (`NO_SHARD` for the
/// batcher), its metrics + occupancy accumulators, and the fleet-shared
/// status board. Threaded through every response path so a terminal
/// resolution is bookkept in exactly one place (`resolve`).
struct Acct {
    shard: usize,
    metrics: ServingMetrics,
    occ: ShardOccupancy,
    board: Arc<StatusBoard>,
}

impl Acct {
    fn new(shard: usize, board: Arc<StatusBoard>) -> Self {
        Self {
            shard,
            metrics: ServingMetrics::default(),
            occ: ShardOccupancy { shard, ..Default::default() },
            board,
        }
    }

    /// Record one request's terminal status. `Ok` contributes its latency
    /// to the percentile aggregates; every failure counts as a reject and
    /// stays out of them.
    fn resolve(&mut self, st: Status, latency_us: u64) {
        self.metrics.completed += 1;
        self.metrics.statuses[st.index()] += 1;
        self.board.note(st);
        self.occ.completed += 1;
        if st == Status::Ok {
            self.metrics.latencies_us.push(latency_us);
        } else {
            self.metrics.rejected += 1;
        }
    }
}

/// Fail a request with one terminal typed status: bookkeep the resolution
/// and send the (single) failure response. The caller's channel closes
/// when the `Request` drops — never a dangling wait.
fn reject(req: &Request, st: Status, acct: &mut Acct) {
    acct.resolve(st, 0);
    let _ = req.resp.send(Response {
        id: req.id,
        next_token: INVALID_TOKEN,
        status: st,
        latency: req.submitted.elapsed(),
        network_latency_us: 0,
        batch_size: 0,
        shard: acct.shard,
    });
}

/// Handle to a running sharded coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    /// Shared with the fleet for live state dumps (`debug_state`).
    queues: Arc<ShardQueues<Work>>,
    board: Arc<StatusBoard>,
    /// Applied to `submit`/`submit_gen` when `default_deadline_ms > 0`.
    default_deadline: Option<Duration>,
}

impl Coordinator {
    /// Load the model from disk and start the shard workers + batcher.
    /// `network_hops` is the placement's hop count (0 = single machine);
    /// `link_latency_us` is charged per hop per batch.
    pub fn start(
        model_path: std::path::PathBuf,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let model = ModelDir::load(&model_path)?;
        Self::start_with_model(model, plan, cfg, network_hops, link_latency_us)
    }

    /// Start from an already-loaded (possibly synthetic, artifact-less)
    /// model: each of `cfg.workers` shards gets its own replica clone.
    pub fn start_with_model(
        model: ModelDir,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        // degenerate knobs fail here, typed, instead of clamping silently or
        // hanging downstream (`ServeConfig::validate`)
        cfg.validate()?;
        let n_shards = cfg.workers.max(1);
        let net_us = network_hops as u64 * link_latency_us;
        let batch_cap = cfg.max_batch.min(model.schema.eval_batch).max(1);
        let policy = cfg.dispatch;
        let fwd_workers = cfg.forward_workers.max(1);
        anyhow::ensure!(
            matches!(cfg.kv_precision, Precision::Raw | Precision::Q8 | Precision::Q4),
            "kv_precision must be raw, 8bit or 4bit (got {})",
            cfg.kv_precision.label()
        );
        let kv_prec = cfg.kv_precision;
        let kv_budget = (cfg.kv_budget_mb.max(0.0) * 1e6) as usize;
        // the fused batched step gathers rows into the forward scratch
        // arena, which holds eval_batch * seq_len of them
        let max_decode_batch = cfg
            .max_decode_batch
            .clamp(1, model.schema.eval_batch * model.schema.seq_len);
        let max_queued = cfg.max_queued_windows;
        let max_live_seqs = cfg.max_live_sequences;
        let default_deadline =
            (cfg.default_deadline_ms > 0).then(|| Duration::from_millis(cfg.default_deadline_ms));
        #[cfg(any(test, feature = "chaos"))]
        let chaos_sched = cfg.chaos.clone().unwrap_or_default();

        // requant policy, built once and shared across shards: eligibility
        // (plan ladder ∩ optional FastEWQ classifier verdicts), entropy
        // order, ceilings, watermarks. Also built when only a forced-swap
        // schedule is present, so scripted swaps work with the pressure
        // policy off.
        let requant_plan: Option<Arc<requant::RequantPlan>> =
            (cfg.requant || !cfg.requant_forced.is_empty()).then(|| {
                let classifier = cfg
                    .requant_classifier
                    .as_deref()
                    .and_then(crate::fastewq::FastEwq::load_optional);
                Arc::new(requant::RequantPlan::build(
                    &cfg,
                    &model.schema,
                    &plan,
                    classifier.as_ref(),
                ))
            });

        // disjoint per-shard core blocks when pinning is on: shard i owns
        // cores [i*fwd_workers, (i+1)*fwd_workers) wrapped around the host
        // core count, so co-located shards never share a core until the
        // host is oversubscribed
        let pin_blocks: Option<Vec<Vec<usize>>> = cfg.pin_workers.then(|| {
            let ncores = crate::par::affinity::available_cores();
            (0..n_shards)
                .map(|i| {
                    (i * fwd_workers..(i + 1) * fwd_workers).map(|c| c % ncores).collect()
                })
                .collect()
        });

        // the shared per-shard work queues the whole fleet drains
        let queues: Arc<ShardQueues<Work>> = Arc::new(ShardQueues::new(n_shards));
        let board = Arc::new(StatusBoard::new());

        // spawn shard workers, each owning a replica
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let (res_tx, res_rx) = channel::<ServingMetrics>();
        let mut shard_handles = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let replica = model.clone();
            let plan = plan.clone();
            let ready = ready_tx.clone();
            let results = res_tx.clone();
            let q = queues.clone();
            let ctx = ShardCtx {
                shard,
                net_us,
                fwd_workers,
                steal: policy.steals(),
                kv_prec,
                kv_budget,
                max_decode_batch,
                max_live_seqs,
                prefix_cache: cfg.prefix_cache,
                pin_cores: pin_blocks.as_ref().map(|b| b[shard].clone()),
                requant: requant_plan.clone(),
                requant_forced: cfg.requant_forced.clone(),
                board: board.clone(),
                #[cfg(any(test, feature = "chaos"))]
                faults: chaos_sched.for_shard(shard),
            };
            let handle = std::thread::Builder::new()
                .name(format!("ewq-shard-{shard}"))
                .spawn(move || {
                    if let Err(e) = shard_worker(ctx, replica, plan, q, ready, results) {
                        eprintln!("shard {shard} failed: {e:#}");
                    }
                })
                .context("spawn shard worker")?;
            shard_handles.push(handle);
        }
        drop(ready_tx);
        drop(res_tx);
        // block until every shard has loaded + compiled + warmed its replica
        // so request latencies never include one-off startup cost
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    queues.stop(); // release the shards that did come up
                    anyhow::bail!("shard startup failed: {msg}");
                }
                Err(_) => {
                    queues.stop();
                    anyhow::bail!("a shard died during startup");
                }
            }
        }

        // batcher thread: groups requests into windows, places them under
        // `cfg.dispatch`; idle shards drain/steal without its involvement
        let (tx, rx) = channel::<Msg>();
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let fleet = Fleet {
            queues: queues.clone(),
            handles: shard_handles,
            results: res_rx,
            policy,
            board: board.clone(),
            max_queued,
        };
        let handle = std::thread::Builder::new()
            .name("ewq-batcher".into())
            .spawn(move || batcher(rx, fleet, batch_cap, max_wait))
            .context("spawn batcher")?;
        Ok(Self { tx, handle: Some(handle), next_id: 0.into(), queues, board, default_deadline })
    }

    /// Submit a classic context; returns the single-response receiver.
    pub fn submit(&self, context: Vec<i32>) -> Receiver<Response> {
        self.submit_gen(context, 1)
    }

    /// Submit a generation request: up to `max_new_tokens` tokens stream
    /// back as individual `Response`s on the returned receiver (the channel
    /// closes after the last one). `max_new_tokens <= 1` degrades to the
    /// classic batched next-token path. `ServeConfig::default_deadline_ms`
    /// applies when set.
    pub fn submit_gen(&self, context: Vec<i32>, max_new_tokens: usize) -> Receiver<Response> {
        let deadline = self.default_deadline.map(|d| Instant::now() + d);
        self.submit_inner(context, max_new_tokens, deadline)
    }

    /// Submit with an explicit per-request deadline (overrides the
    /// configured default). Past the deadline the request is answered with
    /// one terminal `Status::Expired` at the next scheduling boundary —
    /// waiting windows at dequeue, in-flight generations at the next
    /// decode-step boundary.
    pub fn submit_with_deadline(
        &self,
        context: Vec<i32>,
        max_new_tokens: usize,
        deadline: Duration,
    ) -> Receiver<Response> {
        self.submit_inner(context, max_new_tokens, Some(Instant::now() + deadline))
    }

    fn submit_inner(
        &self,
        context: Vec<i32>,
        max_new_tokens: usize,
        deadline: Option<Instant>,
    ) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = self.tx.send(Msg::Req(Request {
            id,
            context,
            max_new_tokens,
            submitted: Instant::now(),
            deadline,
            resp: rtx,
        }));
        rrx
    }

    /// One-line live-state dump: queue depths (+ high-water marks), live
    /// shards, and per-status terminal counts so far. The payload of
    /// `recv_or_dump`'s hang diagnosis.
    pub fn debug_state(&self) -> String {
        let depths = self.queues.depth_snapshot();
        let hwm = self.queues.hwm_snapshot();
        let dead = self.queues.dead_snapshot();
        let live: Vec<usize> = (0..dead.len()).filter(|&i| !dead[i]).collect();
        let counts = self.board.snapshot();
        let statuses: Vec<String> = Status::ALL
            .iter()
            .map(|s| format!("{}={}", s.label(), counts[s.index()]))
            .collect();
        format!(
            "queue depths {depths:?} (hwm {hwm:?}), live shards {live:?}, statuses [{}]",
            statuses.join(" ")
        )
    }

    /// Receive with a timeout; on timeout (or a dropped channel) panic with
    /// the coordinator's live state so a hung test points at the stuck
    /// queue/shard instead of an opaque `RecvTimeoutError`.
    pub fn recv_or_dump(&self, rx: &Receiver<Response>, timeout: Duration) -> Response {
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(e) => panic!("response wait failed ({e}); {}", self.debug_state()),
        }
    }

    /// Stop batcher + shards and collect the merged metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Stop(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// The batcher's handle on the shard fleet: the shared queues, the worker
/// join handles, the metrics return channel, and the dispatch policy.
struct Fleet {
    queues: Arc<ShardQueues<Work>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    results: Receiver<ServingMetrics>,
    policy: DispatchPolicy,
    board: Arc<StatusBoard>,
    /// `ServeConfig::max_queued_windows` (0 = unbounded).
    max_queued: usize,
}

/// Candidate order for shortest-queue dispatch: shard indices sorted by
/// (queue depth, shard id). The head is the dispatch target; the tail is
/// the fallback order when the head shard is dead.
fn shortest_queue_order(depths: &[usize]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..depths.len()).collect();
    idx.sort_by_key(|&i| (depths[i], i));
    idx
}

/// Place one closed window on a shard queue under `policy`, skipping dead
/// shards. Windows that land on a shard that dies before draining them are
/// rescued by live peers inside `ShardQueues::pop`, so placement is only a
/// heuristic — never a correctness concern. **Bounded admission**: with
/// `max_queued > 0`, a shard whose queued + in-flight depth is at the cap
/// is closed to new windows; when every live shard is closed the whole
/// window is shed with one terminal `Status::Busy` per request — queue
/// depth stays bounded instead of growing with the overload.
fn place_window(
    queues: &ShardQueues<Work>,
    policy: DispatchPolicy,
    rr: &mut usize,
    max_queued: usize,
    w: Window,
    acct: &mut Acct,
) {
    let dead = queues.dead_snapshot();
    let depths = queues.depth_snapshot();
    if !dead.iter().any(|&d| !d) {
        // responders drop with the window; callers observe closed channels
        eprintln!("batcher: all shards dead; dropping batch of {}", w.len());
        return;
    }
    let open: Vec<usize> = (0..dead.len())
        .filter(|&i| !dead[i] && (max_queued == 0 || depths[i] < max_queued))
        .collect();
    if open.is_empty() {
        for r in w {
            reject(&r, Status::Busy, acct);
        }
        return;
    }
    let target = match policy {
        // WorkSteal places blindly — consumers repair imbalance themselves
        DispatchPolicy::RoundRobin | DispatchPolicy::WorkSteal => {
            let t = open[*rr % open.len()];
            *rr += 1;
            t
        }
        DispatchPolicy::ShortestQueue => *shortest_queue_order(&depths)
            .iter()
            .find(|i| open.contains(i))
            .expect("open is non-empty"),
    };
    queues.push(target, Work::Prefill(w));
}

/// The shared dynamic batcher: owns the request queue, closes batching
/// windows, and places them on the per-shard queues.
fn batcher(rx: Receiver<Msg>, fleet: Fleet, batch_cap: usize, max_wait: Duration) {
    let started = Instant::now();
    let mut rr = 0usize;
    let mut pending: Vec<Request> = Vec::new();
    let Fleet { queues, handles, results, policy, board, max_queued } = fleet;
    // the batcher's own accounting: requests it sheds before any shard ever
    // sees them (its occupancy record is never published — only metrics)
    let mut acct = Acct::new(NO_SHARD, board);

    // Stop the fleet: after `queues.stop()` the shard workers drain every
    // remaining window (their own, stolen, or rescued) and report metrics
    // before exiting, so joining the handles drains all work.
    let finalize = |mtx: Option<Sender<ServingMetrics>>,
                    handles: Vec<std::thread::JoinHandle<()>>,
                    shed: ServingMetrics| {
        queues.stop();
        for h in handles {
            let _ = h.join();
        }
        if let Some(mtx) = mtx {
            let mut agg = ServingMetrics::default();
            while let Ok(m) = results.try_recv() {
                agg.merge(m);
            }
            agg.merge(shed);
            agg.wall_time = started.elapsed();
            let _ = mtx.send(agg);
        }
    };

    loop {
        // blocking wait for the first request (or stop)
        if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => {
                    // a zero-token generation has no terminal response to
                    // stream; reject it typed instead of silently clamping
                    if r.max_new_tokens == 0 {
                        reject(&r, Status::InvalidContext, &mut acct);
                    } else {
                        pending.push(r);
                    }
                }
                Ok(Msg::Stop(mtx)) => {
                    finalize(Some(mtx), handles, acct.metrics);
                    return;
                }
                Err(_) => {
                    // front end dropped without shutdown: stop shards quietly
                    finalize(None, handles, acct.metrics);
                    return;
                }
            }
        }
        // dynamic batching window
        let window_start = Instant::now();
        let mut stop: Option<Sender<ServingMetrics>> = None;
        while pending.len() < batch_cap && window_start.elapsed() < max_wait {
            match rx.recv_timeout(max_wait.saturating_sub(window_start.elapsed())) {
                Ok(Msg::Req(r)) => {
                    // a zero-token generation has no terminal response to
                    // stream; reject it typed instead of silently clamping
                    if r.max_new_tokens == 0 {
                        reject(&r, Status::InvalidContext, &mut acct);
                    } else {
                        pending.push(r);
                    }
                }
                Ok(Msg::Stop(mtx)) => {
                    stop = Some(mtx);
                    break;
                }
                Err(_) => break,
            }
        }
        let batch: Vec<Request> = pending.drain(..).collect();
        if !batch.is_empty() {
            place_window(&queues, policy, &mut rr, max_queued, batch, &mut acct);
        }
        if let Some(mtx) = stop {
            finalize(Some(mtx), handles, acct.metrics);
            return;
        }
    }
}

/// Per-shard wiring passed into the worker thread.
struct ShardCtx {
    shard: usize,
    net_us: u64,
    /// pool workers inside the replica's native forward pass
    fwd_workers: usize,
    /// whether this worker may steal queued windows from live peers
    steal: bool,
    /// precision of this shard's KV-cache pages
    kv_prec: Precision,
    /// KV-cache budget in bytes (per shard)
    kv_budget: usize,
    /// live-sequence cap per fused decode step (1 = per-sequence GEMV path)
    max_decode_batch: usize,
    /// decode-admission cap: live sequences per shard (0 = unbounded);
    /// admission past it sheds with `Status::Busy` at the step boundary
    max_live_seqs: usize,
    /// whether generation admissions consult the shard cache's prefix index
    /// before charging the KV budget (DESIGN.md §14; off = the equivalence
    /// oracle that always ingests fresh)
    prefix_cache: bool,
    /// this shard's disjoint core block when `pin_workers` is on
    /// (DESIGN.md §16): the shard thread pins itself to `cores[0]` before
    /// building its replica (so the packed payloads are first-touched
    /// node-local) and the forward pool's helpers spread over the block.
    /// Best-effort; `None` = unpinned.
    pin_cores: Option<Vec<usize>>,
    /// fleet-shared requant policy (`None` = requant fully off: no
    /// controller is built and block precisions never move)
    requant: Option<Arc<requant::RequantPlan>>,
    /// scripted swap schedule (each shard applies it at its own item
    /// ordinals; see `config::ForcedSwap`)
    requant_forced: Vec<crate::config::ForcedSwap>,
    /// fleet-shared live per-status counters
    board: Arc<StatusBoard>,
    /// this shard's deterministic fault-injection plan (chaos harness)
    #[cfg(any(test, feature = "chaos"))]
    faults: faultfx::ShardFaults,
}

/// Marks the shard dead on every non-clean exit (panic mid-batch, setup
/// failure) so peers rescue its queued windows and parked workers re-check
/// the stop condition.
struct DeathGuard {
    shard: usize,
    queues: Arc<ShardQueues<Work>>,
    armed: bool,
}

impl Drop for DeathGuard {
    fn drop(&mut self) {
        if self.armed {
            self.queues.mark_dead(self.shard);
        }
    }
}

/// One shard worker: owns a model replica plus the shard's KV cache, and
/// drains the shared queues — prefill windows and (pinned) decode turns
/// interleave through the same deque.
fn shard_worker(
    ctx: ShardCtx,
    model: ModelDir,
    plan: QuantPlan,
    queues: Arc<ShardQueues<Work>>,
    ready: Sender<std::result::Result<(), String>>,
    results: Sender<ServingMetrics>,
) -> Result<()> {
    #[cfg(any(test, feature = "chaos"))]
    let mut chaos = faultfx::FaultState::new(ctx.faults.clone());
    let ShardCtx {
        shard,
        net_us,
        fwd_workers,
        steal,
        kv_prec,
        kv_budget,
        max_decode_batch,
        max_live_seqs,
        prefix_cache,
        pin_cores,
        requant,
        requant_forced,
        board,
        ..
    } = ctx;
    let mut guard = DeathGuard { shard, queues: queues.clone(), armed: true };
    // pin this shard thread to its block's first core *before* building the
    // replica, so the packed payloads it allocates are first-touched on the
    // node the shard will run on (best-effort: a refused pin changes
    // nothing but locality)
    if let Some(cores) = &pin_cores {
        let _ = crate::par::affinity::pin_to_core(cores[0]);
    }
    // Runtime lives entirely inside this thread (PJRT client is not Send).
    let setup = (|| -> Result<_> {
        let rt = Runtime::cpu()?;
        let qm = QuantizedModel::build(&model, &plan)?;
        Ok((rt, qm))
    })();
    let (rt, qm) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    let ex = ModelExecutor::with_pool(&rt, &model, Pool::new_pinned(fwd_workers, pin_cores));
    let (b, s) = (model.schema.eval_batch, model.schema.seq_len);
    let v = model.schema.vocab;
    let n_blocks = model.schema.n_blocks;
    let geom = KvGeometry {
        page_tokens: KV_PAGE_TOKENS,
        n_heads: model.schema.n_heads,
        head_dim: model.schema.d_model / model.schema.n_heads,
    };
    // the executor keeps its own schema/dir copies and the quantized replica
    // is self-contained — drop the fp32 weights instead of pinning a second
    // full-precision copy of the model per shard for the thread's lifetime.
    // (The replica itself is resident at *packed* size: the fused kernels
    // consume the QMat payloads directly, no f32 shadow copies.)
    drop(model);
    if let Err(e) = ex.warmup() {
        let _ = ready.send(Err(format!("{e:#}")));
        return Err(e);
    }
    let _ = ready.send(Ok(()));

    let mut acct = Acct::new(shard, board);
    acct.metrics.resident_weight_bytes = qm.resident_bytes();
    acct.metrics.resident_weight_bytes_per_replica = qm.resident_bytes();
    // this shard's precision controller (None = requant fully off); swaps
    // only ever land at the top of the queue loop, between work items
    let mut requant_ctl =
        requant.map(|p| requant::Controller::new(p, requant_forced));
    // work items dequeued so far — the forced-swap schedule's clock
    let mut item_ord = 0usize;
    let started = Instant::now();
    // this shard's KV cache (decoding sequences are pinned to it) and the
    // reused decode logits buffers (single-row for per-sequence turns and
    // context ingest, (max_decode_batch, vocab) for fused batched steps) —
    // allocated once, never on the hot path
    let mut kv = KvCache::new(geom, kv_budget, kv_prec);
    let mut logits = vec![0.0f32; v];
    let mut batch_logits = vec![0.0f32; max_decode_batch * v];

    loop {
        // chaos: scheduled shard death fires here, BEFORE popping — nothing
        // is in flight, so every queued window is rescued and answered
        // exactly once by the survivors; slow-shard stalls land here too
        #[cfg(any(test, feature = "chaos"))]
        chaos.before_item(shard);
        let (work, stolen) = match queues.pop(shard, steal) {
            Popped::Own(w) => (w, false),
            Popped::Stolen(w, _from) => (w, true),
            Popped::Stop => break,
        };
        if stolen {
            acct.occ.steals += 1;
        }
        // requant swaps land HERE, at the step boundary: the item just
        // popped has not started and nothing else is in flight on this
        // shard, so publishing a new payload generation can never tear a
        // decode step (snapshots taken mid-step keep the old generation).
        // Scripted swaps fire first (deterministic timing for the
        // equivalence harness), then one pressure evaluation.
        if let Some(ctl) = requant_ctl.as_mut() {
            ctl.force(&qm, item_ord);
            // depth includes the item just popped (its slot frees at
            // `complete`), so <= 1 means nothing else is waiting
            let queue_idle = queues.depth_snapshot()[shard] <= 1;
            ctl.step(&qm, kv.allocated_bytes(), queue_idle);
            // keep residency live so `requant_bytes_freed` reconciles
            // against the reported footprint at any shutdown point
            acct.metrics.resident_weight_bytes = qm.resident_bytes();
            acct.metrics.resident_weight_bytes_per_replica = qm.resident_bytes();
        }
        item_ord += 1;
        match work {
            Work::Prefill(batch) => {
                #[cfg(test)]
                if batch.iter().any(|r| r.context.first() == Some(&POISON_CONTEXT)) {
                    panic!("shard {shard}: poison request — simulated mid-flight crash");
                }
                // deadline check at dequeue: an expired request is answered
                // with one terminal Expired and never executed
                let (batch, lapsed): (Vec<Request>, Vec<Request>) =
                    batch.into_iter().partition(|r| !expired(r));
                for r in lapsed {
                    reject(&r, Status::Expired, &mut acct);
                }
                // generation requests leave the window here: each becomes a
                // pinned decode job on this shard's queue
                let (gen, classic): (Vec<Request>, Vec<Request>) =
                    batch.into_iter().partition(|r| r.max_new_tokens > 1);
                for r in gen {
                    #[cfg(any(test, feature = "chaos"))]
                    {
                        if chaos.deny_kv() {
                            // injected budget exhaustion: degrade exactly
                            // like a real failed reservation
                            reject(&r, Status::KvExhausted, &mut acct);
                            continue;
                        }
                    }
                    start_decode(
                        r,
                        n_blocks,
                        (s, v),
                        &mut kv,
                        &queues,
                        max_live_seqs,
                        prefix_cache,
                        &mut acct,
                    );
                }
                if !classic.is_empty() {
                    execute_batch(classic, &ex, &qm, (b, s, v), net_us, &mut acct);
                }
            }
            Work::Decode(job) => {
                if stolen {
                    // rescued off a dead peer's queue: its KV pages died
                    // with that shard — fail the stream cleanly, exactly
                    // once (the queue popped it exactly once)
                    fail_decode(job, Status::ShardLost, &mut acct);
                } else if expired(&job.req) {
                    // deadline passed between turns: retire at the step
                    // boundary with one terminal Expired
                    job.state.release(&mut kv);
                    fail_decode(job, Status::Expired, &mut acct);
                } else if max_decode_batch <= 1 {
                    // per-sequence GEMV path: the batched path's
                    // equivalence oracle, kept behind the config switch
                    if let Some(job) = decode_turn(
                        job,
                        &ex,
                        &qm,
                        &mut kv,
                        &mut logits,
                        (s, v),
                        prefix_cache,
                        &mut acct,
                    ) {
                        // more tokens to generate: go to the back of the
                        // queue so prefill windows that arrived meanwhile
                        // interleave
                        queues.push(shard, Work::Decode(job));
                    }
                } else {
                    // continuous batching: gather every other decode turn
                    // queued on this shard (admission at the step boundary)
                    // and advance the whole cohort through one fused step
                    let mut jobs = vec![job];
                    let drained = queues.drain_pinned(shard, max_decode_batch - 1);
                    let n_drained = drained.len();
                    jobs.extend(drained.into_iter().map(|w| match w {
                        Work::Decode(j) => j,
                        Work::Prefill(_) => unreachable!("only decode work is pinned"),
                    }));
                    // expired cohort members retire here, at the boundary
                    let (jobs, lapsed): (Vec<DecodeJob>, Vec<DecodeJob>) =
                        jobs.into_iter().partition(|j| !expired(&j.req));
                    for j in lapsed {
                        j.state.release(&mut kv);
                        fail_decode(j, Status::Expired, &mut acct);
                    }
                    for job in decode_batch_turn(
                        jobs,
                        &ex,
                        &qm,
                        &mut kv,
                        &mut logits,
                        &mut batch_logits,
                        (s, v),
                        prefix_cache,
                        &mut acct,
                    ) {
                        queues.push(shard, Work::Decode(job));
                    }
                    // each drained window carried its own depth slot (the
                    // popped one is completed at the bottom of the loop)
                    for _ in 0..n_drained {
                        queues.complete(shard);
                    }
                }
            }
        }
        // done (or rejected/failed/requeued): release the window's depth
        // slot so the shortest-queue heuristic sees this shard as free again
        queues.complete(shard);
    }
    guard.armed = false;
    acct.occ.wakes = queues.wake_count(shard);
    acct.metrics.steals = acct.occ.steals;
    acct.metrics.wakes = acct.occ.wakes;
    acct.metrics.kv_bytes = kv.peak_bytes();
    // every decode stream must have retired its KV hold by clean exit (the
    // prefix index legitimately keeps pages resident, but never sequence
    // tables), and the refcount books must balance exactly — both surface
    // as a nonzero metric the chaos/equivalence suites assert against
    acct.metrics.kv_leaked_seqs = kv.live_sequences();
    if let Err(e) = kv.check_invariants() {
        eprintln!("shard {shard}: kv page accounting violated at exit: {e}");
        acct.metrics.kv_leaked_seqs += 1;
    }
    acct.metrics.queue_depth_hwm = queues.depth_hwm(shard);
    // final precision books: the residency histogram is reported even with
    // requant off (all blocks sit in their build-time bucket), and the swap
    // counters come straight from the controller so
    //   initial_resident - final_resident == bytes_freed - bytes_regrown
    // holds at any shutdown point
    acct.metrics.block_residency = qm.block_residency();
    acct.metrics.resident_weight_bytes = qm.resident_bytes();
    acct.metrics.resident_weight_bytes_per_replica = qm.resident_bytes();
    if let Some(ctl) = requant_ctl.as_ref() {
        acct.metrics.requant_swaps = ctl.swaps;
        acct.metrics.requant_bytes_freed = ctl.bytes_freed;
        acct.metrics.requant_bytes_regrown = ctl.bytes_regrown;
    }
    acct.metrics.wall_time = started.elapsed();
    let Acct { metrics: mut m, occ, .. } = acct;
    m.shards = vec![occ];
    let _ = results.send(m);
    Ok(())
}

/// End a decode stream with a single terminal non-`Ok` response (validation
/// failure, KV budget exhaustion, deadline expiry, or dead-shard rescue).
/// The caller's stream ends here — channel closed after exactly one typed
/// failure marker, never a dangling wait.
fn fail_decode(job: DecodeJob, st: Status, acct: &mut Acct) {
    reject(&job.req, st, acct);
}

/// Validate a generation request and seat its decoding sequence on this
/// shard: consult the prefix index first (a hit attaches already-resident
/// shared-prefix pages copy-free, so the budget is charged only for the
/// unshared remainder), then reserve the sequence's KV window up front (so
/// steady-state decode turns never allocate) and queue the pinned decode
/// job behind the current work. Invalid contexts fail with
/// `InvalidContext`, the live-sequence cap sheds with `Busy`, and budget
/// overruns degrade to `KvExhausted` — each a single terminal response,
/// never a mid-stream failure.
#[allow(clippy::too_many_arguments)]
fn start_decode(
    req: Request,
    n_blocks: usize,
    (s, v): (usize, usize),
    kv: &mut KvCache,
    queues: &ShardQueues<Work>,
    max_live_seqs: usize,
    prefix_cache: bool,
    acct: &mut Acct,
) {
    // same validation rule as the prefill path: only the seq_len prefix is
    // ever executed, and it must be entirely in-vocab; generation also
    // needs at least one context token to ingest
    let ctx_len = req.context.len().min(s);
    let valid =
        ctx_len > 0 && req.context[..ctx_len].iter().all(|&t| t >= 0 && (t as usize) < v);
    if !valid {
        reject(&req, Status::InvalidContext, acct);
        return;
    }
    // bounded admission: refuse to seat more concurrent decode sequences
    // than configured — shed with Busy at the admission boundary instead of
    // letting reservations fight over the KV budget mid-stream
    if max_live_seqs > 0 && kv.live_sequences() >= max_live_seqs {
        reject(&req, Status::Busy, acct);
        return;
    }
    let mut state = DecodeState::new(req.id, n_blocks);
    // prefix caching (DESIGN.md §14): a hit seats the sequence on the
    // shared pages before the reservation below, which then only charges
    // the budget for the pages past the attach point — the first decode
    // turn ingests just the unshared suffix. A miss costs one index lookup.
    if prefix_cache {
        let at = state.attach_prefix(kv, &req.context[..ctx_len]);
        if at.tokens > 0 {
            acct.metrics.prefix_hits += 1;
            acct.metrics.prefix_tokens_reused += at.tokens;
            acct.metrics.kv_shared_bytes += at.shared_bytes;
        }
    }
    // the context plus every generated token except the last must fit the
    // window; reserve that many KV slots per block now (saturating: a
    // caller-controlled max_new_tokens near usize::MAX must not overflow —
    // ctx_len >= 1 here, so this equals ctx_len + max_new_tokens - 1)
    let window = (ctx_len - 1).saturating_add(req.max_new_tokens).min(s);
    if let Err(e) = state.reserve(kv, window) {
        eprintln!("shard {}: request {}: {e:#}", acct.shard, req.id);
        state.release(kv);
        reject(&req, Status::KvExhausted, acct);
        return;
    }
    queues.push(acct.shard, Work::Decode(DecodeJob { req, state, produced: 0, next_input: 0 }));
}

/// Run one queue turn of a decoding sequence. The first turn ingests the
/// (seq_len-truncated) context through `decode_step` — starting past any
/// prefix-attached positions, so a cache hit ingests only the unshared
/// suffix — populating the sequence's KV pages and producing the first
/// generated token, which at Raw KV precision is bit-identical to what the
/// batched prefill would have answered; the freshly ingested context is
/// then published into the prefix index for later same-prefix admissions.
/// Every later turn advances exactly one token. Each generated token
/// streams back as its own `Response`. Returns the job when more tokens
/// remain, `None` when the stream is finished (or failed).
#[allow(clippy::too_many_arguments)]
fn decode_turn(
    mut job: DecodeJob,
    ex: &ModelExecutor<'_>,
    qm: &QuantizedModel,
    kv: &mut KvCache,
    logits: &mut [f32],
    (s, v): (usize, usize),
    prefix_cache: bool,
    acct: &mut Acct,
) -> Option<DecodeJob> {
    let exec_start = Instant::now();
    let first_turn = job.produced == 0;
    let stepped: Result<()> = if first_turn {
        let ctx_len = job.req.context.len().min(s);
        let mut r = Ok(());
        // a prefix-cache hit advanced the cursor at admission: those
        // positions are already resident, only the suffix is ingested
        for i in job.state.pos().min(ctx_len)..ctx_len {
            r = ex.decode_step_into(qm, job.req.context[i], &mut job.state, kv, logits);
            acct.metrics.decode_steps += 1;
            if r.is_err() {
                break;
            }
        }
        if r.is_ok() && prefix_cache {
            // publish the now-fully-ingested context so later same-prefix
            // admissions attach instead of re-ingesting (idempotent when
            // this sequence itself attached to an existing entry)
            job.state.register_prefix(kv, &job.req.context[..ctx_len]);
        }
        r
    } else {
        acct.metrics.decode_steps += 1;
        ex.decode_step_into(qm, job.next_input, &mut job.state, kv, logits)
    };
    acct.occ.busy_us += exec_start.elapsed().as_micros() as u64;
    if let Err(e) = stepped {
        // defensive: reservation makes this unreachable in practice, but a
        // decode failure must end the stream cleanly, not kill the shard
        eprintln!("shard {}: decode of request {} failed: {e:#}", acct.shard, job.req.id);
        job.state.release(kv);
        fail_decode(job, Status::ShardLost, acct);
        return None;
    }
    let next = crate::model::sampler::argmax(&logits[..v]) as i32;
    job.produced += 1;
    job.next_input = next;
    let delivered = job
        .req
        .resp
        .send(Response {
            id: job.req.id,
            next_token: next,
            status: Status::Ok,
            latency: job.req.submitted.elapsed(),
            network_latency_us: 0,
            batch_size: 1,
            shard: acct.shard,
        })
        .is_ok();
    // the stream ends when the token budget is spent, the context window is
    // full (no room to feed the new token back), or the caller went away
    let done = job.produced >= job.req.max_new_tokens || job.state.pos() >= s || !delivered;
    if done {
        job.state.release(kv);
        acct.resolve(Status::Ok, job.req.submitted.elapsed().as_micros() as u64);
        return None;
    }
    Some(job)
}

/// Advance a gathered cohort of decode jobs by one turn (continuous
/// batching). Jobs still on their first turn ingest their (ragged-length)
/// context per-sequence via `decode_turn` — they join the fused batch at
/// the next step boundary. Everyone else advances together through ONE
/// `decode_step_batched`: one fused GEMM per weight matrix per block, with
/// each sequence's attention read from its own KV pages — bit-identical to
/// the per-sequence turns it replaces, so response streams are invariant
/// under `max_decode_batch`. Finished/failed/abandoned sequences retire
/// here, mid-batch; the returned survivors go back on the queue and are
/// re-gathered (possibly alongside newly admitted sequences) next turn.
#[allow(clippy::too_many_arguments)]
fn decode_batch_turn(
    jobs: Vec<DecodeJob>,
    ex: &ModelExecutor<'_>,
    qm: &QuantizedModel,
    kv: &mut KvCache,
    logits: &mut [f32],
    batch_logits: &mut [f32],
    (s, v): (usize, usize),
    prefix_cache: bool,
    acct: &mut Acct,
) -> Vec<DecodeJob> {
    let (first, steady): (Vec<DecodeJob>, Vec<DecodeJob>) =
        jobs.into_iter().partition(|j| j.produced == 0);
    let mut survivors = Vec::new();
    for job in first {
        if let Some(j) = decode_turn(job, ex, qm, kv, logits, (s, v), prefix_cache, acct) {
            survivors.push(j);
        }
    }
    if steady.is_empty() {
        return survivors;
    }
    let m = steady.len();
    let exec_start = Instant::now();
    let tokens: Vec<i32> = steady.iter().map(|j| j.next_input).collect();
    let mut states: Vec<DecodeState> = steady.iter().map(|j| j.state.clone()).collect();
    let stepped =
        ex.decode_step_batched(qm, &tokens, &mut states, kv, &mut batch_logits[..m * v]);
    acct.metrics.decode_steps += m;
    acct.metrics.batched_steps += 1;
    acct.metrics.decode_batch_rows += m;
    acct.occ.busy_us += exec_start.elapsed().as_micros() as u64;
    if let Err(e) = stepped {
        // defensive: reservation + admission guards make this unreachable
        // in practice, but a failed fused step must end every in-flight
        // stream cleanly (one terminal status each), not kill the shard
        eprintln!("shard {}: fused decode step of {m} sequences failed: {e:#}", acct.shard);
        for job in steady {
            job.state.release(kv);
            fail_decode(job, Status::ShardLost, acct);
        }
        return survivors;
    }
    for (row, mut job) in steady.into_iter().enumerate() {
        job.state = states[row].clone();
        let next = crate::model::sampler::argmax(&batch_logits[row * v..(row + 1) * v]) as i32;
        job.produced += 1;
        job.next_input = next;
        let delivered = job
            .req
            .resp
            .send(Response {
                id: job.req.id,
                next_token: next,
                status: Status::Ok,
                latency: job.req.submitted.elapsed(),
                network_latency_us: 0,
                batch_size: m,
                shard: acct.shard,
            })
            .is_ok();
        let done = job.produced >= job.req.max_new_tokens || job.state.pos() >= s || !delivered;
        if done {
            job.state.release(kv);
            acct.resolve(Status::Ok, job.req.submitted.elapsed().as_micros() as u64);
        } else {
            survivors.push(job);
        }
    }
    survivors
}

/// Execute one dispatched batch on a shard's replica: reject out-of-vocab
/// contexts, pad, forward, answer. Split out of `shard_worker` so every
/// early exit still falls through to the queue-depth release.
fn execute_batch(
    batch: Vec<Request>,
    ex: &ModelExecutor<'_>,
    qm: &QuantizedModel,
    (b, s, v): (usize, usize, usize),
    net_us: u64,
    acct: &mut Acct,
) {
    let exec_start = Instant::now();
    // reject out-of-vocab contexts up front: the executor validates token
    // range, and one malformed request must never kill the shard (and with
    // it 1/N of all traffic). Only the seq_len prefix is validated — the
    // tail beyond it is truncated away and never executed.
    let (batch, invalid): (Vec<Request>, Vec<Request>) = batch.into_iter().partition(|r| {
        r.context[..r.context.len().min(s)].iter().all(|&t| t >= 0 && (t as usize) < v)
    });
    for r in invalid {
        // answered but never executed: counted separately and excluded
        // from the latency/batch aggregates
        reject(&r, Status::InvalidContext, acct);
    }
    if batch.is_empty() {
        return;
    }
    // execute one padded batch
    let mut toks = vec![0i32; b * s];
    let mut pos = vec![0usize; batch.len()];
    for (row, r) in batch.iter().enumerate() {
        let ctx = &r.context[..r.context.len().min(s)];
        toks[row * s..row * s + ctx.len()].copy_from_slice(ctx);
        pos[row] = ctx.len().saturating_sub(1);
    }
    let logits = match ex.forward(qm, &toks) {
        Ok(l) => l,
        Err(e) => {
            // a failed forward still answers every caller — one terminal
            // ShardLost each, never a silently closed channel — and keeps
            // the shard alive for future work
            eprintln!("shard {}: batch of {} failed: {e:#}", acct.shard, batch.len());
            for r in &batch {
                reject(r, Status::ShardLost, acct);
            }
            return;
        }
    };
    acct.metrics.batches += 1;
    acct.metrics.max_batch_observed = acct.metrics.max_batch_observed.max(batch.len());
    acct.metrics.virtual_network_us += net_us;
    for (row, r) in batch.iter().enumerate() {
        let base = (row * s + pos[row]) * v;
        let next = crate::model::sampler::argmax(&logits[base..base + v]) as i32;
        let latency = r.submitted.elapsed();
        acct.resolve(Status::Ok, latency.as_micros() as u64);
        let _ = r.resp.send(Response {
            id: r.id,
            next_token: next,
            status: Status::Ok,
            latency,
            network_latency_us: net_us,
            batch_size: batch.len(),
            shard: acct.shard,
        });
    }
    acct.occ.batches += 1;
    acct.occ.busy_us += exec_start.elapsed().as_micros() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::quant::Precision;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
    use crate::zoo::Schema;

    const ALL_POLICIES: [DispatchPolicy; 3] = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::ShortestQueue,
        DispatchPolicy::WorkSteal,
    ];

    fn model_path() -> Option<std::path::PathBuf> {
        let p = crate::artifacts_dir().join("models/tl-phi");
        if p.join("weights.ets").exists() {
            Some(p)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    /// Small synthetic model: serving runs offline through the native
    /// reference executor, no artifacts needed.
    fn tiny_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "tiny-serve".into(),
                n_blocks: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                vocab: 64,
                seq_len: 8,
                eval_batch: 4,
            },
            profile: Profile::RampUp,
            seed: 91,
        })
    }

    /// Test-wide response wait: long enough for the slowest CI host; a
    /// timeout panics with the coordinator's live state via `recv_or_dump`.
    const RECV_T: Duration = Duration::from_secs(120);

    fn collect_tokens_with(
        model: &ModelDir,
        workers: usize,
        requests: usize,
        dispatch: DispatchPolicy,
    ) -> (Vec<i32>, ServingMetrics) {
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg =
            ServeConfig { max_batch: 4, max_wait_us: 500, workers, dispatch, ..Default::default() };
        let coord =
            Coordinator::start_with_model(model.clone(), plan, cfg, 1, 50).unwrap();
        let mut rxs = Vec::with_capacity(requests);
        for i in 0..requests {
            rxs.push(coord.submit(vec![
                (i % 64) as i32,
                ((i * 7) % 64) as i32,
                ((i * 13) % 64) as i32,
            ]));
        }
        let toks: Vec<i32> =
            rxs.into_iter().map(|rx| coord.recv_or_dump(&rx, RECV_T).next_token).collect();
        (toks, coord.shutdown())
    }

    fn collect_tokens(model: &ModelDir, workers: usize, requests: usize) -> (Vec<i32>, ServingMetrics) {
        collect_tokens_with(model, workers, requests, DispatchPolicy::default())
    }

    #[test]
    fn sharded_serving_answers_everything_offline() {
        let model = tiny_model();
        let (toks, m) = collect_tokens(&model, 3, 20);
        assert_eq!(toks.len(), 20);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(m.completed, 20);
        assert!(m.batches >= 1);
        assert_eq!(m.shards.len(), 3, "one occupancy record per shard");
        assert_eq!(m.shards.iter().map(|s| s.completed).sum::<usize>(), 20);
        assert_eq!(m.shards.iter().map(|s| s.batches).sum::<usize>(), m.batches);
        assert_eq!(m.steals, m.shards.iter().map(|s| s.steals).sum::<usize>());
        for (i, s) in m.shards.iter().enumerate() {
            assert_eq!(s.shard, i);
            let o = s.occupancy(m.wall_time);
            assert!((0.0..=1.0).contains(&o));
        }
    }

    #[test]
    fn shortest_queue_order_is_depth_then_id() {
        assert_eq!(shortest_queue_order(&[]), Vec::<usize>::new());
        assert_eq!(shortest_queue_order(&[5]), vec![0]);
        assert_eq!(shortest_queue_order(&[2, 0, 1]), vec![1, 2, 0]);
        // ties break on shard id, so the order is total and deterministic
        assert_eq!(shortest_queue_order(&[1, 1, 0, 1]), vec![2, 0, 1, 3]);
        crate::proptest_lite::check(
            0x5105,
            100,
            16,
            |g| {
                let n = g.usize_in(1, 12);
                (0..n).map(|_| g.usize_in(0, 8)).collect::<Vec<usize>>()
            },
            |depths| {
                let order = shortest_queue_order(depths);
                let mut seen = order.clone();
                seen.sort_unstable();
                if seen != (0..depths.len()).collect::<Vec<_>>() {
                    return Err("not a permutation".into());
                }
                for w in order.windows(2) {
                    if (depths[w[0]], w[0]) > (depths[w[1]], w[1]) {
                        return Err(format!("order violated at {w:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Big enough that one forward takes real time (~100ms-class on a CI
    /// host): the balance tests need execution to outlast dispatch by a
    /// wide margin, so queues are non-empty whenever the batcher (or an
    /// idle thief) routes the next expensive window.
    fn balance_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "balance".into(),
                n_blocks: 4,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 64,
                seq_len: 32,
                eval_batch: 8,
            },
            profile: Profile::UShape,
            seed: 1717,
        })
    }

    fn run_skewed(dispatch: crate::config::DispatchPolicy) -> ServingMetrics {
        let model = balance_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1, // every request is its own window
            max_wait_us: 100,
            workers: 2,
            dispatch,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // skewed batch costs: even windows are expensive (a full forward),
        // odd windows are cheap (all-reject, answered without executing)
        let mut rxs = Vec::new();
        for i in 0..24 {
            let ctx = if i % 2 == 0 { vec![1, 2, 3] } else { vec![-1] };
            rxs.push(coord.submit(ctx));
        }
        for rx in rxs {
            let _ = coord.recv_or_dump(&rx, RECV_T);
        }
        coord.shutdown()
    }

    #[test]
    fn shortest_queue_balances_skewed_batch_costs() {
        use crate::config::DispatchPolicy;
        // Round-robin alternates blindly: with alternating expensive/cheap
        // windows and two shards, every expensive window lands on shard 0 —
        // shard 1 never executes a batch.
        let rr = run_skewed(DispatchPolicy::RoundRobin);
        assert_eq!(rr.completed, 24);
        let rr_batches: Vec<usize> = rr.shards.iter().map(|s| s.batches).collect();
        assert_eq!(rr_batches.iter().sum::<usize>(), 12);
        assert_eq!(
            rr_batches.iter().filter(|&&b| b == 0).count(),
            1,
            "round-robin starves one shard of executed work: {rr_batches:?}"
        );
        assert_eq!(rr.steals, 0, "round-robin never steals");
        // Shortest-queue routes around the busy shard: both shards execute
        // expensive windows. (All 24 requests are queued before the first
        // ~100ms forward finishes, so the starved-shard outcome would need
        // the batcher to stall ~100ms between every pair of windows — the
        // assertion is kept to >= 1 per shard so scheduler noise on loaded
        // CI hosts cannot flake it.)
        let sq = run_skewed(DispatchPolicy::ShortestQueue);
        assert_eq!(sq.completed, 24);
        let sq_batches: Vec<usize> = sq.shards.iter().map(|s| s.batches).collect();
        assert_eq!(sq_batches.iter().sum::<usize>(), 12);
        assert!(
            sq_batches.iter().all(|&b| b >= 1),
            "shortest-queue must spread executed batches: {sq_batches:?}"
        );
        let rr_min = *rr_batches.iter().min().unwrap();
        let sq_min = *sq_batches.iter().min().unwrap();
        assert!(sq_min > rr_min, "balance must improve: rr {rr_batches:?} vs sq {sq_batches:?}");
    }

    #[test]
    fn work_steal_balances_skewed_batch_costs() {
        use crate::config::DispatchPolicy;
        // WorkSteal places like round-robin (all expensive windows on shard
        // 0), but the idle shard pulls from the backed-up queue: both shards
        // end up executing, and steals are observed and accounted.
        let ws = run_skewed(DispatchPolicy::WorkSteal);
        assert_eq!(ws.completed, 24);
        let ws_batches: Vec<usize> = ws.shards.iter().map(|s| s.batches).collect();
        assert_eq!(ws_batches.iter().sum::<usize>(), 12);
        assert!(
            ws_batches.iter().all(|&b| b >= 1),
            "work stealing must spread executed batches: {ws_batches:?}"
        );
        assert!(ws.steals >= 1, "the idle shard must have stolen queued work");
        assert_eq!(ws.steals, ws.shards.iter().map(|s| s.steals).sum::<usize>());
        assert!(ws.wakes >= 1, "idle shards park and are woken");
    }

    #[test]
    fn metrics_report_packed_resident_bytes_per_replica() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q4);
        let expected = QuantizedModel::build(&model, &plan).unwrap().resident_bytes();
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 500, workers: 3, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let rx = coord.submit(vec![1, 2, 3]);
        let _ = coord.recv_or_dump(&rx, RECV_T);
        let m = coord.shutdown();
        assert_eq!(
            m.resident_weight_bytes,
            3 * expected,
            "every shard pins exactly one packed replica"
        );
        assert_eq!(
            m.resident_weight_bytes_per_replica, expected,
            "the per-replica figure is one replica's footprint, not the fleet sum"
        );
        // residency is reported even with requant off: every replica's
        // blocks sit in their build-time bucket
        assert_eq!(m.block_residency[Precision::Q4.tag() as usize], 3 * 2);
        assert_eq!(m.block_residency.iter().sum::<usize>(), 3 * 2);
        assert!(m.summary().contains("resident"));
        assert!(m.summary().contains("/replica"));
    }

    /// Every degenerate knob fails at `start_with_model`, typed and naming
    /// the knob — not as a silent clamp or a downstream hang — and a
    /// zero-token generation is rejected per-request the same way.
    #[test]
    fn degenerate_serve_configs_are_rejected_at_startup() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let bad = [
            (ServeConfig { max_decode_batch: 0, ..Default::default() }, "max_decode_batch"),
            (ServeConfig { kv_budget_mb: 0.0, ..Default::default() }, "kv_budget_mb"),
            (ServeConfig { kv_budget_mb: f64::NAN, ..Default::default() }, "kv_budget_mb"),
            (ServeConfig { forward_workers: 0, ..Default::default() }, "forward_workers"),
            (
                ServeConfig {
                    requant: true,
                    requant_low_mb: 64.0,
                    requant_high_mb: 48.0,
                    ..Default::default()
                },
                "requant",
            ),
        ];
        for (cfg, knob) in bad {
            let err = Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0)
                .err()
                .expect("degenerate config must fail startup");
            let msg = format!("{err}");
            assert!(msg.contains(knob), "error names the offending knob {knob}: {msg}");
        }
        // the request-level twin: max_new_tokens == 0 used to be clamped to
        // 1 in submit_inner, answering a question nobody asked
        let coord =
            Coordinator::start_with_model(model, plan, ServeConfig::default(), 0, 0).unwrap();
        let rx = coord.submit_gen(vec![1, 2], 0);
        let resps: Vec<Response> = rx.iter().collect();
        assert_eq!(resps.len(), 1, "exactly one terminal response");
        assert_eq!(resps[0].status, Status::InvalidContext);
        assert_eq!(resps[0].next_token, INVALID_TOKEN);
        let m = coord.shutdown();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.statuses[Status::InvalidContext.index()], 1);
    }

    /// When every request was rejected the latency sample set is empty; the
    /// summary must say `n/a`, not fabricate a `p50 0us` figure.
    #[test]
    fn summary_renders_na_for_percentiles_when_nothing_completed_ok() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 300, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // out-of-vocab and zero-token: both rejected, excluded from latencies
        let a = coord.submit(vec![9999]);
        let b = coord.submit_gen(vec![1, 2], 0);
        assert_eq!(coord.recv_or_dump(&a, RECV_T).status, Status::InvalidContext);
        assert_eq!(coord.recv_or_dump(&b, RECV_T).status, Status::InvalidContext);
        let m = coord.shutdown();
        assert!(m.latencies_us.is_empty(), "rejects never enter the latency sample");
        let s = m.summary();
        assert!(s.contains("p50 n/a p95 n/a p99 n/a"), "empty percentiles render n/a: {s}");
        assert!(!s.contains("p50 0us"), "no fabricated zero percentile: {s}");
    }

    /// Scripted swaps on a live coordinator: the controller's byte books
    /// must reconcile exactly against the reported resident footprint, and
    /// the residency histogram must account for every block of every
    /// replica.
    #[test]
    fn forced_requant_books_reconcile_with_resident_footprint() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let initial = QuantizedModel::build(&model, &plan).unwrap().resident_bytes();
        let forced = vec![
            crate::config::ForcedSwap { after_item: 0, block: 0, prec: Precision::Q4 },
            crate::config::ForcedSwap { after_item: 1, block: 1, prec: Precision::Q3 },
            crate::config::ForcedSwap { after_item: 2, block: 0, prec: Precision::Q8 },
        ];
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 1,
            max_wait_us: 200,
            requant_forced: forced,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // serialized submission so item ordinals are deterministic; enough
        // items that every scripted swap fires
        for i in 0..5 {
            let rx = coord.submit(vec![(i % 64) as i32, 1, 2]);
            let r = coord.recv_or_dump(&rx, RECV_T);
            assert_eq!(r.status, Status::Ok, "request {i} served across swaps");
        }
        let m = coord.shutdown();
        assert_eq!(m.requant_swaps, 3, "every scripted swap fired");
        assert!(m.requant_bytes_freed > 0);
        assert!(m.requant_bytes_regrown > 0, "the Q8 restore regrows bytes");
        assert_eq!(
            initial - m.resident_weight_bytes,
            m.requant_bytes_freed - m.requant_bytes_regrown,
            "controller books reconcile with the reported footprint"
        );
        assert_eq!(
            m.resident_weight_bytes, m.resident_weight_bytes_per_replica,
            "single replica: fleet total equals the per-replica figure"
        );
        // final residency: block 0 back at Q8, block 1 parked at Q3
        assert_eq!(m.block_residency[Precision::Q8.tag() as usize], 1);
        assert_eq!(m.block_residency[Precision::Q3.tag() as usize], 1);
        assert_eq!(m.block_residency.iter().sum::<usize>(), 2, "every block accounted");
        assert!(m.summary().contains("requant 3 swaps"));
    }

    #[test]
    fn forward_workers_do_not_change_responses() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let run = |forward_workers: usize| -> Vec<i32> {
            let cfg = ServeConfig {
                max_batch: 4,
                max_wait_us: 500,
                workers: 2,
                forward_workers,
                ..Default::default()
            };
            let coord =
                Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0).unwrap();
            let rxs: Vec<_> = (0..10)
                .map(|i| coord.submit(vec![i % 64, (i * 5 + 1) % 64]))
                .collect();
            let toks =
                rxs.into_iter().map(|rx| coord.recv_or_dump(&rx, RECV_T).next_token).collect();
            coord.shutdown();
            toks
        };
        let serial = run(1);
        assert_eq!(serial, run(4), "intra-forward parallelism is response-invariant");
        assert_eq!(
            serial,
            run(ParallelConfig::test_workers(3)),
            "invariant at the CI matrix worker count too"
        );
    }

    #[test]
    fn responses_are_invariant_to_worker_count_and_policy() {
        // the acceptance invariant: identical per-request responses whether
        // one worker or many serve the trace, under every dispatch policy
        let model = tiny_model();
        let (serial, _) = collect_tokens(&model, 1, 16);
        for policy in ALL_POLICIES {
            for workers in [1usize, 2, 7, ParallelConfig::test_workers(4)] {
                let (toks, m) = collect_tokens_with(&model, workers, 16, policy);
                assert_eq!(
                    serial,
                    toks,
                    "workers={workers} policy={}",
                    policy.label()
                );
                assert_eq!(m.completed, 16);
            }
        }
    }

    #[test]
    fn invalid_tokens_get_sentinel_and_shard_survives() {
        // exercised under every policy so the event-driven loop (parking,
        // stealing) sees rejects too — the work-steal coverage the rescue
        // protocol requires
        for policy in ALL_POLICIES {
            let model = tiny_model();
            let plan =
                QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
            let cfg = ServeConfig {
                max_batch: 4,
                max_wait_us: 500,
                workers: 2,
                dispatch: policy,
                ..Default::default()
            };
            let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
            let bad_high = coord.submit(vec![1, 9999, 2]); // out of vocab
            let bad_neg = coord.submit(vec![-7]);
            let good = coord.submit(vec![1, 2, 3]);
            let r = coord.recv_or_dump(&bad_high, RECV_T);
            assert_eq!(r.next_token, INVALID_TOKEN, "policy={}", policy.label());
            assert_eq!(r.status, Status::InvalidContext, "typed, not just the sentinel");
            let r = coord.recv_or_dump(&bad_neg, RECV_T);
            assert_eq!(r.next_token, INVALID_TOKEN);
            assert_eq!(r.status, Status::InvalidContext);
            // the shards must still execute valid work afterwards
            let resp = coord.recv_or_dump(&good, RECV_T);
            assert!((0..64).contains(&resp.next_token));
            assert_eq!(resp.status, Status::Ok);
            // bad token BEYOND the seq_len truncation point: executed normally
            let mut long_ctx = vec![3i32; 8];
            long_ctx.extend([9999, 9999]);
            let truncated = coord.submit(long_ctx);
            assert!((0..64).contains(&coord.recv_or_dump(&truncated, RECV_T).next_token));
            let late = coord.submit(vec![4, 5]);
            assert!((0..64).contains(&coord.recv_or_dump(&late, RECV_T).next_token));
            let m = coord.shutdown();
            assert_eq!(m.completed, 5, "policy={}", policy.label());
            assert_eq!(m.rejected, 2);
            // rejects are excluded from the latency/batch aggregates
            assert_eq!(m.latencies_us.len(), 3);
            // per-status bookkeeping: every request got exactly one status
            assert_eq!(m.statuses[Status::Ok.index()], 3, "policy={}", policy.label());
            assert_eq!(m.statuses[Status::InvalidContext.index()], 2);
            assert_eq!(m.statuses.iter().sum::<usize>(), m.completed);
        }
    }

    #[test]
    fn poisoned_shard_dies_and_peers_answer_every_other_request_once() {
        // "a stolen window from a shard that dies mid-flight must be
        // re-dispatched exactly once": the poisoned window kills whichever
        // shard picks it up; every window stranded on the dead shard's
        // queue is rescued by the survivor, and no request is ever answered
        // twice. (The queue-level exactly-once property is unit-tested in
        // `queues::tests`; this exercises it end-to-end.)
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait_us: 200,
            workers: 2,
            dispatch: DispatchPolicy::WorkSteal,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let poisoned = coord.submit(vec![POISON_CONTEXT]);
        let mut rxs = Vec::new();
        for i in 0..10 {
            rxs.push(coord.submit(vec![(i % 64) as i32, 1, 2]));
        }
        // the poisoned window dies with its shard: closed channel, no answer
        assert!(
            poisoned.recv_timeout(Duration::from_secs(120)).is_err(),
            "poisoned request must never be answered"
        );
        // every other request is answered exactly once — dispatched to the
        // live shard directly or rescued off the dead one's queue
        for (i, rx) in rxs.into_iter().enumerate() {
            let responses: Vec<Response> = rx.iter().collect();
            assert_eq!(responses.len(), 1, "request {i} answered exactly once");
            assert!((0..64).contains(&responses[0].next_token), "request {i}");
        }
        let m = coord.shutdown();
        // only the survivor reports; the dead shard's metrics die with it
        assert!(m.shards.len() < 2, "dead shard must not report occupancy");
        assert!(m.completed <= 10);
    }

    /// Submit `n_req` generation requests of `n_tok` tokens each and
    /// collect the full response streams (the channel closes after the
    /// terminal token, so `iter()` drains exactly one stream).
    fn collect_streams(
        model: &ModelDir,
        workers: usize,
        dispatch: DispatchPolicy,
        kv: crate::quant::Precision,
        n_req: usize,
        n_tok: usize,
    ) -> (Vec<Vec<i32>>, ServingMetrics) {
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 500,
            workers,
            dispatch,
            kv_precision: kv,
            ..Default::default()
        };
        let coord =
            Coordinator::start_with_model(model.clone(), plan, cfg, 0, 0).unwrap();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| {
                coord.submit_gen(
                    vec![(i % 64) as i32, ((i * 11 + 3) % 64) as i32],
                    n_tok,
                )
            })
            .collect();
        let streams: Vec<Vec<i32>> = rxs
            .into_iter()
            .map(|rx| rx.iter().map(|r| r.next_token).collect())
            .collect();
        (streams, coord.shutdown())
    }

    #[test]
    fn generated_streams_are_invariant_across_workers_and_policies() {
        // the generation acceptance invariant: a served generation request
        // returns the identical token stream whether 1, 2 or 7 shard
        // workers serve it, under every dispatch policy — sequences are
        // pinned, decode is deterministic, and Raw KV is bit-identical to
        // recompute, so scheduling must be unobservable in the stream
        let model = tiny_model();
        let (baseline, m) =
            collect_streams(&model, 1, DispatchPolicy::WorkSteal, Precision::Raw, 6, 4);
        assert_eq!(baseline.len(), 6);
        for st in &baseline {
            assert_eq!(st.len(), 4, "2-token context + 4 generated fits the window");
            assert!(st.iter().all(|&t| (0..64).contains(&t)), "{st:?}");
        }
        assert!(m.decode_steps > 0, "generation must run through decode_step");
        assert!(m.kv_bytes > 0, "kv pages must have been resident");
        assert_eq!(m.completed, 6);
        for policy in ALL_POLICIES {
            for workers in [1usize, 2, 7, ParallelConfig::test_workers(3)] {
                let (streams, m) =
                    collect_streams(&model, workers, policy, Precision::Raw, 6, 4);
                assert_eq!(
                    baseline,
                    streams,
                    "workers={workers} policy={}",
                    policy.label()
                );
                assert_eq!(m.completed, 6);
                assert_eq!(m.rejected, 0);
            }
        }
    }

    #[test]
    fn batched_decode_matches_the_per_sequence_oracle_and_reports_occupancy() {
        // the serving-level continuous-batching acceptance: token streams
        // are identical with max_decode_batch 1 (the per-sequence GEMV
        // oracle) and 16 (the fused batched path), and the metrics surface
        // the fused steps and their mean occupancy
        let model = tiny_model();
        let streams_with = |max_db: usize| {
            let plan =
                QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
            let cfg = ServeConfig {
                max_batch: 8,
                max_wait_us: 50_000,
                workers: 1,
                max_decode_batch: max_db,
                ..Default::default()
            };
            let coord = Coordinator::start_with_model(model.clone(), plan, cfg, 0, 0).unwrap();
            let rxs: Vec<_> = (0..6)
                .map(|i| coord.submit_gen(vec![(i % 64) as i32, ((i * 7 + 2) % 64) as i32], 5))
                .collect();
            let streams: Vec<Vec<i32>> =
                rxs.into_iter().map(|rx| rx.iter().map(|r| r.next_token).collect()).collect();
            (streams, coord.shutdown())
        };
        let (oracle, m1) = streams_with(1);
        assert_eq!(m1.batched_steps, 0, "max_decode_batch 1 keeps the per-sequence path");
        assert_eq!(m1.decode_batch_rows, 0);
        assert_eq!(m1.decode_batch_occupancy(), 0.0);
        for st in &oracle {
            assert_eq!(st.len(), 5);
            assert!(st.iter().all(|&t| (0..64).contains(&t)));
        }
        let (batched, mb) = streams_with(16);
        assert_eq!(oracle, batched, "fused batched decode must not move a single token");
        assert!(mb.batched_steps > 0, "the fused path must actually have run");
        assert_eq!(mb.decode_steps, m1.decode_steps, "same decode volume, different gather");
        assert!(mb.decode_batch_rows >= mb.batched_steps);
        assert!(mb.decode_batch_occupancy() >= 1.0);
        assert!(mb.summary().contains("batched"), "occupancy shows up in the summary line");
        assert_eq!(mb.completed, 6);
        assert_eq!(mb.rejected, 0);
    }

    #[test]
    fn quantized_kv_streams_are_deterministic_and_valid() {
        let model = tiny_model();
        for kv in [Precision::Q8, Precision::Q4] {
            let (a, m) = collect_streams(&model, 1, DispatchPolicy::WorkSteal, kv, 4, 3);
            let (b, _) = collect_streams(&model, 2, DispatchPolicy::ShortestQueue, kv, 4, 3);
            assert_eq!(a, b, "quantized-kv decode is still deterministic ({})", kv.label());
            for st in &a {
                assert_eq!(st.len(), 3);
                assert!(st.iter().all(|&t| (0..64).contains(&t)));
            }
            assert!(m.kv_bytes > 0);
        }
        // unsupported kv codecs are rejected at startup, not mid-flight
        let plan = QuantPlan::uniform("tiny-serve", 2, Precision::Q8);
        let cfg = ServeConfig { kv_precision: Precision::T2, ..Default::default() };
        assert!(Coordinator::start_with_model(tiny_model(), plan, cfg, 0, 0).is_err());
    }

    #[test]
    fn generation_and_classic_requests_interleave_on_the_same_shards() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 300, workers: 2, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let gen_rxs: Vec<_> = (0..4).map(|i| coord.submit_gen(vec![i % 64, 5], 5)).collect();
        let classic_rxs: Vec<_> = (0..8).map(|i| coord.submit(vec![i % 64, 2, 3])).collect();
        for (i, rx) in classic_rxs.into_iter().enumerate() {
            let resps: Vec<Response> = rx.iter().collect();
            assert_eq!(resps.len(), 1, "classic request {i} answers exactly once");
            assert!((0..64).contains(&resps[0].next_token));
        }
        for (i, rx) in gen_rxs.into_iter().enumerate() {
            let toks: Vec<i32> = rx.iter().map(|r| r.next_token).collect();
            assert_eq!(toks.len(), 5, "gen request {i} streams 5 tokens: {toks:?}");
            assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 12);
        assert!(m.batches >= 1, "classic windows executed as batched prefill");
        // 4 sequences x (2 ingest + 4 extra) decode steps
        assert_eq!(m.decode_steps, 4 * 6);
    }

    #[test]
    fn pinned_serving_streams_match_unpinned_bitwise() {
        // `--pin on` is a pure locality knob: shard threads and their
        // forward pools land on disjoint cores (best-effort), and every
        // response stream must be identical to the unpinned run — the
        // kernels are bit-stable wherever the threads execute
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let run = |pin: bool| {
            let cfg = ServeConfig {
                max_batch: 2,
                max_wait_us: 300,
                workers: 2,
                forward_workers: 2,
                pin_workers: pin,
                ..Default::default()
            };
            let coord =
                Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0).unwrap();
            let rxs: Vec<_> =
                (0..4).map(|i| coord.submit_gen(vec![(1 + i) % 64, 5], 4)).collect();
            let streams: Vec<Vec<i32>> =
                rxs.into_iter().map(|rx| rx.iter().map(|r| r.next_token).collect()).collect();
            coord.shutdown();
            streams
        };
        let unpinned = run(false);
        let pinned = run(true);
        assert_eq!(unpinned, pinned, "pinning must never move a bit");
        assert!(unpinned.iter().all(|s| s.len() == 4));
    }

    #[test]
    fn generation_respects_the_context_window() {
        let model = tiny_model(); // seq_len 8
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 2, max_wait_us: 300, workers: 1, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // a full-window context leaves room for exactly one generated token
        let full = coord.submit_gen((0..8).collect(), 5);
        // an over-long context is truncated to the window first
        let long = coord.submit_gen((0..12).collect(), 5);
        // 6 context tokens leave room for 3 of the 5 requested tokens
        let partial = coord.submit_gen((0..6).collect(), 5);
        // an absurd token budget must not overflow the reservation math:
        // the stream is simply capped by the window (7 tokens after a
        // 2-token context), never failed or panicked
        let huge = coord.submit_gen(vec![1, 2], usize::MAX);
        assert_eq!(full.iter().count(), 1);
        assert_eq!(long.iter().count(), 1);
        assert_eq!(partial.iter().count(), 3);
        let huge_toks: Vec<i32> = huge.iter().map(|r| r.next_token).collect();
        assert_eq!(huge_toks.len(), 7, "window-capped: {huge_toks:?}");
        assert!(huge_toks.iter().all(|&t| t != INVALID_TOKEN));
        let m = coord.shutdown();
        assert_eq!(m.completed, 4);
        assert_eq!(m.rejected, 0, "window-limited streams are completions, not failures");
    }

    #[test]
    fn invalid_generation_requests_fail_with_one_terminal_sentinel() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 2, max_wait_us: 300, workers: 2, ..Default::default() };
        let coord = Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0).unwrap();
        let empty = coord.submit_gen(vec![], 4);
        let bad = coord.submit_gen(vec![1, 9999], 4);
        let good = coord.submit_gen(vec![1, 2], 4);
        for (name, rx) in [("empty", empty), ("out-of-vocab", bad)] {
            let resps: Vec<Response> = rx.iter().collect();
            assert_eq!(resps.len(), 1, "{name}: exactly one terminal response");
            assert_eq!(resps[0].next_token, INVALID_TOKEN, "{name}");
            assert_eq!(resps[0].status, Status::InvalidContext, "{name}");
        }
        assert_eq!(good.iter().count(), 4, "valid generation unaffected");
        let m = coord.shutdown();
        assert_eq!(m.completed, 3);
        assert_eq!(m.rejected, 2);
        assert_eq!(m.statuses[Status::InvalidContext.index()], 2);
        // a kv budget too small for even one page fails generations cleanly
        // (and classic requests, which never touch the cache, still work);
        // the budget must be positive to pass startup validation, so use one
        // that cannot fit a single page rather than zero
        let cfg = ServeConfig { kv_budget_mb: 1e-6, max_wait_us: 300, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let starved = coord.submit_gen(vec![1, 2], 4);
        let resps: Vec<Response> = starved.iter().collect();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].next_token, INVALID_TOKEN);
        assert_eq!(resps[0].status, Status::KvExhausted, "budget refusal is typed");
        let classic = coord.submit(vec![1, 2, 3]);
        let answered = coord.recv_or_dump(&classic, RECV_T).next_token;
        assert!((0..64).contains(&answered));
        let m = coord.shutdown();
        assert_eq!(m.kv_bytes, 0, "nothing was ever resident in the starved cache");
        assert_eq!(m.statuses[Status::KvExhausted.index()], 1);
    }

    /// A single-shard fleet stalled by chaos injection, flooded past its
    /// `max_queued_windows` cap: excess windows are shed at enqueue with one
    /// terminal `Status::Busy` each, and the queue high-water mark proves
    /// depth never exceeded the cap.
    #[test]
    fn admission_cap_sheds_with_typed_busy() {
        let model = tiny_model();
        let plan = QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1, // every request is its own window
            max_wait_us: 100,
            workers: 1,
            max_queued_windows: 2,
            chaos: Some(faultfx::ChaosSchedule {
                shards: vec![faultfx::ShardFaults {
                    die_before_item: None,
                    stall_us: 400_000, // 400ms per work item: the flood outruns the drain
                    deny_kv_from: None,
                }],
            }),
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let rxs: Vec<_> = (0..10).map(|i| coord.submit(vec![(i % 64) as i32, 2])).collect();
        let mut ok = 0usize;
        let mut busy = 0usize;
        for rx in rxs {
            let r = coord.recv_or_dump(&rx, RECV_T);
            match r.status {
                Status::Ok => {
                    assert!((0..64).contains(&r.next_token));
                    ok += 1;
                }
                Status::Busy => {
                    assert_eq!(r.next_token, INVALID_TOKEN, "shed answers carry the sentinel");
                    busy += 1;
                }
                other => panic!("unexpected terminal status {other:?}"),
            }
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 10, "every request resolved exactly once");
        assert_eq!(ok + busy, 10);
        assert!(busy >= 6, "flood past a stalled cap-2 queue must shed most windows (shed {busy})");
        assert_eq!(m.shed(), busy);
        assert!(
            m.queue_depth_hwm <= 2,
            "bounded admission: high-water mark {} exceeds the cap",
            m.queue_depth_hwm
        );
        assert_eq!(m.statuses.iter().sum::<usize>(), m.completed);
        assert_eq!(m.latencies_us.len(), ok, "shed requests stay out of the percentiles");
        assert!(m.summary().contains("shed "));
    }

    /// Requests whose deadline lapses while queued behind a chaos-stalled
    /// shard are dropped at dequeue with one terminal `Status::Expired` —
    /// both with an explicit `submit_with_deadline` and with the
    /// `default_deadline_ms` config path.
    #[test]
    fn deadline_expires_queued_request_with_typed_expired() {
        let model = tiny_model();
        let plan = QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let stalled = |default_deadline_ms| ServeConfig {
            max_batch: 1,
            max_wait_us: 100,
            workers: 1,
            default_deadline_ms,
            chaos: Some(faultfx::ChaosSchedule {
                shards: vec![faultfx::ShardFaults {
                    die_before_item: None,
                    stall_us: 300_000,
                    deny_kv_from: None,
                }],
            }),
            ..Default::default()
        };
        // explicit per-request deadline
        let coord =
            Coordinator::start_with_model(model.clone(), plan.clone(), stalled(0), 0, 0).unwrap();
        let doomed = coord.submit_with_deadline(vec![1, 2, 3], 1, Duration::from_millis(1));
        let patient = coord.submit(vec![4, 5]); // no deadline: rides out the stall
        let resps: Vec<Response> = doomed.iter().collect();
        assert_eq!(resps.len(), 1, "exactly one terminal response");
        assert_eq!(resps[0].status, Status::Expired);
        assert_eq!(resps[0].next_token, INVALID_TOKEN);
        assert_eq!(coord.recv_or_dump(&patient, RECV_T).status, Status::Ok);
        let m = coord.shutdown();
        assert_eq!(m.expired(), 1);
        assert_eq!(m.statuses.iter().sum::<usize>(), m.completed);
        assert!(m.summary().contains("expired 1"));
        // the configured default applies to plain submits
        let coord = Coordinator::start_with_model(model, plan, stalled(1), 0, 0).unwrap();
        let resps: Vec<Response> = coord.submit(vec![1, 2]).iter().collect();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].status, Status::Expired);
        let m = coord.shutdown();
        assert_eq!(m.expired(), 1);
    }

    /// A generation whose deadline lapses mid-stream retires at the next
    /// decode-step boundary: tokens already streamed stay valid, the stream
    /// ends with exactly one `Status::Expired`, and the sequence's KV pages
    /// are released.
    #[test]
    fn deadline_expires_mid_generation_at_a_step_boundary() {
        let model = tiny_model();
        let plan = QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1,
            max_wait_us: 100,
            workers: 1,
            chaos: Some(faultfx::ChaosSchedule {
                shards: vec![faultfx::ShardFaults {
                    die_before_item: None,
                    // one stall fits inside the deadline, two do not: the
                    // prefill admits the sequence, the decode step expires it
                    stall_us: 300_000,
                    deny_kv_from: None,
                }],
            }),
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let rx = coord.submit_with_deadline(vec![1, 2], 8, Duration::from_millis(450));
        let resps: Vec<Response> = rx.iter().collect();
        assert!(!resps.is_empty(), "the stream must still terminate");
        let (last, streamed) = resps.split_last().unwrap();
        assert_eq!(last.status, Status::Expired, "stream ends with one terminal Expired");
        assert_eq!(last.next_token, INVALID_TOKEN);
        for r in streamed {
            assert_eq!(r.status, Status::Ok, "already-streamed tokens stay valid");
            assert!((0..64).contains(&r.next_token));
        }
        let m = coord.shutdown();
        assert_eq!(m.expired(), 1);
        assert_eq!(m.statuses.iter().sum::<usize>(), m.completed);
    }

    /// `max_live_sequences` caps concurrent decode streams per shard:
    /// admission beyond the cap degrades to a terminal `Status::Busy` at
    /// prefill time instead of failing mid-stream.
    #[test]
    fn live_sequence_cap_degrades_admission_to_busy() {
        let model = tiny_model();
        let plan = QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 50_000, // all three generations land in ONE window
            workers: 1,
            max_live_sequences: 1,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let rxs: Vec<_> = (0..3).map(|i| coord.submit_gen(vec![1 + i, 2], 4)).collect();
        let mut ok_streams = 0usize;
        let mut busy = 0usize;
        for rx in rxs {
            let resps: Vec<Response> = rx.iter().collect();
            if resps[0].status == Status::Busy {
                assert_eq!(resps.len(), 1, "shed streams get exactly one terminal response");
                assert_eq!(resps[0].next_token, INVALID_TOKEN);
                busy += 1;
            } else {
                assert_eq!(resps.len(), 4, "the admitted stream generates to completion");
                assert!(resps.iter().all(|r| r.status == Status::Ok));
                ok_streams += 1;
            }
        }
        assert_eq!(ok_streams, 1, "exactly one sequence fits under the cap");
        assert_eq!(busy, 2);
        let m = coord.shutdown();
        assert_eq!(m.shed(), 2);
        assert_eq!(m.statuses.iter().sum::<usize>(), m.completed);
    }

    #[test]
    fn poisoned_shard_mid_generation_fails_stranded_streams_exactly_once() {
        // the decode extension of the poison-pill test, under EVERY policy:
        // generation sequences in flight on the dying shard are either
        // completed by it before death, or rescued off its queue and failed
        // with exactly one terminal INVALID_TOKEN — never answered twice,
        // never left hanging on an open channel
        for policy in ALL_POLICIES {
            let model = tiny_model();
            let plan =
                QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
            let cfg = ServeConfig {
                max_batch: 1,
                max_wait_us: 200,
                workers: 2,
                dispatch: policy,
                ..Default::default()
            };
            let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
            // generations first so decode jobs are live when the poison lands
            let gen_rxs: Vec<_> =
                (0..8).map(|i| coord.submit_gen(vec![(i % 64) as i32, 3], 4)).collect();
            let poisoned = coord.submit(vec![POISON_CONTEXT]);
            let late: Vec<_> = (0..4).map(|i| coord.submit(vec![(i % 64) as i32, 1])).collect();
            assert!(
                poisoned.recv_timeout(Duration::from_secs(120)).is_err(),
                "poisoned request must never be answered (policy={})",
                policy.label()
            );
            for (i, rx) in gen_rxs.into_iter().enumerate() {
                let toks: Vec<i32> = rx.iter().map(|r| r.next_token).collect();
                assert!(
                    !toks.is_empty() && toks.len() <= 4,
                    "gen {i} stream bounds (policy={}): {toks:?}",
                    policy.label()
                );
                let invalids = toks.iter().filter(|&&t| t == INVALID_TOKEN).count();
                assert!(invalids <= 1, "gen {i}: at most one failure marker: {toks:?}");
                if invalids == 1 {
                    assert_eq!(
                        *toks.last().unwrap(),
                        INVALID_TOKEN,
                        "gen {i}: the failure marker is terminal: {toks:?}"
                    );
                }
                for &t in &toks[..toks.len() - invalids] {
                    assert!((0..64).contains(&t), "gen {i}: valid tokens before the end");
                }
                // a stream the shard finished before dying is complete
                if invalids == 0 {
                    assert_eq!(toks.len(), 4, "gen {i}: completed streams are full: {toks:?}");
                }
            }
            // classic requests submitted after the poison still get answered
            // exactly once (directly or via rescue)
            for (i, rx) in late.into_iter().enumerate() {
                let resps: Vec<Response> = rx.iter().collect();
                assert_eq!(resps.len(), 1, "late {i} answered exactly once");
                assert!((0..64).contains(&resps[0].next_token));
            }
            let m = coord.shutdown();
            assert!(m.shards.len() < 2, "dead shard must not report occupancy");
        }
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let Some(path) = model_path() else { return };
        let plan = QuantPlan::uniform("tl-phi", 8, Precision::Q8);
        let cfg =
            ServeConfig { max_batch: 8, max_wait_us: 3_000, workers: 2, ..Default::default() };
        let coord = Coordinator::start(path, plan, cfg, 1, 200).unwrap();

        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(coord.submit(vec![1, 160 + (i % 16), 100 + (i % 57), 2]));
        }
        for rx in rxs {
            let resp = coord.recv_or_dump(&rx, RECV_T);
            assert!((0..512).contains(&resp.next_token));
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert_eq!(resp.network_latency_us, 200);
            assert!(resp.shard < 2);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 20);
        assert!(m.batches <= 20);
        assert!(m.max_batch_observed <= 8);
        assert!(m.throughput_rps() > 0.0);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.99));
    }

    #[test]
    fn shutdown_without_requests_is_clean() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny-serve", 2, Precision::Raw);
        let coord = Coordinator::start_with_model(
            model,
            plan,
            ServeConfig { workers: 2, ..Default::default() },
            0,
            0,
        )
        .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.completed, 0);
        assert_eq!(m.virtual_network_us, 0);
        assert_eq!(m.shards.len(), 2);
        assert!(m.shards.iter().all(|s| s.completed == 0 && s.busy_us == 0));
        assert_eq!(m.steals, 0);
    }

    #[test]
    fn metrics_percentiles_ordered() {
        let m = ServingMetrics {
            completed: 5,
            rejected: 0,
            batches: 2,
            latencies_us: vec![10, 50, 20, 90, 30],
            wall_time: Duration::from_millis(10),
            max_batch_observed: 3,
            virtual_network_us: 0,
            resident_weight_bytes: 0,
            steals: 0,
            wakes: 0,
            decode_steps: 0,
            batched_steps: 0,
            decode_batch_rows: 0,
            kv_bytes: 0,
            statuses: [5, 0, 0, 0, 0, 0],
            queue_depth_hwm: 0,
            shards: Vec::new(),
            ..Default::default()
        };
        assert_eq!(m.percentile_us(0.0), 10);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.95));
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_nearest_rank_on_small_samples() {
        // the old (len*p) truncation read p50 of [1,2] as index 1
        let m = |lats: Vec<u64>| ServingMetrics { latencies_us: lats, ..Default::default() };
        let two = m(vec![2, 1]);
        assert_eq!(two.percentile_us(0.5), 1, "p50 of [1,2] is the first sample");
        assert_eq!(two.percentile_us(0.51), 2);
        assert_eq!(two.percentile_us(1.0), 2);
        let three = m(vec![3, 1, 2]);
        assert_eq!(three.percentile_us(0.5), 2);
        assert_eq!(three.percentile_us(0.0), 1);
        let hundred = m((1..=100).collect());
        assert_eq!(hundred.percentile_us(0.99), 99, "p99 of 1..=100 is 99, not 100");
        assert_eq!(hundred.percentile_us(0.50), 50);
        assert_eq!(hundred.percentile_us(1.0), 100);
        let one = m(vec![42]);
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile_us(p), 42);
        }
        assert_eq!(m(vec![]).percentile_us(0.5), 0);
    }

    #[test]
    fn merge_aggregates_shards() {
        let mut a = ServingMetrics {
            completed: 3,
            rejected: 1,
            batches: 2,
            latencies_us: vec![10, 20, 30],
            wall_time: Duration::from_millis(5),
            max_batch_observed: 2,
            virtual_network_us: 100,
            resident_weight_bytes: 1000,
            steals: 2,
            wakes: 5,
            decode_steps: 3,
            batched_steps: 2,
            decode_batch_rows: 5,
            kv_bytes: 100,
            statuses: [2, 1, 0, 0, 0, 0],
            queue_depth_hwm: 3,
            shards: vec![ShardOccupancy {
                shard: 1,
                completed: 3,
                batches: 2,
                busy_us: 4000,
                steals: 2,
                wakes: 5,
            }],
            prefix_hits: 1,
            prefix_tokens_reused: 16,
            kv_shared_bytes: 256,
            kv_leaked_seqs: 0,
            resident_weight_bytes_per_replica: 1000,
            requant_swaps: 2,
            requant_bytes_freed: 300,
            requant_bytes_regrown: 100,
            block_residency: [0, 1, 1, 0, 0],
            ..Default::default()
        };
        let b = ServingMetrics {
            completed: 2,
            rejected: 0,
            batches: 1,
            latencies_us: vec![40, 50],
            wall_time: Duration::from_millis(9),
            max_batch_observed: 3,
            virtual_network_us: 50,
            resident_weight_bytes: 1000,
            steals: 1,
            wakes: 3,
            decode_steps: 2,
            batched_steps: 1,
            decode_batch_rows: 2,
            kv_bytes: 50,
            statuses: [2, 0, 0, 0, 0, 0],
            queue_depth_hwm: 5,
            shards: vec![ShardOccupancy {
                shard: 0,
                completed: 2,
                batches: 1,
                busy_us: 1000,
                steals: 1,
                wakes: 3,
            }],
            prefix_hits: 2,
            prefix_tokens_reused: 32,
            kv_shared_bytes: 512,
            kv_leaked_seqs: 0,
            resident_weight_bytes_per_replica: 800,
            requant_swaps: 1,
            requant_bytes_freed: 50,
            requant_bytes_regrown: 0,
            block_residency: [0, 1, 0, 1, 0],
            ..Default::default()
        };
        a.merge(b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.wall_time, Duration::from_millis(9));
        assert_eq!(a.max_batch_observed, 3);
        assert_eq!(a.virtual_network_us, 150);
        assert_eq!(a.resident_weight_bytes, 2000, "replica footprints sum across shards");
        assert_eq!(a.steals, 3, "steal counts sum across shards");
        assert_eq!(a.wakes, 8, "park/wake transitions sum across shards");
        assert_eq!(a.decode_steps, 5, "decode step counts sum across shards");
        assert_eq!(a.batched_steps, 3, "fused step counts sum across shards");
        assert_eq!(a.decode_batch_rows, 7, "batched row counts sum across shards");
        assert!((a.decode_batch_occupancy() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.kv_bytes, 150, "kv peaks sum across shards");
        assert_eq!(a.statuses, [4, 1, 0, 0, 0, 0], "per-status counters sum element-wise");
        assert_eq!(a.shed(), 1);
        assert_eq!(a.expired(), 0);
        assert_eq!(a.queue_depth_hwm, 5, "queue high-water mark merges as max");
        assert_eq!(a.prefix_hits, 3, "prefix hit counts sum across shards");
        assert_eq!(a.prefix_tokens_reused, 48, "reused-token counts sum across shards");
        assert_eq!(a.kv_shared_bytes, 768, "shared-page byte counts sum across shards");
        assert_eq!(a.kv_leaked_seqs, 0);
        // fleet total sums; the per-replica figure is a representative
        // footprint, so it merges as max, never a sum
        assert_eq!(a.resident_weight_bytes_per_replica, 1000);
        assert_eq!(a.requant_swaps, 3, "swap counts sum across shards");
        assert_eq!(a.requant_bytes_freed, 350);
        assert_eq!(a.requant_bytes_regrown, 100);
        assert_eq!(a.block_residency, [0, 2, 1, 1, 0], "residency merges element-wise");
        assert!(a.summary().contains("requant 3 swaps"));
        assert!(a.summary().contains("prefix hits 3"));
        assert!(a.summary().contains("shed 1"));
        assert!(a.summary().contains("q-hwm 5"));
        assert!(!a.summary().contains("expired"), "zero counters stay out of the summary");
        assert!(a.summary().contains("decode 5 steps"));
        assert!(a.summary().contains("batched 3 steps"));
        assert_eq!(a.latencies_us.len(), 5);
        // shards sorted by id after merge
        assert_eq!(a.shards.iter().map(|s| s.shard).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(a.percentile_us(1.0), 50);
        let occ = a.shards[1].occupancy(a.wall_time);
        assert!((occ - 4000.0 / 9000.0).abs() < 1e-9);
        assert!(a.summary().contains("steals 3"));
    }
}
