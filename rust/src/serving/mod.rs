//! Serving coordinator — the L3 request path, sharded across N workers.
//!
//! Topology: the front end submits requests over a channel to a **batcher**
//! thread; a dynamic batching window groups up to `max_batch` requests or
//! waits at most `max_wait`, then dispatches the whole batch to one of
//! `ServeConfig::workers` **shard workers** over per-shard queues — by
//! default to the **shortest queue** (fewest queued + in-flight batches,
//! tracked by per-shard depth counters), which balances skewed batch costs;
//! `DispatchPolicy::RoundRobin` keeps the original blind rotation. Each
//! shard owns a full model replica (its own `Runtime` — the PJRT client is
//! not `Send`, so it is created inside the shard thread — plus its own
//! `QuantizedModel`, resident at **packed** size: the native executor
//! serves straight from the `QMat` payloads through the fused kernels) and
//! answers every request in the batch.
//!
//! Responses are batching- and shard-invariant: attention never mixes batch
//! rows, padding rows are zeros, and every replica is built from the same
//! plan — so a request's `next_token` is identical whether it is served by
//! 1 worker or N. Shard-level `ShardOccupancy` is folded into the aggregate
//! metrics via `ServingMetrics::merge` at shutdown.
//!
//! Cross-machine block placement (from `cluster::Distribution`) is simulated:
//! each batch is charged `hops × link_latency` of virtual network time,
//! reported separately from wall-clock latency.

pub mod kvcache;
pub mod trace;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{DispatchPolicy, ServeConfig};
use crate::ewq::QuantPlan;
use crate::model::{ModelExecutor, QuantizedModel};
use crate::par::Pool;
use crate::runtime::Runtime;
use crate::zoo::ModelDir;

/// One generation request: a token context, answered with the next token.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub context: Vec<i32>,
    submitted: Instant,
    resp: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// wall-clock queue+compute latency
    pub latency: Duration,
    /// simulated cross-machine network time for the batch
    pub network_latency_us: u64,
    pub batch_size: usize,
    /// which shard worker executed the batch
    pub shard: usize,
}

/// Sentinel `next_token` for requests whose context contains tokens outside
/// the model vocabulary — answered immediately, never executed.
pub const INVALID_TOKEN: i32 = -1;

enum Msg {
    Req(Request),
    Stop(Sender<ServingMetrics>),
}

enum ShardMsg {
    Batch(Vec<Request>),
    Stop(Sender<ServingMetrics>),
}

/// Per-shard execution accounting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShardOccupancy {
    pub shard: usize,
    pub completed: usize,
    pub batches: usize,
    /// time spent executing batches (excludes idle waiting)
    pub busy_us: u64,
}

impl ShardOccupancy {
    /// Fraction of the serving wall-clock this shard spent executing.
    pub fn occupancy(&self, wall: Duration) -> f64 {
        let wall_us = wall.as_micros() as f64;
        if wall_us <= 0.0 {
            return 0.0;
        }
        (self.busy_us as f64 / wall_us).min(1.0)
    }
}

/// Aggregate serving metrics (single shard, or merged across shards).
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub completed: usize,
    /// Requests answered with `INVALID_TOKEN` without executing (counted in
    /// `completed`, excluded from latency/batch aggregates).
    pub rejected: usize,
    pub batches: usize,
    pub latencies_us: Vec<u64>,
    pub wall_time: Duration,
    pub max_batch_observed: usize,
    pub virtual_network_us: u64,
    /// Resident weight bytes across all replicas (each shard reports its
    /// `QuantizedModel::resident_bytes`; `merge` sums them) — the packed
    /// footprint the memory-reduction claim is measured by.
    pub resident_weight_bytes: usize,
    /// One entry per shard worker (sorted by shard id after `merge`).
    pub shards: Vec<ShardOccupancy>,
}

impl ServingMetrics {
    /// Nearest-rank percentile: index ceil(p·n) − 1, clamped to the sample
    /// range (so p=0 is the min and p=1 the max).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = (p * v.len() as f64).ceil() as usize;
        v[rank.saturating_sub(1).min(v.len() - 1)]
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_time.as_secs_f64().max(1e-9)
    }

    /// Mean EXECUTED requests per batch (rejects never enter a batch).
    pub fn mean_batch(&self) -> f64 {
        (self.completed - self.rejected) as f64 / self.batches.max(1) as f64
    }

    /// Fold another shard's (or coordinator's) metrics into this aggregate:
    /// counters add, latencies concatenate, wall-clock takes the max, shard
    /// occupancy records append.
    pub fn merge(&mut self, other: ServingMetrics) {
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.batches += other.batches;
        self.latencies_us.extend(other.latencies_us);
        self.wall_time = self.wall_time.max(other.wall_time);
        self.max_batch_observed = self.max_batch_observed.max(other.max_batch_observed);
        self.virtual_network_us += other.virtual_network_us;
        self.resident_weight_bytes += other.resident_weight_bytes;
        self.shards.extend(other.shards);
        self.shards.sort_by_key(|s| s.shard);
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs in {:?} ({:.1} req/s), batches {} (mean {:.2}, max {}), \
             p50 {}us p95 {}us p99 {}us, virtual-net {}us",
            self.completed,
            self.wall_time,
            self.throughput_rps(),
            self.batches,
            self.mean_batch(),
            self.max_batch_observed,
            self.percentile_us(0.50),
            self.percentile_us(0.95),
            self.percentile_us(0.99),
            self.virtual_network_us,
        );
        if self.rejected > 0 {
            s.push_str(&format!(", rejected {}", self.rejected));
        }
        if self.resident_weight_bytes > 0 {
            s.push_str(&format!(
                ", resident {}",
                crate::report::bytes_human(self.resident_weight_bytes)
            ));
        }
        if self.shards.len() > 1 {
            let occ: Vec<String> = self
                .shards
                .iter()
                .map(|sh| {
                    format!(
                        "s{}:{}r/{:.0}%",
                        sh.shard,
                        sh.completed,
                        100.0 * sh.occupancy(self.wall_time)
                    )
                })
                .collect();
            s.push_str(&format!(", shards [{}]", occ.join(" ")));
        }
        s
    }
}

/// Handle to a running sharded coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Load the model from disk and start the shard workers + batcher.
    /// `network_hops` is the placement's hop count (0 = single machine);
    /// `link_latency_us` is charged per hop per batch.
    pub fn start(
        model_path: std::path::PathBuf,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let model = ModelDir::load(&model_path)?;
        Self::start_with_model(model, plan, cfg, network_hops, link_latency_us)
    }

    /// Start from an already-loaded (possibly synthetic, artifact-less)
    /// model: each of `cfg.workers` shards gets its own replica clone.
    pub fn start_with_model(
        model: ModelDir,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let n_shards = cfg.workers.max(1);
        let net_us = network_hops as u64 * link_latency_us;
        let batch_cap = cfg.max_batch.min(model.schema.eval_batch).max(1);
        let policy = cfg.dispatch;
        let fwd_workers = cfg.forward_workers.max(1);

        // per-shard queue depth (queued + in-flight batches): the batcher
        // increments on dispatch, the shard decrements when a batch is done
        let depths: Vec<Arc<AtomicUsize>> =
            (0..n_shards).map(|_| Arc::new(AtomicUsize::new(0))).collect();

        // spawn shard workers, each owning a replica
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        let mut shard_txs: Vec<Sender<ShardMsg>> = Vec::with_capacity(n_shards);
        let mut shard_handles = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (stx, srx) = channel::<ShardMsg>();
            let replica = model.clone();
            let plan = plan.clone();
            let ready = ready_tx.clone();
            let ctx = ShardCtx { shard, net_us, fwd_workers, depth: depths[shard].clone() };
            let handle = std::thread::Builder::new()
                .name(format!("ewq-shard-{shard}"))
                .spawn(move || {
                    if let Err(e) = shard_worker(ctx, replica, plan, srx, ready) {
                        eprintln!("shard {shard} failed: {e:#}");
                    }
                })
                .context("spawn shard worker")?;
            shard_txs.push(stx);
            shard_handles.push(handle);
        }
        drop(ready_tx);
        // block until every shard has loaded + compiled + warmed its replica
        // so request latencies never include one-off startup cost
        for _ in 0..n_shards {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => anyhow::bail!("shard startup failed: {msg}"),
                Err(_) => anyhow::bail!("a shard died during startup"),
            }
        }

        // batcher thread: groups requests, dispatches under `cfg.dispatch`
        let (tx, rx) = channel::<Msg>();
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let shards = Shards { txs: shard_txs, handles: shard_handles, depths, policy };
        let handle = std::thread::Builder::new()
            .name("ewq-batcher".into())
            .spawn(move || batcher(rx, shards, batch_cap, max_wait))
            .context("spawn batcher")?;
        Ok(Self { tx, handle: Some(handle), next_id: 0.into() })
    }

    /// Submit a context; returns the response receiver.
    pub fn submit(&self, context: Vec<i32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = self.tx.send(Msg::Req(Request {
            id,
            context,
            submitted: Instant::now(),
            resp: rtx,
        }));
        rrx
    }

    /// Stop batcher + shards and collect the merged metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Stop(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

/// The batcher's handle on the shard fleet: queues, join handles, depth
/// counters, and the dispatch policy.
struct Shards {
    txs: Vec<Sender<ShardMsg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    depths: Vec<Arc<AtomicUsize>>,
    policy: DispatchPolicy,
}

/// Candidate order for shortest-queue dispatch: shard indices sorted by
/// (queue depth, shard id). The head is the dispatch target; the tail is
/// the dead-shard reroute order, so a failed send falls through to the
/// next-least-loaded shard.
fn shortest_queue_order(depths: &[usize]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..depths.len()).collect();
    idx.sort_by_key(|&i| (depths[i], i));
    idx
}

/// The shared dynamic batcher: owns the request queue, closes batching
/// windows, and dispatches full batches over per-shard queues — to the
/// shortest queue by default, round-robin under the legacy policy.
fn batcher(rx: Receiver<Msg>, shards: Shards, batch_cap: usize, max_wait: Duration) {
    let started = Instant::now();
    let mut rr = 0usize;
    let mut pending: Vec<Request> = Vec::new();
    let Shards { txs: shard_txs, handles: shard_handles, depths, policy } = shards;

    let finalize = |mtx: Sender<ServingMetrics>,
                    shard_txs: Vec<Sender<ShardMsg>>,
                    shard_handles: Vec<std::thread::JoinHandle<()>>| {
        // Stop messages queue behind in-flight batches, so every shard
        // finishes its work before reporting
        let mut agg = ServingMetrics::default();
        for stx in &shard_txs {
            let (stop_tx, stop_rx) = channel();
            if stx.send(ShardMsg::Stop(stop_tx)).is_ok() {
                if let Ok(m) = stop_rx.recv() {
                    agg.merge(m);
                }
            }
        }
        agg.wall_time = started.elapsed();
        let _ = mtx.send(agg);
        drop(shard_txs);
        for h in shard_handles {
            let _ = h.join();
        }
    };

    loop {
        // blocking wait for the first request (or stop)
        if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    finalize(mtx, shard_txs, shard_handles);
                    return;
                }
                Err(_) => {
                    // front end dropped without shutdown: stop shards quietly
                    drop(shard_txs);
                    for h in shard_handles {
                        let _ = h.join();
                    }
                    return;
                }
            }
        }
        // dynamic batching window
        let window_start = Instant::now();
        let mut stop: Option<Sender<ServingMetrics>> = None;
        while pending.len() < batch_cap && window_start.elapsed() < max_wait {
            match rx.recv_timeout(max_wait.saturating_sub(window_start.elapsed())) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    stop = Some(mtx);
                    break;
                }
                Err(_) => break,
            }
        }
        // dispatch the closed window in policy order; a dead shard
        // (panicked thread) is skipped with a log line instead of silently
        // eating 1/N of the traffic forever
        let batch: Vec<Request> = pending.drain(..).collect();
        if !batch.is_empty() {
            let n_shards = shard_txs.len();
            let order: Vec<usize> = match policy {
                DispatchPolicy::RoundRobin => (0..n_shards).map(|k| (rr + k) % n_shards).collect(),
                DispatchPolicy::ShortestQueue => shortest_queue_order(
                    &depths.iter().map(|d| d.load(Ordering::SeqCst)).collect::<Vec<_>>(),
                ),
            };
            let mut msg = ShardMsg::Batch(batch);
            let mut delivered = false;
            for target in order {
                // count the batch before sending: the shard decrements when
                // done, and could otherwise race ahead of the increment
                depths[target].fetch_add(1, Ordering::SeqCst);
                match shard_txs[target].send(msg) {
                    Ok(()) => {
                        rr = target + 1;
                        delivered = true;
                        break;
                    }
                    Err(std::sync::mpsc::SendError(m)) => {
                        depths[target].fetch_sub(1, Ordering::SeqCst);
                        eprintln!("batcher: shard {target} unreachable, rerouting batch");
                        msg = m;
                    }
                }
            }
            if !delivered {
                eprintln!("batcher: all shards unreachable; dropping batch");
            }
        }
        if let Some(mtx) = stop {
            finalize(mtx, shard_txs, shard_handles);
            return;
        }
    }
}

/// Per-shard wiring passed into the worker thread.
struct ShardCtx {
    shard: usize,
    net_us: u64,
    /// pool workers inside the replica's native forward pass
    fwd_workers: usize,
    /// queue depth shared with the batcher (queued + in-flight batches)
    depth: Arc<AtomicUsize>,
}

/// One shard worker: owns a model replica and executes dispatched batches.
fn shard_worker(
    ctx: ShardCtx,
    model: ModelDir,
    plan: QuantPlan,
    rx: Receiver<ShardMsg>,
    ready: Sender<std::result::Result<(), String>>,
) -> Result<()> {
    let ShardCtx { shard, net_us, fwd_workers, depth } = ctx;
    // Runtime lives entirely inside this thread (PJRT client is not Send).
    let setup = (|| -> Result<_> {
        let rt = Runtime::cpu()?;
        let qm = QuantizedModel::build(&model, &plan)?;
        Ok((rt, qm))
    })();
    let (rt, qm) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    let ex = ModelExecutor::with_pool(&rt, &model, Pool::new(fwd_workers));
    let (b, s) = (model.schema.eval_batch, model.schema.seq_len);
    let v = model.schema.vocab;
    // the executor keeps its own schema/dir copies and the quantized replica
    // is self-contained — drop the fp32 weights instead of pinning a second
    // full-precision copy of the model per shard for the thread's lifetime.
    // (The replica itself is resident at *packed* size: the fused kernels
    // consume the QMat payloads directly, no f32 shadow copies.)
    drop(model);
    if let Err(e) = ex.warmup() {
        let _ = ready.send(Err(format!("{e:#}")));
        return Err(e);
    }
    let _ = ready.send(Ok(()));

    let mut metrics = ServingMetrics {
        resident_weight_bytes: qm.resident_bytes(),
        ..Default::default()
    };
    let mut occ = ShardOccupancy { shard, ..Default::default() };
    let started = Instant::now();

    loop {
        match rx.recv() {
            Ok(ShardMsg::Batch(batch)) => {
                execute_batch(batch, &ex, &qm, (b, s, v), (shard, net_us), &mut metrics, &mut occ);
                // done (or rejected/failed): this batch no longer occupies
                // the queue — let the batcher route new windows here
                depth.fetch_sub(1, Ordering::SeqCst);
            }
            Ok(ShardMsg::Stop(mtx)) => {
                metrics.wall_time = started.elapsed();
                metrics.shards = vec![occ];
                let _ = mtx.send(metrics);
                return Ok(());
            }
            Err(_) => return Ok(()),
        }
    }
}

/// Execute one dispatched batch on a shard's replica: reject out-of-vocab
/// contexts, pad, forward, answer. Split out of `shard_worker` so every
/// early exit still falls through to the queue-depth decrement.
fn execute_batch(
    batch: Vec<Request>,
    ex: &ModelExecutor<'_>,
    qm: &QuantizedModel,
    (b, s, v): (usize, usize, usize),
    (shard, net_us): (usize, u64),
    metrics: &mut ServingMetrics,
    occ: &mut ShardOccupancy,
) {
    let exec_start = Instant::now();
    // reject out-of-vocab contexts up front: the executor validates token
    // range, and one malformed request must never kill the shard (and with
    // it 1/N of all traffic). Only the seq_len prefix is validated — the
    // tail beyond it is truncated away and never executed.
    let (batch, rejected): (Vec<Request>, Vec<Request>) = batch.into_iter().partition(|r| {
        r.context[..r.context.len().min(s)].iter().all(|&t| t >= 0 && (t as usize) < v)
    });
    for r in rejected {
        // answered but never executed: counted separately and excluded
        // from the latency/batch aggregates
        metrics.completed += 1;
        metrics.rejected += 1;
        occ.completed += 1;
        let _ = r.resp.send(Response {
            id: r.id,
            next_token: INVALID_TOKEN,
            latency: r.submitted.elapsed(),
            network_latency_us: 0,
            batch_size: 0,
            shard,
        });
    }
    if batch.is_empty() {
        return;
    }
    // execute one padded batch
    let mut toks = vec![0i32; b * s];
    let mut pos = vec![0usize; batch.len()];
    for (row, r) in batch.iter().enumerate() {
        let ctx = &r.context[..r.context.len().min(s)];
        toks[row * s..row * s + ctx.len()].copy_from_slice(ctx);
        pos[row] = ctx.len().saturating_sub(1);
    }
    let logits = match ex.forward(qm, &toks) {
        Ok(l) => l,
        Err(e) => {
            // drop this batch's responses (callers see a closed channel)
            // but keep the shard alive for future work
            eprintln!("shard {shard}: batch of {} failed: {e:#}", batch.len());
            return;
        }
    };
    metrics.batches += 1;
    metrics.max_batch_observed = metrics.max_batch_observed.max(batch.len());
    metrics.virtual_network_us += net_us;
    for (row, r) in batch.iter().enumerate() {
        let base = (row * s + pos[row]) * v;
        // total_cmp: a NaN logit must not panic the shard thread
        let next = logits[base..base + v]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap();
        let latency = r.submitted.elapsed();
        metrics.completed += 1;
        metrics.latencies_us.push(latency.as_micros() as u64);
        let _ = r.resp.send(Response {
            id: r.id,
            next_token: next,
            latency,
            network_latency_us: net_us,
            batch_size: batch.len(),
            shard,
        });
    }
    occ.batches += 1;
    occ.completed += batch.len();
    occ.busy_us += exec_start.elapsed().as_micros() as u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;
    use crate::zoo::gen::{synthetic_model_dir, Profile, SyntheticArch};
    use crate::zoo::Schema;

    fn model_path() -> Option<std::path::PathBuf> {
        let p = crate::artifacts_dir().join("models/tl-phi");
        if p.join("weights.ets").exists() {
            Some(p)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    /// Small synthetic model: serving runs offline through the native
    /// reference executor, no artifacts needed.
    fn tiny_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "tiny-serve".into(),
                n_blocks: 2,
                d_model: 32,
                n_heads: 4,
                d_ff: 64,
                vocab: 64,
                seq_len: 8,
                eval_batch: 4,
            },
            profile: Profile::RampUp,
            seed: 91,
        })
    }

    fn collect_tokens(model: &ModelDir, workers: usize, requests: usize) -> (Vec<i32>, ServingMetrics) {
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 500, workers, ..Default::default() };
        let coord =
            Coordinator::start_with_model(model.clone(), plan, cfg, 1, 50).unwrap();
        let mut rxs = Vec::with_capacity(requests);
        for i in 0..requests {
            rxs.push(coord.submit(vec![
                (i % 64) as i32,
                ((i * 7) % 64) as i32,
                ((i * 13) % 64) as i32,
            ]));
        }
        let toks: Vec<i32> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
            .collect();
        (toks, coord.shutdown())
    }

    #[test]
    fn sharded_serving_answers_everything_offline() {
        let model = tiny_model();
        let (toks, m) = collect_tokens(&model, 3, 20);
        assert_eq!(toks.len(), 20);
        assert!(toks.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(m.completed, 20);
        assert!(m.batches >= 1);
        assert_eq!(m.shards.len(), 3, "one occupancy record per shard");
        assert_eq!(m.shards.iter().map(|s| s.completed).sum::<usize>(), 20);
        assert_eq!(m.shards.iter().map(|s| s.batches).sum::<usize>(), m.batches);
        for (i, s) in m.shards.iter().enumerate() {
            assert_eq!(s.shard, i);
            let o = s.occupancy(m.wall_time);
            assert!((0.0..=1.0).contains(&o));
        }
    }

    #[test]
    fn shortest_queue_order_is_depth_then_id() {
        assert_eq!(shortest_queue_order(&[]), Vec::<usize>::new());
        assert_eq!(shortest_queue_order(&[5]), vec![0]);
        assert_eq!(shortest_queue_order(&[2, 0, 1]), vec![1, 2, 0]);
        // ties break on shard id, so the order is total and deterministic
        assert_eq!(shortest_queue_order(&[1, 1, 0, 1]), vec![2, 0, 1, 3]);
        crate::proptest_lite::check(
            0x5105,
            100,
            16,
            |g| {
                let n = g.usize_in(1, 12);
                (0..n).map(|_| g.usize_in(0, 8)).collect::<Vec<usize>>()
            },
            |depths| {
                let order = shortest_queue_order(depths);
                let mut seen = order.clone();
                seen.sort_unstable();
                if seen != (0..depths.len()).collect::<Vec<_>>() {
                    return Err("not a permutation".into());
                }
                for w in order.windows(2) {
                    if (depths[w[0]], w[0]) > (depths[w[1]], w[1]) {
                        return Err(format!("order violated at {w:?}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Big enough that one forward takes real time (~100ms-class on a CI
    /// host): the balance test needs execution to outlast dispatch by a
    /// wide margin, so depth counters are non-zero whenever the batcher
    /// routes the next expensive window.
    fn balance_model() -> ModelDir {
        synthetic_model_dir(&SyntheticArch {
            schema: Schema {
                name: "balance".into(),
                n_blocks: 4,
                d_model: 96,
                n_heads: 4,
                d_ff: 384,
                vocab: 64,
                seq_len: 32,
                eval_batch: 8,
            },
            profile: Profile::UShape,
            seed: 1717,
        })
    }

    fn run_skewed(dispatch: crate::config::DispatchPolicy) -> ServingMetrics {
        let model = balance_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig {
            max_batch: 1, // every request is its own window
            max_wait_us: 100,
            workers: 2,
            dispatch,
            ..Default::default()
        };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        // skewed batch costs: even windows are expensive (a full forward),
        // odd windows are cheap (all-reject, answered without executing)
        let mut rxs = Vec::new();
        for i in 0..24 {
            let ctx = if i % 2 == 0 { vec![1, 2, 3] } else { vec![-1] };
            rxs.push(coord.submit(ctx));
        }
        for rx in rxs {
            let _ = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        }
        coord.shutdown()
    }

    #[test]
    fn shortest_queue_balances_skewed_batch_costs() {
        use crate::config::DispatchPolicy;
        // Round-robin alternates blindly: with alternating expensive/cheap
        // windows and two shards, every expensive window lands on shard 0 —
        // shard 1 never executes a batch.
        let rr = run_skewed(DispatchPolicy::RoundRobin);
        assert_eq!(rr.completed, 24);
        let rr_batches: Vec<usize> = rr.shards.iter().map(|s| s.batches).collect();
        assert_eq!(rr_batches.iter().sum::<usize>(), 12);
        assert_eq!(
            rr_batches.iter().filter(|&&b| b == 0).count(),
            1,
            "round-robin starves one shard of executed work: {rr_batches:?}"
        );
        // Shortest-queue routes around the busy shard: both shards execute
        // expensive windows. (All 24 requests are queued before the first
        // ~100ms forward finishes, so the starved-shard outcome would need
        // the batcher to stall ~100ms between every pair of windows — the
        // assertion is kept to >= 1 per shard so scheduler noise on loaded
        // CI hosts cannot flake it.)
        let sq = run_skewed(DispatchPolicy::ShortestQueue);
        assert_eq!(sq.completed, 24);
        let sq_batches: Vec<usize> = sq.shards.iter().map(|s| s.batches).collect();
        assert_eq!(sq_batches.iter().sum::<usize>(), 12);
        assert!(
            sq_batches.iter().all(|&b| b >= 1),
            "shortest-queue must spread executed batches: {sq_batches:?}"
        );
        let rr_min = *rr_batches.iter().min().unwrap();
        let sq_min = *sq_batches.iter().min().unwrap();
        assert!(sq_min > rr_min, "balance must improve: rr {rr_batches:?} vs sq {sq_batches:?}");
    }

    #[test]
    fn metrics_report_packed_resident_bytes_per_replica() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q4);
        let expected = QuantizedModel::build(&model, &plan).unwrap().resident_bytes();
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 500, workers: 3, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let _ = coord.submit(vec![1, 2, 3]).recv_timeout(Duration::from_secs(120)).unwrap();
        let m = coord.shutdown();
        assert_eq!(
            m.resident_weight_bytes,
            3 * expected,
            "every shard pins exactly one packed replica"
        );
        assert!(m.summary().contains("resident"));
    }

    #[test]
    fn forward_workers_do_not_change_responses() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let run = |forward_workers: usize| -> Vec<i32> {
            let cfg = ServeConfig {
                max_batch: 4,
                max_wait_us: 500,
                workers: 2,
                forward_workers,
                ..Default::default()
            };
            let coord =
                Coordinator::start_with_model(model.clone(), plan.clone(), cfg, 0, 0).unwrap();
            let rxs: Vec<_> = (0..10)
                .map(|i| coord.submit(vec![i % 64, (i * 5 + 1) % 64]))
                .collect();
            let toks = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
                .collect();
            coord.shutdown();
            toks
        };
        assert_eq!(run(1), run(4), "intra-forward parallelism is response-invariant");
    }

    #[test]
    fn responses_are_invariant_to_worker_count() {
        // the acceptance invariant: identical per-request responses whether
        // one worker or many serve the trace
        let model = tiny_model();
        let (serial, _) = collect_tokens(&model, 1, 16);
        let (sharded, _) = collect_tokens(&model, 4, 16);
        assert_eq!(serial, sharded);
    }

    #[test]
    fn invalid_tokens_get_sentinel_and_shard_survives() {
        let model = tiny_model();
        let plan =
            QuantPlan::uniform(&model.schema.name, model.schema.n_blocks, Precision::Q8);
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 500, workers: 1, ..Default::default() };
        let coord = Coordinator::start_with_model(model, plan, cfg, 0, 0).unwrap();
        let bad_high = coord.submit(vec![1, 9999, 2]); // out of vocab
        let bad_neg = coord.submit(vec![-7]);
        let good = coord.submit(vec![1, 2, 3]);
        assert_eq!(
            bad_high.recv_timeout(Duration::from_secs(120)).unwrap().next_token,
            INVALID_TOKEN
        );
        assert_eq!(
            bad_neg.recv_timeout(Duration::from_secs(120)).unwrap().next_token,
            INVALID_TOKEN
        );
        // the shard must still execute valid work afterwards
        let resp = good.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!((0..64).contains(&resp.next_token));
        // bad token BEYOND the seq_len truncation point: executed normally
        let mut long_ctx = vec![3i32; 8];
        long_ctx.extend([9999, 9999]);
        let truncated = coord.submit(long_ctx);
        assert!(
            (0..64).contains(&truncated.recv_timeout(Duration::from_secs(120)).unwrap().next_token)
        );
        let late = coord.submit(vec![4, 5]);
        assert!((0..64).contains(&late.recv_timeout(Duration::from_secs(120)).unwrap().next_token));
        let m = coord.shutdown();
        assert_eq!(m.completed, 5);
        assert_eq!(m.rejected, 2);
        // rejects are excluded from the latency/batch aggregates
        assert_eq!(m.latencies_us.len(), 3);
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let Some(path) = model_path() else { return };
        let plan = QuantPlan::uniform("tl-phi", 8, Precision::Q8);
        let cfg =
            ServeConfig { max_batch: 8, max_wait_us: 3_000, workers: 2, ..Default::default() };
        let coord = Coordinator::start(path, plan, cfg, 1, 200).unwrap();

        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(coord.submit(vec![1, 160 + (i % 16), 100 + (i % 57), 2]));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!((0..512).contains(&resp.next_token));
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert_eq!(resp.network_latency_us, 200);
            assert!(resp.shard < 2);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 20);
        assert!(m.batches <= 20);
        assert!(m.max_batch_observed <= 8);
        assert!(m.throughput_rps() > 0.0);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.99));
    }

    #[test]
    fn shutdown_without_requests_is_clean() {
        let model = tiny_model();
        let plan = QuantPlan::uniform("tiny-serve", 2, Precision::Raw);
        let coord = Coordinator::start_with_model(
            model,
            plan,
            ServeConfig { workers: 2, ..Default::default() },
            0,
            0,
        )
        .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.completed, 0);
        assert_eq!(m.virtual_network_us, 0);
        assert_eq!(m.shards.len(), 2);
        assert!(m.shards.iter().all(|s| s.completed == 0 && s.busy_us == 0));
    }

    #[test]
    fn metrics_percentiles_ordered() {
        let m = ServingMetrics {
            completed: 5,
            rejected: 0,
            batches: 2,
            latencies_us: vec![10, 50, 20, 90, 30],
            wall_time: Duration::from_millis(10),
            max_batch_observed: 3,
            virtual_network_us: 0,
            resident_weight_bytes: 0,
            shards: Vec::new(),
        };
        assert_eq!(m.percentile_us(0.0), 10);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.95));
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_nearest_rank_on_small_samples() {
        // the old (len*p) truncation read p50 of [1,2] as index 1
        let m = |lats: Vec<u64>| ServingMetrics { latencies_us: lats, ..Default::default() };
        let two = m(vec![2, 1]);
        assert_eq!(two.percentile_us(0.5), 1, "p50 of [1,2] is the first sample");
        assert_eq!(two.percentile_us(0.51), 2);
        assert_eq!(two.percentile_us(1.0), 2);
        let three = m(vec![3, 1, 2]);
        assert_eq!(three.percentile_us(0.5), 2);
        assert_eq!(three.percentile_us(0.0), 1);
        let hundred = m((1..=100).collect());
        assert_eq!(hundred.percentile_us(0.99), 99, "p99 of 1..=100 is 99, not 100");
        assert_eq!(hundred.percentile_us(0.50), 50);
        assert_eq!(hundred.percentile_us(1.0), 100);
        let one = m(vec![42]);
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.percentile_us(p), 42);
        }
        assert_eq!(m(vec![]).percentile_us(0.5), 0);
    }

    #[test]
    fn merge_aggregates_shards() {
        let mut a = ServingMetrics {
            completed: 3,
            rejected: 1,
            batches: 2,
            latencies_us: vec![10, 20, 30],
            wall_time: Duration::from_millis(5),
            max_batch_observed: 2,
            virtual_network_us: 100,
            resident_weight_bytes: 1000,
            shards: vec![ShardOccupancy { shard: 1, completed: 3, batches: 2, busy_us: 4000 }],
        };
        let b = ServingMetrics {
            completed: 2,
            rejected: 0,
            batches: 1,
            latencies_us: vec![40, 50],
            wall_time: Duration::from_millis(9),
            max_batch_observed: 3,
            virtual_network_us: 50,
            resident_weight_bytes: 1000,
            shards: vec![ShardOccupancy { shard: 0, completed: 2, batches: 1, busy_us: 1000 }],
        };
        a.merge(b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.batches, 3);
        assert_eq!(a.wall_time, Duration::from_millis(9));
        assert_eq!(a.max_batch_observed, 3);
        assert_eq!(a.virtual_network_us, 150);
        assert_eq!(a.resident_weight_bytes, 2000, "replica footprints sum across shards");
        assert_eq!(a.latencies_us.len(), 5);
        // shards sorted by id after merge
        assert_eq!(a.shards.iter().map(|s| s.shard).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(a.percentile_us(1.0), 50);
        let occ = a.shards[1].occupancy(a.wall_time);
        assert!((occ - 4000.0 / 9000.0).abs() < 1e-9);
        assert!(!a.summary().is_empty());
    }
}
