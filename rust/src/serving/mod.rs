//! Serving coordinator — the L3 request path.
//!
//! A worker thread owns the PJRT runtime (the client is not `Send`, so it is
//! created inside the worker) and a quantized model instance; the front end
//! submits requests over a channel. A dynamic batcher groups up to
//! `max_batch` requests or waits at most `max_wait`, then executes one
//! full-sequence forward and answers every request in the batch.
//!
//! Cross-machine block placement (from `cluster::Distribution`) is simulated:
//! each batch is charged `hops × link_latency` of virtual network time,
//! reported separately from wall-clock latency.

pub mod kvcache;
pub mod trace;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServeConfig;
use crate::ewq::QuantPlan;
use crate::model::{ModelExecutor, QuantizedModel};
use crate::runtime::Runtime;
use crate::zoo::ModelDir;

/// One generation request: a token context, answered with the next token.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub context: Vec<i32>,
    submitted: Instant,
    resp: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    /// wall-clock queue+compute latency
    pub latency: Duration,
    /// simulated cross-machine network time for the batch
    pub network_latency_us: u64,
    pub batch_size: usize,
}

enum Msg {
    Req(Request),
    Stop(Sender<ServingMetrics>),
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServingMetrics {
    pub completed: usize,
    pub batches: usize,
    pub latencies_us: Vec<u64>,
    pub wall_time: Duration,
    pub max_batch_observed: usize,
    pub virtual_network_us: u64,
}

impl ServingMetrics {
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        v[((v.len() as f64 * p) as usize).min(v.len() - 1)]
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.wall_time.as_secs_f64().max(1e-9)
    }

    pub fn mean_batch(&self) -> f64 {
        self.completed as f64 / self.batches.max(1) as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "{} reqs in {:?} ({:.1} req/s), batches {} (mean {:.2}, max {}), \
             p50 {}us p95 {}us p99 {}us, virtual-net {}us",
            self.completed,
            self.wall_time,
            self.throughput_rps(),
            self.batches,
            self.mean_batch(),
            self.max_batch_observed,
            self.percentile_us(0.50),
            self.percentile_us(0.95),
            self.percentile_us(0.99),
            self.virtual_network_us,
        )
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Start the worker. `network_hops` is the placement's hop count
    /// (0 = single machine); `link_latency_us` is charged per hop per batch.
    pub fn start(
        model_path: std::path::PathBuf,
        plan: QuantPlan,
        cfg: ServeConfig,
        network_hops: usize,
        link_latency_us: u64,
    ) -> Result<Self> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::Builder::new()
            .name("ewq-coordinator".into())
            .spawn(move || {
                if let Err(e) =
                    worker(model_path, plan, cfg, network_hops, link_latency_us, rx, ready_tx)
                {
                    eprintln!("coordinator worker failed: {e:#}");
                }
            })
            .context("spawn coordinator")?;
        // block until the worker has loaded + compiled + warmed the model so
        // request latencies never include one-off startup cost
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => anyhow::bail!("coordinator startup failed: {msg}"),
            Err(_) => anyhow::bail!("coordinator died during startup"),
        }
        Ok(Self { tx, handle: Some(handle), next_id: 0.into() })
    }

    /// Submit a context; returns the response receiver.
    pub fn submit(&self, context: Vec<i32>) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = self.tx.send(Msg::Req(Request {
            id,
            context,
            submitted: Instant::now(),
            resp: rtx,
        }));
        rrx
    }

    /// Stop the worker and collect metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Msg::Stop(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        metrics
    }
}

fn worker(
    model_path: std::path::PathBuf,
    plan: QuantPlan,
    cfg: ServeConfig,
    network_hops: usize,
    link_latency_us: u64,
    rx: Receiver<Msg>,
    ready: Sender<Result<(), String>>,
) -> Result<()> {
    // PJRT client lives entirely inside this thread (not Send).
    let setup = (|| -> Result<_> {
        let rt = Runtime::cpu()?;
        let model = ModelDir::load(&model_path)?;
        let qm = QuantizedModel::build(&model, &plan)?;
        Ok((rt, model, qm))
    })();
    let (rt, model, qm) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    let ex = ModelExecutor::new(&rt, &model);
    if let Err(e) = ex.warmup() {
        let _ = ready.send(Err(format!("{e:#}")));
        return Err(e);
    }
    let _ = ready.send(Ok(()));

    let mut metrics = ServingMetrics::default();
    let started = Instant::now();
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let batch_cap = cfg.max_batch.min(model.schema.eval_batch);

    let mut pending: Vec<Request> = Vec::new();
    loop {
        // blocking wait for the first request (or stop)
        if pending.is_empty() {
            match rx.recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    metrics.wall_time = started.elapsed();
                    let _ = mtx.send(metrics);
                    return Ok(());
                }
                Err(_) => return Ok(()),
            }
        }
        // dynamic batching window
        let window_start = Instant::now();
        let mut stop: Option<Sender<ServingMetrics>> = None;
        while pending.len() < batch_cap && window_start.elapsed() < max_wait {
            match rx.recv_timeout(max_wait.saturating_sub(window_start.elapsed())) {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Stop(mtx)) => {
                    stop = Some(mtx);
                    break;
                }
                Err(_) => break,
            }
        }

        // execute one padded batch
        let batch: Vec<Request> = pending.drain(..).collect();
        let (b, s) = (model.schema.eval_batch, model.schema.seq_len);
        let mut toks = vec![0i32; b * s];
        let mut pos = vec![0usize; batch.len()];
        for (row, r) in batch.iter().enumerate() {
            let ctx = &r.context[..r.context.len().min(s)];
            toks[row * s..row * s + ctx.len()].copy_from_slice(ctx);
            pos[row] = ctx.len().saturating_sub(1);
        }
        let net_us = network_hops as u64 * link_latency_us;
        let logits = ex.forward(&qm, &toks)?;
        let v = model.schema.vocab;
        metrics.batches += 1;
        metrics.max_batch_observed = metrics.max_batch_observed.max(batch.len());
        metrics.virtual_network_us += net_us;
        for (row, r) in batch.iter().enumerate() {
            let base = (row * s + pos[row]) * v;
            let next = logits[base..base + v]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap();
            let latency = r.submitted.elapsed();
            metrics.completed += 1;
            metrics.latencies_us.push(latency.as_micros() as u64);
            let _ = r.resp.send(Response {
                id: r.id,
                next_token: next,
                latency,
                network_latency_us: net_us,
                batch_size: batch.len(),
            });
        }
        if let Some(mtx) = stop {
            metrics.wall_time = started.elapsed();
            let _ = mtx.send(metrics);
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Precision;

    fn model_path() -> Option<std::path::PathBuf> {
        let p = crate::artifacts_dir().join("models/tl-phi");
        if p.join("weights.ets").exists() {
            Some(p)
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let Some(path) = model_path() else { return };
        let plan = QuantPlan::uniform("tl-phi", 8, Precision::Q8);
        let cfg = ServeConfig { max_batch: 8, max_wait_us: 3_000, ..Default::default() };
        let coord = Coordinator::start(path, plan, cfg, 1, 200).unwrap();

        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(coord.submit(vec![1, 160 + (i % 16), 100 + (i % 57), 2]));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!((0..512).contains(&resp.next_token));
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
            assert_eq!(resp.network_latency_us, 200);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 20);
        assert!(m.batches <= 20);
        assert!(m.max_batch_observed <= 8);
        assert!(m.throughput_rps() > 0.0);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.99));
    }

    #[test]
    fn shutdown_without_requests_is_clean() {
        let Some(path) = model_path() else { return };
        let plan = QuantPlan::uniform("tl-phi", 8, Precision::Raw);
        let coord =
            Coordinator::start(path, plan, ServeConfig::default(), 0, 0).unwrap();
        let m = coord.shutdown();
        assert_eq!(m.completed, 0);
        assert_eq!(m.virtual_network_us, 0);
    }

    #[test]
    fn metrics_percentiles_ordered() {
        let m = ServingMetrics {
            completed: 5,
            batches: 2,
            latencies_us: vec![10, 50, 20, 90, 30],
            wall_time: Duration::from_millis(10),
            max_batch_observed: 3,
            virtual_network_us: 0,
        };
        assert_eq!(m.percentile_us(0.0), 10);
        assert!(m.percentile_us(0.5) <= m.percentile_us(0.95));
        assert!((m.mean_batch() - 2.5).abs() < 1e-12);
    }
}
