//! Workload trace generation for the serving benches/examples: request
//! arrival processes (Poisson / bursty / closed-loop) over the SynthMMLU
//! context distribution.

use crate::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// exponential inter-arrival times at `rps` requests/second
    Poisson { rps: f64 },
    /// `burst` back-to-back requests, then a `gap_us` pause
    Bursty { burst: usize, gap_us: u64 },
    /// all requests at t=0 (offered-load ceiling)
    Instant,
}

/// One trace entry: arrival offset from t0 + the request context.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEntry {
    pub at_us: u64,
    pub context: Vec<i32>,
}

/// Deterministic trace of `n` fact-retrieval requests.
pub fn generate(n: usize, arrival: Arrival, seed: u64) -> Vec<TraceEntry> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut t_us = 0u64;
    (0..n)
        .map(|i| {
            match arrival {
                Arrival::Poisson { rps } => {
                    let u = rng.next_f64().max(1e-12);
                    t_us += (-u.ln() / rps * 1e6) as u64;
                }
                Arrival::Bursty { burst, gap_us } => {
                    if i > 0 && i % burst == 0 {
                        t_us += gap_us;
                    }
                }
                Arrival::Instant => {}
            }
            let s = rng.below(16) as i32;
            let r = rng.below(57) as i32;
            TraceEntry { at_us: t_us, context: vec![1, 160 + s, 100 + r, 2] }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instant_all_at_zero() {
        let t = generate(10, Arrival::Instant, 1);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|e| e.at_us == 0));
    }

    #[test]
    fn bursty_inserts_gaps() {
        let t = generate(9, Arrival::Bursty { burst: 3, gap_us: 1000 }, 2);
        assert_eq!(t[0].at_us, 0);
        assert_eq!(t[2].at_us, 0);
        assert_eq!(t[3].at_us, 1000);
        assert_eq!(t[6].at_us, 2000);
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let t = generate(2000, Arrival::Poisson { rps: 1000.0 }, 3);
        let span_s = t.last().unwrap().at_us as f64 / 1e6;
        let rate = 2000.0 / span_s;
        assert!((rate - 1000.0).abs() < 150.0, "measured rate {rate}");
        // monotone arrivals
        for w in t.windows(2) {
            assert!(w[1].at_us >= w[0].at_us);
        }
    }

    #[test]
    fn contexts_are_valid_fact_queries() {
        for e in generate(100, Arrival::Instant, 4) {
            assert_eq!(e.context[0], 1);
            assert!((160..176).contains(&e.context[1]));
            assert!((100..157).contains(&e.context[2]));
            assert_eq!(e.context[3], 2);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(
            generate(20, Arrival::Poisson { rps: 500.0 }, 7),
            generate(20, Arrival::Poisson { rps: 500.0 }, 7)
        );
    }
}
