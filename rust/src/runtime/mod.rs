//! Execution runtime behind the `xla` cargo feature.
//!
//! With `--features xla` this wraps the PJRT CPU client: AOT-lowered HLO
//! **text** artifacts are parsed, compiled once, cached per path, and
//! executed via the `xla` crate (pattern: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`). Python is
//! never loaded at serve time.
//!
//! With default features (offline builds) `Runtime` is an inert handle and
//! every forward pass dispatches through the native fused-kernel executor
//! (`model::refexec::ForwardPass`, serving straight from packed `QMat`
//! payloads via `crate::kernels`, parallelized on a persistent `par::Pool`
//! whose workers stay parked between kernel scopes) — same `Runtime::cpu()`
//! surface, so callers (`exp`, `serving`, benches, examples) compile
//! identically either way. Each serving shard constructs its own `Runtime`
//! inside its worker thread (the PJRT client is not `Send`), which is what
//! lets the event-driven shard loop steal whole windows without ever
//! migrating a runtime across threads. See DESIGN.md §"xla feature matrix",
//! §"kernel layer", and §9.

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    /// Thin wrapper around the PJRT CPU client with an executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self { client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact (cached by path).
        pub fn load(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.cache.lock().unwrap().get(path) {
                return Ok(e.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = std::sync::Arc::new(
                self.client
                    .compile(&comp)
                    .with_context(|| format!("compile {}", path.display()))?,
            );
            self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
            Ok(exe)
        }

        /// Execute a cached executable on literal inputs. All our artifacts are
        /// lowered with `return_tuple=True`, so the single output is a 1-tuple.
        pub fn run(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            args: &[xla::Literal],
        ) -> Result<xla::Literal> {
            let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
            Ok(result.to_tuple1()?)
        }

        pub fn cached_modules(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }

    // ---- literal construction helpers -------------------------------------------
    /// f32 literal of arbitrary shape.
    pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            dims,
            &bytes,
        )?)
    }

    /// i32 literal (token ids).
    pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            dims,
            &bytes,
        )?)
    }

    /// i8 literal (q8 payloads).
    pub fn lit_i8(dims: &[usize], data: &[i8]) -> Result<xla::Literal> {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            dims,
            &bytes,
        )?)
    }

    /// u8 literal (packed q4/t2 payloads).
    pub fn lit_u8(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            dims,
            data,
        )?)
    }

    /// Read an f32 literal back into a Vec.
    pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Execute the shared `entropy.hlo` artifact (fixed 65536-padded input) —
    /// cross-checks the L1 Pallas kernel against the L3 native implementation.
    pub const ENTROPY_PAD: usize = 65536;
    pub const ENTROPY_NEG_PAD: f32 = -1e30;

    pub fn entropy_via_hlo(rt: &Runtime, artifacts: &Path, w: &[f32]) -> Result<f64> {
        assert!(w.len() <= ENTROPY_PAD, "tensor too large for entropy.hlo ({})", w.len());
        let exe = rt.load(&artifacts.join("entropy.hlo.txt"))?;
        let mut padded = vec![ENTROPY_NEG_PAD; ENTROPY_PAD];
        padded[..w.len()].copy_from_slice(w);
        let out = rt.run(&exe, &[lit_f32(&[ENTROPY_PAD], &padded)?])?;
        Ok(to_vec_f32(&out)?[0] as f64)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn runtime_and_artifacts() -> Option<(Runtime, std::path::PathBuf)> {
            let art = crate::artifacts_dir();
            if !art.join("entropy.hlo.txt").exists() {
                eprintln!("skipping: artifacts not built");
                return None;
            }
            Some((Runtime::cpu().unwrap(), art))
        }

        #[test]
        fn entropy_hlo_matches_native() {
            let Some((rt, art)) = runtime_and_artifacts() else { return };
            let mut r = crate::rng::Xoshiro256pp::new(1);
            for n in [100usize, 5000, 50176] {
                let w: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 0.4)).collect();
                let h_native = crate::entropy::entropy(&w);
                let h_hlo = entropy_via_hlo(&rt, &art, &w).unwrap();
                assert!(
                    (h_native - h_hlo).abs() < 3e-3 * (1.0 + h_native.abs()),
                    "n={n}: native {h_native} vs hlo {h_hlo}"
                );
            }
        }

        #[test]
        fn executable_cache_reuses_modules() {
            let Some((rt, art)) = runtime_and_artifacts() else { return };
            let _ = rt.load(&art.join("entropy.hlo.txt")).unwrap();
            let _ = rt.load(&art.join("entropy.hlo.txt")).unwrap();
            assert_eq!(rt.cached_modules(), 1);
        }

        #[test]
        fn literal_roundtrip_f32() {
            let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
            assert_eq!(to_vec_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        }

        #[test]
        fn literal_i8_u8() {
            let l = lit_i8(&[4], &[-3, -1, 0, 7]).unwrap();
            assert_eq!(l.to_vec::<i8>().unwrap(), vec![-3, -1, 0, 7]);
            let l = lit_u8(&[3], &[0, 128, 255]).unwrap();
            assert_eq!(l.to_vec::<u8>().unwrap(), vec![0, 128, 255]);
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::*;

#[cfg(not(feature = "xla"))]
mod native {
    use anyhow::Result;

    /// Inert runtime handle for offline builds: forward passes run through
    /// the fused-kernel executor (`model::refexec::ForwardPass`) and never
    /// touch this struct beyond its existence.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Self { _private: () })
        }

        pub fn platform(&self) -> String {
            "native-ref".to_string()
        }

        /// No executables are compiled on the native path.
        pub fn cached_modules(&self) -> usize {
            0
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_runtime_constructs() {
            let rt = Runtime::cpu().unwrap();
            assert_eq!(rt.platform(), "native-ref");
            assert_eq!(rt.cached_modules(), 0);
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use native::*;
